"""Routing-kernel micro-benchmarks: Pallas (interpret) vs jnp reference.

CPU wall-times are NOT TPU predictions; the derived column reports the
kernel's arithmetic intensity and VMEM working set — the quantities that
matter for the TPU roofline placement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autoencoder import bank_scores
from repro.kernels import ops
from repro.kernels.expert_score import pad_to_lane

from .common import emit, timeit


def bench_expert_score(B=1024, K=6, D=784, H=128):
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    params = {
        "w_enc": jax.random.normal(ks[0], (K, D, H)) * 0.03,
        "b_enc": jnp.zeros((K, H)),
        "bn_scale": jnp.ones((K, H)),
        "bn_bias": jnp.zeros((K, H)),
        "w_dec": jax.random.normal(ks[1], (K, H, D)) * 0.03,
        "b_dec": jnp.zeros((K, D)),
    }
    states = {"mean": jnp.zeros((K, H)), "var": jnp.ones((K, H)),
              "count": jnp.ones((K,))}
    x = jax.random.uniform(ks[2], (B, D))
    folded = ops.fold_bank(params, states)
    t_kernel = timeit(lambda: ops.expert_score_folded(folded, x))
    ref_fn = jax.jit(lambda: bank_scores(params, states, x))
    t_ref = timeit(ref_fn)
    Dp = pad_to_lane(D)
    flops = 2 * B * K * (Dp * H * 2)
    vmem_kb = (Dp * H * 2 * 4 + 128 * Dp * 4) / 1024
    ai = flops / (B * Dp * 4 + K * (Dp * H * 2) * 4)
    emit("expert_score_pallas_interp", t_kernel,
         f"B={B};K={K};AI={ai:.1f}flop/B;vmem={vmem_kb:.0f}KB")
    emit("expert_score_jnp_ref", t_ref, f"B={B};K={K}")


def bench_decode_attention(B=8, H=16, KV=4, dh=128, S=4096):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, S, KV, dh))
    v = jax.random.normal(ks[2], (B, S, KV, dh))
    q_pos = jnp.asarray(S - 1, jnp.int32)
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    t_kernel = timeit(lambda: ops.decode_attention(q, k, v, q_pos, kv_pos),
                      n_iter=3)
    from repro.kernels import ref
    ref_fn = jax.jit(lambda: ref.decode_attention_ref(q, k, v, q_pos, kv_pos))
    t_ref = timeit(ref_fn, n_iter=3)
    cache_mb = 2 * B * S * KV * dh * 4 / 2**20
    emit("decode_attention_pallas_interp", t_kernel,
         f"B={B};S={S};cache={cache_mb:.0f}MB")
    emit("decode_attention_jnp_ref", t_ref, f"B={B};S={S}")


def bench_routing_throughput(B=4096, K=6):
    """End-to-end matcher routing throughput (samples/sec, jnp path)."""
    from repro.core import build_matcher, init_ae
    aes = [init_ae(jax.random.PRNGKey(i)) for i in range(K)]
    m = build_matcher(aes, [str(i) for i in range(K)])
    x = jax.random.uniform(jax.random.PRNGKey(0), (B, 784))
    route = jax.jit(m.assign_coarse)
    t = timeit(lambda: route(x))
    emit("matcher_route_batch", t, f"B={B};{B / (t / 1e6):.0f}samples/s")


def main():
    bench_expert_score()
    bench_decode_attention()
    bench_routing_throughput()


if __name__ == "__main__":
    main()
