"""Regenerate the §Roofline tables inside EXPERIMENTS.md from the dry-run
result directories (baseline + optimized)."""
from __future__ import annotations

import re

from .roofline import build_table


def main():
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    base = build_table("results/dryrun_baseline", multi_pod=False)
    opt = build_table("results/dryrun", multi_pod=False)
    opt_mp = build_table("results/dryrun", multi_pod=True)
    text = re.sub(
        r"<!-- BASELINE_TABLE -->.*?(?=\n## )",
        "<!-- BASELINE_TABLE -->\n" + base + "\n\n",
        text, flags=re.S)
    text = re.sub(
        r"<!-- OPTIMIZED_TABLE -->.*?(?=\n### Reading the table)",
        "<!-- OPTIMIZED_TABLE -->\n" + opt
        + "\n\nMulti-pod (2×256 chips) optimized:\n\n" + opt_mp + "\n",
        text, flags=re.S)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md roofline tables updated")


if __name__ == "__main__":
    main()
