"""Serving benchmark: throughput + latency percentiles under Poisson
traffic against the continuous-batching RoutedServer.

Arrivals are *virtual-time* Poisson processes; service is real measured
compute. The event loop submits every request whose arrival time has
passed, runs one scheduler step, charges its wall-clock duration to the
virtual clock, and records per-request latency = completion - arrival.
When the system is idle the clock jumps to the next arrival, so offered
load (not Python sleep jitter) determines queueing.

Traffic scenarios (the ISSUE's acceptance matrix):
  uniform  — requests spread evenly over all experts
  skewed   — 80% of traffic hammers one expert (hot-expert queueing)
  bursty   — on/off arrivals: idle gaps, then bursts at 10x rate
  shared-prefix (``--workload shared-prefix``) — cohort traffic: groups
             of clients repeatedly send the *same* prompt (the paper's
             setting: one regional cohort, one dataset, near-identical
             queries). With ``--kv paged`` the engine deduplicates
             cohort prefills and serves repeats from the prefix cache,
             so prefill tokens *computed* drop strictly below prefill
             tokens *submitted* — the CI-asserted savings signal.
  long-prompt (``--workload long-prompt``) — mixed-length Poisson
             traffic where every few requests is a *whale* (a prompt
             near ``max_len``, far past ``chunk_len``). The bench runs
             the identical stream against a chunked server (suffix
             chunks budgeted per step via
             ``SchedulerConfig.prefill_tokens_per_step``) and a
             monolithic reference, asserts token identity, and reports
             the p99 latency of the *short* (decode-dominated)
             requests on both — the disaggregation signal: with
             chunking, decode ticks keep running while a whale
             prefills, so the short-request tail stays bounded
             (asserted, and emitted to the ``--json`` payload).
  bursty speculative (``--workload bursty --speculate-k k``) — the
             speculative-decoding comparison: one bursty decode-heavy
             stream (short prompts, 16-32 new tokens) served by a
             draft-k/verify-1 server and a plain-decode server built
             from identical params. Asserts bitwise token identity
             (greedy verification is exact), >1.5x decoded tokens/sec
             over the plain reference, zero steady-state recompiles on
             *both* servers, and (with ``--accept-floor``) a draft
             acceptance-rate floor — the CI regression signal.
  zipf (``--hub``) — the long-tail catalog workload: ``--n-experts N``
             experts served through an ExpertHub with only
             ``--resident K`` device slots (N >> K). Traffic is one
             catalog sweep (every expert cold-starts once) followed by
             Zipf-distributed arrivals, so popular experts stay
             resident while the tail churns through the slots. The
             bench runs the identical request stream against a
             fully-resident baseline hub (K = N) and asserts zero
             token divergence, evictions > 0, every expert served, and
             zero steady-state recompiles (bank jit cache + install
             executable count unchanged from post-warmup through the
             whole measured run).

crossed with two KV layouts:
  ring   — dense per-wave KV buffers (the reference)
  paged  — per-shard page pool + per-row page tables with refcounted
           shared-prefix reuse (token-identical to ring; asserted in
           tests/test_paged_kv.py)

and two placement columns:
  per-device — PR 1's path: one independent ExpertEngine per expert
  banked     — plan_placement banks homogeneous experts into one
               vmapped/sharded dispatch over a mesh ``expert`` axis
               (``--devices N`` forces N host CPU devices so the mesh
               path runs on a laptop/CI box)

and two dispatch executors:
  serial     — the blocking reference: every decode tick forces a
               device→host copy of its sampled token before the next
               shard's work is issued
  overlapped — async dispatch: all shards' prefills and decode ticks
               are enqueued before anything blocks; tokens stay on
               device and the host blocks at most once per wave per
               step (the batched harvest transfer)

Both executors are token-identical; the CI-stable signal separating
them is ``host_blocks`` (the engines' sync counter) per decoded token,
reported per scenario and in ``--json`` output.

  PYTHONPATH=src python benchmarks/serving_bench.py [--requests 60] \
      [--placement {per-device,banked}] [--devices 8] \
      [--executor {serial,overlapped}] [--kv {ring,paged}] \
      [--workload {standard,shared-prefix,long-prompt,bursty}] \
      [--chunk-len 32 --prefill-budget 32] [--json OUT.json] \
      [--speculate-k 4 --draft table --accept-floor 0.25] \
      [--hub --n-experts 64 --resident 8]

Output: one CSV-ish line per scenario,
  scenario,placement,executor,kv,n,throughput_rps,p50_ms,p95_ms,p99_ms,
  batches,prefill_compiles,host_blocks_per_tok,prefill_tok_computed,
  prefill_tok_submitted
and, with ``--json``, a machine-readable results file for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")

import numpy as np

DATASETS = ["mnist", "har", "reuters"]


def build_server(n_per_dataset: int, epochs: int, max_batch: int,
                 placement: str, executor: str = "overlapped",
                 kv: str = "ring", check_every: int = 0,
                 max_len: int = 64, chunk_len: "int | None" = None,
                 prefill_budget: int = 0, speculate_k: int = 0,
                 draft=None):
    import jax
    from repro.configs import get_config
    from repro.core import ExpertRegistry, build_matcher, train_bank
    from repro.data import load_benchmark
    from repro.launch.mesh import make_expert_mesh
    from repro.models import build_model
    from repro.serve import ExpertEngine, RoutedServer, plan_placement

    bench = load_benchmark(names=DATASETS, n_per_dataset=n_per_dataset,
                           seed=0)
    names = list(bench)
    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=epochs, batch_size=64)
    cents = [(bench[n]["server"][0], bench[n]["server"][1]) for n in names]
    matcher = build_matcher(aes, names, cents)
    registry = ExpertRegistry()
    for i, n in enumerate(names):
        cfg = get_config("smollm-135m").reduced(name=f"expert-{n}")
        model = build_model(cfg)
        registry.add(n, ExpertEngine(
            model, model.init(jax.random.PRNGKey(i)), max_len=max_len,
            kv_layout=kv, chunk_len=chunk_len,
            speculate_k=speculate_k, draft=draft))
    plan = None
    if placement == "banked":
        mesh = make_expert_mesh()
        plan = plan_placement(registry, mesh=mesh)
        print(f"# placement over {len(jax.devices())} device(s):",
              flush=True)
        for line in plan.describe(registry.names).splitlines():
            print(f"#   {line}", flush=True)
    server = RoutedServer(matcher, registry, max_batch=max_batch,
                          placement=plan, executor=executor,
                          check_every=check_every,
                          prefill_tokens_per_step=prefill_budget)
    return server, bench, names


def build_hub_server(n_experts: int, resident: int, max_batch: int,
                     executor: str, kv: str, store: "str | None",
                     seed: int = 0, use_mesh: bool = True,
                     max_len: int = 32, check_every: int = 0):
    """An ExpertHub-fronted server: ``n_experts`` catalogued, only
    ``resident`` device slots. Requests are pre-routed (no matcher —
    the hub bench isolates the residency subsystem), and with ``store``
    every expert is checkpointed cold so staging is real disk I/O. The
    slot bank shards over the expert mesh when the forced device count
    divides it (the fully-resident baseline passes ``use_mesh=False``:
    it is a token-identity reference, and GSPMD-compiling E = catalog
    vmapped graphs would dominate the bench for no extra signal)."""
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_expert_mesh
    from repro.models import build_model
    from repro.serve import ExpertHub, RoutedServer

    cfg = get_config("smollm-135m").reduced(name="hub-expert")
    model = build_model(cfg)
    mesh = make_expert_mesh() if (use_mesh and len(jax.devices()) > 1
                                  and resident % len(jax.devices()) == 0) \
        else None
    hub = ExpertHub(model, n_slots=resident, max_len=max_len, mesh=mesh,
                    kv_layout=kv, store=store)
    for i in range(n_experts):
        hub.add_expert(f"expert-{i:03d}",
                       model.init(jax.random.PRNGKey(seed + i)),
                       cold=store is not None)
    server = RoutedServer(None, hub.build_registry(),
                          max_batch=max_batch, hub=hub,
                          executor=executor, check_every=check_every)
    return server, hub


def zipf_requests(n: int, n_experts: int, rng: np.random.Generator,
                  alpha: float = 1.1, max_len: int = 32) -> list:
    """Long-tail catalog traffic: a catalog sweep (every expert exactly
    once — the cold-start path, and the guarantee that all N experts
    are served) followed by Zipf(alpha) arrivals over expert rank, so
    expert 0 is hottest and the tail churns through the hub's slots."""
    from repro.serve import Request
    p = 1.0 / np.arange(1, n_experts + 1) ** alpha
    p /= p.sum()
    picks = rng.choice(n_experts, size=max(n - n_experts, 0), p=p)
    experts = list(range(n_experts)) + list(picks)
    hi = max(4, 3 * max_len // 4)
    return [Request(uid=uid, features=np.zeros(784, np.float32),
                    prompt=rng.integers(0, 100,
                                        size=int(rng.integers(3, hi))),
                    max_new_tokens=int(rng.integers(2, 6)),
                    expert=int(e))
            for uid, e in enumerate(experts[:n])]


def _engine_stats(server):
    st = server.stats
    # engine stats are per ExpertEngine; bank stats are per bank (each
    # bank serves several experts but counts its executables once)
    return list(st["engines"].values()) + list(st["banks"].values())


def total_prefill_compiles(server) -> int:
    return sum(e.prefill_compiles for e in _engine_stats(server))


def total_decode_compiles(server) -> int:
    return sum(e.decode_compiles for e in _engine_stats(server))


def total_suffix_compiles(server) -> int:
    """Suffix-chunk executables (zero on unchunked/ring engines)."""
    return sum(e.suffix_compiles for e in _engine_stats(server))


def total_verify_compiles(server) -> int:
    """Speculative verify executables (zero on k=0 engines)."""
    return sum(e.verify_compiles for e in _engine_stats(server))


def total_jit_cache_entries(server) -> int:
    """Every real XLA executable across every engine — the number the
    zero-steady-state-recompile assertion pins between warmup and the
    end of a measured run."""
    return sum(e.jit_cache_entries for e in _engine_stats(server))


def total_host_blocks(server) -> int:
    """Host-blocking device→host syncs across all engines (the
    executor-sensitive counter: serial blocks once per decode tick per
    wave, overlapped at most once per wave per step)."""
    return sum(e.host_blocks for e in _engine_stats(server))


def total_tokens(server) -> int:
    return sum(e.tokens_generated for e in _engine_stats(server))


def total_prefill_tokens(server) -> "tuple[int, int]":
    """(computed, submitted) prompt-token totals across engines. With
    the paged layout, deduplicated and prefix-cached rows contribute
    nothing to computed — the shared-prefix savings signal."""
    return (sum(e.prefill_tokens_computed for e in _engine_stats(server)),
            sum(e.prefill_tokens_submitted for e in _engine_stats(server)))


def assert_bounded_compiles(server) -> None:
    """The bucket ladders bound the number of *real* XLA executables.

    Checked against the corrected compile counters (per-wrapper
    ``_cache_size`` sums): a wrapper that silently recompiled for a
    shape/dtype the bucket key didn't capture now trips this assert
    instead of hiding behind a one-count-per-wrapper scheme.

    On a jax build without the private counter API
    (``COMPILE_COUNTER_EXACT`` False) the counters degrade to one per
    wrapper — a *lower* bound on real executables, so the ladder check
    still holds but can no longer catch silent recompiles. That
    downgrade is announced rather than silent.
    """
    from repro.serve import ExpertEngine
    from repro.serve.core import COMPILE_COUNTER_EXACT
    if not COMPILE_COUNTER_EXACT:
        print("# WARNING: jit._cache_size() unavailable on this jax "
              "build; compile counters fall back to one per wrapper "
              "(>= semantics: a lower bound on real executables). The "
              "ladder bound below still holds, but silent per-wrapper "
              "recompiles cannot be detected.", flush=True)
    cores = [s.bank.core for s in server.scheduler.shards if s.banked]
    cores += [b.core for b in (server.registry[e].backend
                               for e in range(len(server.registry)))
              if isinstance(b, ExpertEngine)]
    bounds = [c.executable_bounds() for c in cores]
    p_bound = sum(b["prefill"] for b in bounds)
    s_bound = sum(b["suffix"] for b in bounds)
    d_bound = sum(b["decode"] for b in bounds)
    v_bound = sum(b["verify"] for b in bounds)
    got_p = total_prefill_compiles(server)
    got_s = total_suffix_compiles(server)
    got_d = total_decode_compiles(server)
    got_v = total_verify_compiles(server)
    assert (got_p <= p_bound and got_s <= s_bound and got_d <= d_bound
            and got_v <= v_bound), (
        f"compile bound violated: {got_p} prefill (bound {p_bound}), "
        f"{got_s} suffix (bound {s_bound}), {got_d} decode (bound "
        f"{d_bound}), {got_v} verify (bound {v_bound}) real executables")


def arrivals_for(scenario: str, n: int, rate: float,
                 rng: np.random.Generator) -> np.ndarray:
    """Arrival timestamps (seconds, sorted) for ``n`` requests."""
    if scenario == "bursty":
        # on/off: bursts of ~n/6 requests at 10x rate, gaps of 3/rate
        ts, t = [], 0.0
        while len(ts) < n:
            for _ in range(min(int(np.ceil(n / 6)), n - len(ts))):
                t += float(rng.exponential(1.0 / (10 * rate)))
                ts.append(t)
            t += 3.0 / rate
        return np.asarray(ts[:n])
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def expert_mix(scenario: str, n: int, n_experts: int,
               rng: np.random.Generator) -> np.ndarray:
    if scenario == "skewed":
        p = np.full(n_experts, 0.2 / max(n_experts - 1, 1))
        p[0] = 0.8
        return rng.choice(n_experts, size=n, p=p)
    return rng.integers(0, n_experts, size=n)


def cohort_requests(bench, names, n: int, rng) -> list:
    """Shared-prefix workload: cohorts of clients sending the *same*
    prompt (the paper's regional-cohort setting). Each cohort is pinned
    to one dataset/expert; prompts are 30 tokens (a 32-bucket, no ring
    wrap at max_new <= 10, so prefixes stay cacheable across waves)."""
    from repro.serve import Request
    reqs = []
    n_cohorts = max(len(names), n // 8)
    prompts = [rng.integers(0, 100, size=30) for _ in range(n_cohorts)]
    for uid in range(n):
        c = int(rng.integers(n_cohorts))
        x, _ = bench[names[c % len(names)]]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[int(rng.integers(len(x)))],
            prompt=prompts[c],
            max_new_tokens=int(rng.integers(2, 11))))
    return reqs


def long_prompt_requests(bench, names, n: int, rng,
                         max_len: int = 128,
                         whale_every: int = 6) -> "tuple[list, set]":
    """Mixed-length traffic: mostly short decode-dominated requests,
    with every ``whale_every``-th request a whale prompt near
    ``max_len`` (far past ``chunk_len``, so it prefills through the
    suffix-chunk ladder). Returns (requests, whale_uids) — the bench
    reports decode-tail latency over the *non*-whale uids."""
    from repro.serve import Request
    reqs, whales = [], set()
    for uid in range(n):
        x, _ = bench[names[uid % len(names)]]["client_a"]
        if uid % whale_every == whale_every - 1:
            size = int(rng.integers(3 * max_len // 4, max_len - 7))
            max_new = int(rng.integers(2, 5))
            whales.add(uid)
        else:
            size = int(rng.integers(3, 25))
            max_new = int(rng.integers(2, 11))
        reqs.append(Request(
            uid=uid, features=x[int(rng.integers(len(x)))],
            prompt=rng.integers(0, 100, size=size),
            max_new_tokens=max_new))
    return reqs, whales


def run_scenario(scenario: str, server, bench, names,
                 n: int, rate: float, seed: int,
                 reqs: "list | None" = None,
                 collect: "dict | None" = None,
                 whale_uids: "set | None" = None) -> dict:
    """Drive one scenario. ``reqs`` overrides the generated request
    stream (the hub bench feeds both servers the identical stream);
    ``collect`` (a dict) captures uid -> (expert, tokens) for token-
    identity comparison across servers; ``whale_uids`` splits the
    latency report — the result gains ``decode_p50_ms``/
    ``decode_p99_ms`` over the non-whale uids, plus counters for how
    many steps ran with prefill chunks pending and how many of those
    also advanced a decode wave (the disaggregation signal)."""
    import jax
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    t_arr = arrivals_for("bursty" if scenario == "bursty" else "uniform",
                         n, rate, rng)
    if reqs is not None:
        assert len(reqs) == n
    elif scenario == "shared-prefix":
        reqs = cohort_requests(bench, names, n, rng)
    else:
        which = expert_mix(scenario, n, len(names), rng)
        reqs = []
        for uid in range(n):
            x, _ = bench[names[which[uid]]]["client_a"]
            reqs.append(Request(
                uid=uid, features=x[int(rng.integers(len(x)))],
                prompt=rng.integers(0, 100,
                                    size=int(rng.integers(3, 48))),
                max_new_tokens=int(rng.integers(2, 12))))

    now, busy, i, done_at = 0.0, 0.0, 0, {}
    chunk_steps, overlap_steps = 0, 0
    sched = server.scheduler
    batches0 = sched.stats.batches
    stalls0 = sched.stats.kv_stalls
    rstalls0 = sched.stats.resident_stalls
    compiles0 = total_prefill_compiles(server)
    blocks0 = total_host_blocks(server)
    tokens0 = total_tokens(server)
    pf0 = total_prefill_tokens(server)
    while i < n or sched.has_work:
        while i < n and t_arr[i] <= now:
            got = sched.submit([reqs[i]])
            if not got:    # queue full: let the scheduler make room
                break
            i += got
        if not sched.has_work:
            now = max(now, t_arr[i])  # idle: jump to next arrival
            continue
        pending_chunks = any(
            eng is not None and getattr(eng, "core", None) is not None
            and eng.core.has_pending_chunks
            for eng in map(sched._shard_engine, sched.shards))
        ticks0 = sched.stats.ticks
        t0 = time.perf_counter()
        resps = sched.step()
        # charge device completion of every harvested response to this
        # step: without the sync the clock stops at enqueue time and
        # the reported latency percentiles under-count device work
        # still in flight (rule L004). In-flight waves of *unfinished*
        # requests stay unsynced — their device time is charged to the
        # step that eventually harvests them, preserving the overlap
        # the async executor exists to provide.
        jax.block_until_ready([r.tokens for r in resps])
        dt = time.perf_counter() - t0
        now += dt
        busy += dt
        if pending_chunks:
            chunk_steps += 1
            if sched.stats.ticks > ticks0:
                overlap_steps += 1
        for r in resps:  # completed during this step
            done_at[r.uid] = now
            if collect is not None:
                collect[r.uid] = (r.expert, r.tokens.tolist())
    lat = np.asarray([done_at[u] - t_arr[u] for u in range(n)])
    toks = total_tokens(server) - tokens0
    blocks = total_host_blocks(server) - blocks0
    pf1 = total_prefill_tokens(server)
    extra = {}
    if whale_uids is not None:
        dec = np.asarray([done_at[u] - t_arr[u] for u in range(n)
                          if u not in whale_uids])
        extra = {"decode_p50_ms": float(np.percentile(dec, 50) * 1e3),
                 "decode_p99_ms": float(np.percentile(dec, 99) * 1e3),
                 "prefill_chunk_steps": chunk_steps,
                 "decode_overlap_steps": overlap_steps}
    return {**extra, "scenario": scenario, "n": n,
            "throughput_rps": n / max(now, 1e-9),
            # decode throughput over *busy* step time (idle gaps between
            # arrivals excluded) — the speculative bench's speedup metric
            "busy_s": busy,
            "decoded_tok_per_s": toks / max(busy, 1e-9),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p95_ms": float(np.percentile(lat, 95) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "batches": sched.stats.batches - batches0,
            "prefill_compiles": total_prefill_compiles(server) - compiles0,
            "host_blocks": blocks,
            "tokens_generated": toks,
            "host_blocks_per_tok": blocks / max(toks, 1),
            "prefill_tokens_computed": pf1[0] - pf0[0],
            "prefill_tokens_submitted": pf1[1] - pf0[1],
            "kv_stalls": sched.stats.kv_stalls - stalls0,
            "resident_stalls": sched.stats.resident_stalls - rstalls0}


_CSV_HEADER = ("scenario,placement,executor,kv,n,throughput_rps,p50_ms,"
               "p95_ms,p99_ms,batches,prefill_compiles,"
               "host_blocks_per_tok,prefill_tok_computed,"
               "prefill_tok_submitted")


def _csv_row(r: dict, args) -> str:
    placement = "hub" if args.hub else args.placement
    return (f"{r['scenario']},{placement},{args.executor},"
            f"{args.kv},{r['n']},{r['throughput_rps']:.1f},"
            f"{r['p50_ms']:.1f},{r['p95_ms']:.1f},{r['p99_ms']:.1f},"
            f"{r['batches']},{r['prefill_compiles']},"
            f"{r['host_blocks_per_tok']:.3f},"
            f"{r['prefill_tokens_computed']},"
            f"{r['prefill_tokens_submitted']}")


def run_hub_bench(args) -> None:
    """The long-tail residency benchmark: N catalogued experts through
    K device slots, token-identity asserted against a fully-resident
    (K = N) baseline on the identical Zipf request stream.

    The whole measured run happens *after* the ladder warmup, so the
    no-recompile clause of the ``--hub`` acceptance criterion is
    direct: the bank's jit cache plus the slot-install executable must
    not grow across a run in which dozens of experts rotate through
    the slots.
    """
    import tempfile

    t0 = time.time()
    store = args.store or tempfile.mkdtemp(prefix="expert-store-")
    server, hub = build_hub_server(
        args.n_experts, args.resident, args.max_batch, args.executor,
        args.kv, store, seed=args.seed,
        check_every=args.check_invariants)
    base_srv, base_hub = build_hub_server(
        args.n_experts, args.n_experts, args.max_batch, args.executor,
        args.kv, None, seed=args.seed, use_mesh=False,
        check_every=args.check_invariants)
    print(f"# hub server up in {time.time()-t0:.1f}s "
          f"({args.n_experts} experts, {args.resident} slots, "
          f"kv={args.kv}, executor={args.executor}, "
          f"{hub.bank.mesh is not None and 'sharded' or 'unsharded'})",
          flush=True)
    import jax
    t0 = time.time()
    hub.warmup(args.max_batch)
    # warmup enqueues the whole ladder; sync before stopping the clock
    # so the reported figure is compile+execute, not enqueue (L004)
    jax.block_until_ready(hub.bank.core.params)
    jit_warm = hub.bank.stats.jit_cache_entries + hub.install_compiles
    print(f"# ladder warmup in {time.time()-t0:.1f}s "
          f"({jit_warm} executables)", flush=True)

    print(_CSV_HEADER)
    results = []
    rng = np.random.default_rng(args.seed)
    reqs = zipf_requests(args.requests, args.n_experts, rng,
                         alpha=args.alpha, max_len=hub.bank.max_len)
    got, want = {}, {}
    r = run_scenario("zipf", server, None, None, args.requests,
                     args.rate, args.seed, reqs=reqs, collect=got)
    rb = run_scenario("zipf", base_srv, None, None, args.requests,
                      args.rate, args.seed, reqs=reqs, collect=want)
    diverged = [u for u in want if got.get(u) != want[u]]
    assert not diverged, (
        f"hub diverged from the fully-resident baseline on uids "
        f"{diverged[:5]} (of {len(diverged)})")
    served = {e for e, _ in got.values()}
    assert len(served) == args.n_experts, (
        f"only {len(served)}/{args.n_experts} experts served")
    r["experts_served"] = len(served)
    r["baseline_throughput_rps"] = rb["throughput_rps"]
    results.append(r)
    print(_csv_row(r, args), flush=True)

    jit_end = hub.bank.stats.jit_cache_entries + hub.install_compiles
    hub.check()
    st = hub.stats
    print(f"# hub: {st.loads} loads, {st.evictions} evictions, "
          f"{st.resident_misses} resident misses, "
          f"stage {st.stage_ms_avg:.1f}ms avg, "
          f"commit {st.commit_ms_avg:.1f}ms avg", flush=True)
    print(f"# jit executables: {jit_warm} post-warmup -> {jit_end} "
          f"after the measured run", flush=True)
    # the ISSUE's acceptance criteria, asserted in-process so CI only
    # has to check the exit code
    from repro.serve.core import COMPILE_COUNTER_EXACT
    if not COMPILE_COUNTER_EXACT:
        print("# WARNING: inexact compile counters (no _cache_size): "
              "the steady-state check degrades to wrapper-count "
              "equality and cannot see per-wrapper recompiles.",
              flush=True)
    assert st.evictions > 0, "no evictions: catalog fits the slots?"
    assert jit_end == jit_warm, (
        f"steady-state recompiles: {jit_warm} executables post-warmup "
        f"grew to {jit_end}")
    assert base_hub.stats.evictions == 0   # baseline truly resident
    assert_bounded_compiles(server)
    if args.json:
        payload = {"hub": True, "n_experts": args.n_experts,
                   "resident": args.resident, "alpha": args.alpha,
                   "kv": args.kv, "executor": args.executor,
                   "requests": args.requests, "rate": args.rate,
                   "seed": args.seed, "scenarios": results,
                   "hub_stats": st.as_dict(),
                   "jit_post_warmup": jit_warm,
                   "jit_after_runs": jit_end}
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)
    if args.check_invariants:
        checks = (server.scheduler.stats.invariant_checks
                  + base_srv.scheduler.stats.invariant_checks)
        print(f"# invariants: {checks} mid-run sweeps "
              f"(every {args.check_invariants} steps), all held",
              flush=True)
    # join the staging workers: a bench that leaks its hub thread
    # would mask exactly the shutdown bugs the concurrency gate polices
    server.close()
    base_srv.close()


def run_long_prompt_bench(args) -> None:
    """The whale-prompt disaggregation benchmark: one mixed short/whale
    Poisson stream against a chunked server (suffix prefill, per-step
    chunk budget) and a monolithic reference built from identical
    params. Asserts token identity, that decode waves advanced while
    whale chunks were pending, and that the short-request (decode) p99
    stays bounded relative to the monolithic reference — the numbers
    and the bound land in the ``--json`` payload for CI."""
    from repro.serve import Request

    cl = args.chunk_len or 32
    budget = args.prefill_budget or cl
    max_len = 128
    t0 = time.time()
    server, bench, names = build_server(
        args.n_per_dataset, args.epochs, args.max_batch, args.placement,
        args.executor, "paged", check_every=args.check_invariants,
        max_len=max_len, chunk_len=cl, prefill_budget=budget)
    mono, _, _ = build_server(
        args.n_per_dataset, args.epochs, args.max_batch, args.placement,
        args.executor, "paged", check_every=args.check_invariants,
        max_len=max_len)
    print(f"# long-prompt servers up in {time.time()-t0:.1f}s "
          f"(chunk_len={cl}, prefill budget={budget} tok/step, "
          f"max_len={max_len}, placement={args.placement}, "
          f"executor={args.executor})", flush=True)

    # warm both servers' hot ladder points (one whale + one short per
    # expert) so the measured run charges the same residual compiles
    # to both sides
    wrng = np.random.default_rng(1)
    warm = []
    for k in range(len(names)):
        x = bench[names[k]]["client_a"][0]
        warm.append(Request(uid=-(2 * k + 1), features=x[k],
                            prompt=wrng.integers(0, 100, size=max_len - 8),
                            max_new_tokens=2))
        warm.append(Request(uid=-(2 * k + 2), features=x[k + 1],
                            prompt=wrng.integers(0, 100, size=12),
                            max_new_tokens=4))
    server.serve(list(warm))
    mono.serve(list(warm))
    print("# warmup done", flush=True)

    rng = np.random.default_rng(args.seed)
    reqs, whales = long_prompt_requests(bench, names, args.requests,
                                        rng, max_len=max_len)
    got, want = {}, {}
    print(_CSV_HEADER)
    r = run_scenario("long-prompt", server, bench, names, args.requests,
                     args.rate, args.seed, reqs=reqs, collect=got,
                     whale_uids=whales)
    print(_csv_row(r, args), flush=True)
    rm = run_scenario("long-prompt-mono", mono, bench, names,
                      args.requests, args.rate, args.seed, reqs=reqs,
                      collect=want, whale_uids=whales)
    print(_csv_row(rm, args), flush=True)

    diverged = [u for u in want if got.get(u) != want[u]]
    assert not diverged, (
        f"chunked server diverged from the monolithic reference on "
        f"uids {diverged[:5]} (of {len(diverged)})")
    assert r["prefill_chunk_steps"] > 0, (
        "no scheduler step ran with prefill chunks pending — whale "
        "prompts never went through the chunk ladder")
    assert r["decode_overlap_steps"] > 0, (
        "decode never advanced while a whale prefilled — the "
        "disaggregation seam is not interleaving")
    # the acceptance bound: a generous relative envelope, so the assert
    # catches a decode tail that collapsed back to whale-serialized
    # behaviour without being sensitive to CI machine noise
    bound = max(2.0 * rm["decode_p99_ms"], rm["decode_p99_ms"] + 250.0)
    assert r["decode_p99_ms"] <= bound, (
        f"short-request p99 {r['decode_p99_ms']:.1f}ms with chunking "
        f"exceeds the bound {bound:.1f}ms derived from the monolithic "
        f"reference ({rm['decode_p99_ms']:.1f}ms)")
    assert_bounded_compiles(server)
    assert_bounded_compiles(mono)
    print(f"# decode p99 while whales prefill: "
          f"{r['decode_p99_ms']:.1f}ms chunked vs "
          f"{rm['decode_p99_ms']:.1f}ms monolithic "
          f"(bound {bound:.1f}ms)", flush=True)
    print(f"# steps with chunks pending: {r['prefill_chunk_steps']}, "
          f"of which advanced decode: {r['decode_overlap_steps']}",
          flush=True)
    if args.json:
        payload = {"workload": "long-prompt",
                   "placement": args.placement,
                   "executor": args.executor, "kv": "paged",
                   "chunk_len": cl, "prefill_budget": budget,
                   "max_len": max_len, "requests": args.requests,
                   "rate": args.rate, "seed": args.seed,
                   "whales": len(whales),
                   "scenarios": [r, rm],
                   "decode_p99_ms": r["decode_p99_ms"],
                   "decode_p99_bound_ms": bound,
                   "decode_p99_bounded": bool(
                       r["decode_p99_ms"] <= bound),
                   "token_identity": True}
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)


def speculative_requests(bench, names, n: int, rng,
                         max_len: int = 128) -> list:
    """Decode-heavy traffic for the speculative bench: short prompts
    (<= 16 tokens) with long greedy continuations (32-64 tokens), so
    wall-clock is dominated by the decode ticks speculation collapses
    — and the long tails give the online bigram draft time to converge
    on each sequence's greedy cycle. The geometry keeps every admission
    inside the no-wrap gate: Sb <= 16 and steps <= 63, so
    Sb + steps + k <= 87 < max_len for any k <= 8 — no wave is forced
    onto the fallback decode path."""
    from repro.serve import Request
    reqs = []
    for uid in range(n):
        x, _ = bench[names[uid % len(names)]]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[int(rng.integers(len(x)))],
            prompt=rng.integers(0, 100, size=int(rng.integers(3, 17))),
            max_new_tokens=int(rng.integers(32, 65))))
    return reqs


def warm_full_ladder(server, rng, hi_bucket: int = 64,
                     max_new: int = 3) -> None:
    """Deterministically compile every reachable ladder point of every
    engine: one wave per (batch bucket, len bucket <= ``hi_bucket``)
    plus the decode/verify family each wave's ticks pull in.

    Scheduler admission shapes are *timing*-dependent (group sizes
    depend on how many requests arrive while a step runs), so no
    stream-driven warmup can guarantee coverage — a measured pass after
    this one is charged zero compiles by construction, which is what
    lets the bench pin ``jit_cache_entries`` exactly. Prompts are drawn
    fresh from ``rng`` so the paged prefix cache can't dedupe the
    prefill this wave exists to compile."""
    sched = server.scheduler
    for shard in sched.shards:
        eng = sched._shard_engine(shard)
        core = getattr(eng, "core", None)
        if core is None:
            continue
        for Sb in core.len_buckets:
            if Sb > hi_bucket:
                continue
            for Bb in core.batch_buckets:
                uids = [("__ladder__", Sb, Bb, i) for i in range(Bb)]
                prompts = [rng.integers(0, 100, size=Sb).astype(np.int32)
                           for _ in range(Bb)]
                core.admit_wave({0: (uids, prompts, [max_new] * Bb)})
                while core.has_pending:
                    core.tick()
                    core.harvest()
                    core.poll()


def _chain_stages(records) -> dict:
    """uid -> set of lifecycle stages observed in the trace records.
    Decode/verify spans carry only a wave id, so they are joined onto
    uids through the wave's prefill span (which lists its uids)."""
    have: dict = {}
    wave_uids: dict = {}
    decode_waves = set()
    for rec in records:
        name, a = rec["name"], rec["args"]
        if name == "request.submit":
            have.setdefault(a["uid"], set()).add("submit")
        elif name == "route":
            for u in a.get("uids", []):
                have.setdefault(u, set()).add("route")
        elif name == "request.admit":
            for u in a.get("uids", []):
                have.setdefault(u, set()).add("admit")
        elif name == "wave.prefill":
            for u in a.get("uids", []):
                have.setdefault(u, set()).add("prefill")
            wave_uids[a["wave"]] = list(a.get("uids", []))
        elif name in ("wave.decode", "wave.verify"):
            decode_waves.add(a["wave"])
        elif name == "request.finish":
            have.setdefault(a["uid"], set()).add("finish")
    for w in decode_waves:
        for u in wave_uids.get(w, []):
            have.setdefault(u, set()).add("decode")
    return have


def _stage_breakdown(records) -> dict:
    """Per-request stage table from one traced lap: queue/stalled come
    from the ``request.finish`` event's accounting, prefill/decode from
    the device spans of the waves each uid rode (decode time of a wave
    is attributed to every row in it — wave time, not per-token
    amortization). Returns p50/p95/p99 per stage in milliseconds."""
    finish: dict = {}
    prefill_ms: dict = {}
    wave_uids: dict = {}
    wave_decode_ms: dict = {}
    for rec in records:
        name, a = rec["name"], rec["args"]
        dur_ms = rec.get("dur", 0.0) / 1e3
        if name == "request.finish":
            finish[a["uid"]] = a
        elif name == "wave.prefill":
            for u in a.get("uids", []):
                prefill_ms[u] = prefill_ms.get(u, 0.0) + dur_ms
            wave_uids[a["wave"]] = list(a.get("uids", []))
        elif name in ("wave.decode", "wave.verify"):
            w = a["wave"]
            wave_decode_ms[w] = wave_decode_ms.get(w, 0.0) + dur_ms
    decode_ms: dict = {}
    for w, uids in wave_uids.items():
        for u in uids:
            decode_ms[u] = decode_ms.get(u, 0.0) + wave_decode_ms.get(
                w, 0.0)
    stages = ("queue_ms", "stalled_ms", "prefill_ms", "decode_ms",
              "total_ms")
    rows = {u: {"queue_ms": f.get("queue_ms", 0.0),
                "stalled_ms": f.get("stalled_ms", 0.0),
                "prefill_ms": prefill_ms.get(u, 0.0),
                "decode_ms": decode_ms.get(u, 0.0),
                "total_ms": f.get("total_ms", 0.0)}
            for u, f in finish.items()}
    out = {"requests": len(rows)}
    for st in stages:
        vals = np.asarray([r[st] for r in rows.values()]
                          if rows else [0.0])
        out[st] = {"p50": float(np.percentile(vals, 50)),
                   "p95": float(np.percentile(vals, 95)),
                   "p99": float(np.percentile(vals, 99))}
    return out


def _host_block_parity(spec, reqs) -> "tuple[int, int]":
    """The tentpole's sync-safety claim, asserted exactly. A *timed*
    lap cannot carry this comparison: the virtual arrival clock charges
    real step durations, so two timed laps can legitimately form
    different waves (and pay different harvest syncs) from timing noise
    alone. Instead replay the identical request list as a pure state
    machine — submit everything, drain — from a pinned starting state
    (draft table restored, prefix caches emptied), once untraced and
    once traced. Execution is then deterministic, so *any*
    ``host_blocks`` delta could only come from the tracer itself."""
    from repro.obs import Tracer
    sched = spec.scheduler
    cores = [eng.core for eng in map(sched._shard_engine, sched.shards)
             if eng is not None
             and getattr(eng, "core", None) is not None]
    saved = [c.draft_state for c in cores]   # immutable device pytrees

    def reset():
        for c, st in zip(cores, saved):
            c.draft_state = st
            if getattr(c, "prefix_cache", None) is not None \
                    and c.pool is not None:
                for e in range(c.pool.n_experts):
                    c.prefix_cache.evict_for(e, c.pool.n_pages)

    def drain_lap(tracer):
        reset()
        spec.bind_tracer(tracer)
        b0 = total_host_blocks(spec)
        try:
            sched.submit(reqs)
            while sched.has_work:
                sched.step()
        finally:
            spec.bind_tracer(None)
        return total_host_blocks(spec) - b0

    hb_off = drain_lap(None)
    parity_tracer = Tracer()
    hb_on = drain_lap(parity_tracer)
    assert parity_tracer.open_device_count() == 0, (
        f"{parity_tracer.open_device_count()} device span(s) left open "
        "after a full drain — span balance broke")
    assert hb_on == hb_off, (
        f"tracing changed the host sync count on a deterministic "
        f"replay: {hb_on} traced vs {hb_off} untraced — the tracer "
        "must close device spans only at existing sync points")
    return hb_off, hb_on


def _traced_lap(args, spec, bench, names, reqs, ref) -> dict:
    """One extra lap of the identical bursty stream on the *warm*
    speculative server with lifecycle tracing on. Same process, same
    jit caches, back to back with the tracing-off reference lap — the
    in-job comparison CI pins the <3% overhead budget against. Asserts
    the tentpole's sync-safety claim (``host_blocks`` identical on/off
    via a deterministic replay, zero device spans left open) and that
    at least one request produced a complete
    submit→route→admit→prefill→decode→finish span chain, then exports
    the Chrome trace (+ greppable JSONL sibling)."""
    from repro.obs import Tracer
    hb_off, hb_on = _host_block_parity(spec, reqs)
    tracer = Tracer()
    spec.bind_tracer(tracer)
    try:
        rt = run_scenario("bursty", spec, bench, names, args.requests,
                          args.rate, args.seed, reqs=reqs)
    finally:
        spec.bind_tracer(None)
    assert tracer.open_device_count() == 0, (
        f"{tracer.open_device_count()} device span(s) left open after "
        "a full drain — span balance broke")
    records = tracer.records()
    need = {"submit", "route", "admit", "prefill", "decode", "finish"}
    chains = [u for u, s in _chain_stages(records).items() if need <= s]
    assert chains, (
        "no request produced a complete span chain "
        "(submit→route→admit→prefill→decode→finish)")
    regression = 100.0 * (1.0 - rt["decoded_tok_per_s"]
                          / max(ref["decoded_tok_per_s"], 1e-9))
    n_events = tracer.export_chrome(args.trace)
    jsonl = args.trace + "l"  # OUT.json -> OUT.jsonl
    tracer.export_jsonl(jsonl)
    table = _stage_breakdown(records)
    print(f"# traced lap: {rt['decoded_tok_per_s']:.1f} tok/s vs "
          f"{ref['decoded_tok_per_s']:.1f} untraced "
          f"({regression:+.2f}% overhead), host_blocks "
          f"{hb_on}=={hb_off} on the deterministic replay, "
          f"{len(chains)}/{args.requests} complete span chains",
          flush=True)
    print(f"# stage breakdown (ms): " + ", ".join(
        f"{st} p50={table[st]['p50']:.1f} p99={table[st]['p99']:.1f}"
        for st in ("queue_ms", "stalled_ms", "prefill_ms",
                   "decode_ms")), flush=True)
    print(f"# wrote {args.trace} ({n_events} events) + {jsonl}",
          flush=True)
    return {"tok_per_s_off": ref["decoded_tok_per_s"],
            "tok_per_s_on": rt["decoded_tok_per_s"],
            "regression_pct": regression,
            "host_blocks_off": hb_off,
            "host_blocks_on": hb_on,
            "complete_chains": len(chains),
            "events": n_events,
            "chrome_trace": args.trace,
            "jsonl": jsonl,
            "stage_ms": table}


def run_speculative_bench(args) -> None:
    """The speculative-decoding benchmark: one bursty decode-heavy
    stream against a draft-k/verify-1 server and a plain-decode server
    built from identical params. Asserts bitwise token identity (greedy
    verification is exact by construction — this is the end-to-end
    check of that claim), a decoded-tokens/sec speedup over the plain
    reference, and that *neither* server minted a single executable
    after warmup (``jit_cache_entries`` pinned across the measured
    run — speculation must ride the bounded ladder, not grow it)."""
    k = args.speculate_k
    max_len = 128
    t0 = time.time()
    spec, bench, names = build_server(
        args.n_per_dataset, args.epochs, args.max_batch, args.placement,
        args.executor, args.kv, check_every=args.check_invariants,
        max_len=max_len, speculate_k=k, draft=args.draft)
    plain, _, _ = build_server(
        args.n_per_dataset, args.epochs, args.max_batch, args.placement,
        args.executor, args.kv, check_every=args.check_invariants,
        max_len=max_len)
    print(f"# speculative servers up in {time.time()-t0:.1f}s "
          f"(k={k}, draft={args.draft}, kv={args.kv}, "
          f"placement={args.placement}, executor={args.executor})",
          flush=True)

    # warmup, two layers: (1) compile every reachable ladder point
    # deterministically — measured-pass admission shapes are timing-
    # dependent, so only an exhaustive sweep lets the bench pin the jit
    # caches exactly; (2) two passes of the identical measured stream,
    # which converge the speculative server's engine-level draft state
    # (the online bigram table keeps learning the target experts' greedy
    # transitions across laps — drafting chains of learned successors
    # needs the *successor's* successor known too) and populate the
    # paged prefix cache both measured passes will hit the same way.
    wrng = np.random.default_rng(args.seed + 1)
    warm_full_ladder(spec, wrng, hi_bucket=16)
    warm_full_ladder(plain, wrng, hi_bucket=16)
    rng = np.random.default_rng(args.seed)
    reqs = speculative_requests(bench, names, args.requests, rng,
                                max_len=max_len)
    for _lap in range(3):
        run_scenario("bursty", spec, bench, names, args.requests,
                     args.rate, args.seed, reqs=reqs)
        run_scenario("bursty", plain, bench, names, args.requests,
                     args.rate, args.seed, reqs=reqs)
    print("# warmup done (full ladder + 3 stream laps)", flush=True)

    cache0_spec = total_jit_cache_entries(spec)
    cache0_plain = total_jit_cache_entries(plain)
    got, want = {}, {}
    print(_CSV_HEADER)
    r = run_scenario("bursty", spec, bench, names, args.requests,
                     args.rate, args.seed, reqs=reqs, collect=got)
    print(_csv_row(r, args), flush=True)
    rp = run_scenario("bursty", plain, bench, names, args.requests,
                      args.rate, args.seed, reqs=reqs, collect=want)
    rp["scenario"] = "bursty-plain"
    print(_csv_row(rp, args), flush=True)

    sstats = spec.scheduler.speculative_stats()
    speedup = (r["decoded_tok_per_s"]
               / max(rp["decoded_tok_per_s"], 1e-9))
    print(f"# decoded tok/s: {r['decoded_tok_per_s']:.1f} speculative "
          f"vs {rp['decoded_tok_per_s']:.1f} plain "
          f"({speedup:.2f}x)", flush=True)
    print(f"# acceptance: {sstats['tokens_accepted']}/"
          f"{sstats['tokens_drafted']} drafted tokens "
          f"({sstats['acceptance_rate']:.3f}) over "
          f"{sstats['verify_steps']} verify steps, "
          f"{sstats['spec_fallback_waves']} gate-blocked waves",
          flush=True)

    diverged = [u for u in want if got.get(u) != want[u]]
    assert not diverged, (
        f"speculative server diverged from plain decode on uids "
        f"{diverged[:5]} (of {len(diverged)}) — greedy verification "
        "must be bitwise exact")
    assert total_jit_cache_entries(spec) == cache0_spec, (
        f"speculative server minted executables in steady state: "
        f"{total_jit_cache_entries(spec)} != {cache0_spec}")
    assert total_jit_cache_entries(plain) == cache0_plain, (
        f"plain server minted executables in steady state: "
        f"{total_jit_cache_entries(plain)} != {cache0_plain}")
    assert_bounded_compiles(spec)
    assert_bounded_compiles(plain)
    assert speedup > 1.5, (
        f"speculative decode speedup {speedup:.2f}x <= 1.5x the plain "
        "reference on the bursty decode-heavy stream")
    if args.accept_floor > 0:
        assert sstats["acceptance_rate"] >= args.accept_floor, (
            f"draft acceptance rate {sstats['acceptance_rate']:.3f} "
            f"below the recorded floor {args.accept_floor} — the "
            "draft has regressed against the target experts")
    trace_block = None
    if args.trace:
        trace_block = _traced_lap(args, spec, bench, names, reqs, r)
    if args.json:
        payload = {"workload": "speculative",
                   "placement": args.placement,
                   "executor": args.executor, "kv": args.kv,
                   "speculate_k": k, "draft": args.draft,
                   "max_len": max_len, "requests": args.requests,
                   "rate": args.rate, "seed": args.seed,
                   "scenarios": [r, rp],
                   "speculative": sstats,
                   "speedup_decoded_tok_per_s": speedup,
                   "acceptance_floor": args.accept_floor,
                   "token_identity": True,
                   "jit_cache_stable": True}
        if trace_block is not None:
            payload["trace"] = trace_block
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean arrival rate, req/s of virtual time")
    ap.add_argument("--n-per-dataset", type=int, default=600)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--placement", choices=("per-device", "banked"),
                    default="per-device",
                    help="per-device: one ExpertEngine per expert (PR 1); "
                         "banked: plan_placement over a mesh expert axis")
    ap.add_argument("--executor", choices=("serial", "overlapped"),
                    default="overlapped",
                    help="serial: blocking per-tick reference dispatch; "
                         "overlapped: enqueue all shards' work, one "
                         "batched host transfer per wave per step")
    ap.add_argument("--kv", choices=("ring", "paged"), default="ring",
                    help="KV cache layout: ring = dense per-wave "
                         "buffers (reference); paged = per-shard page "
                         "pool with refcounted shared-prefix reuse")
    ap.add_argument("--workload",
                    choices=("standard", "shared-prefix", "long-prompt",
                             "bursty"),
                    default="standard",
                    help="standard: uniform/skewed/bursty grid; "
                         "shared-prefix: cohort traffic re-sending the "
                         "same prompts (asserts prefill-compute savings "
                         "when --kv paged); long-prompt: mixed traffic "
                         "with whale prompts, chunked vs monolithic "
                         "prefill (asserts token identity and a bounded "
                         "short-request decode tail; implies --kv paged); "
                         "bursty: the speculative comparison bench — one "
                         "bursty decode-heavy stream, draft-k/verify-1 "
                         "vs plain decode (asserts token identity, "
                         ">1.5x decoded tok/s, zero steady-state "
                         "recompiles; requires --speculate-k)")
    ap.add_argument("--chunk-len", type=int, default=0,
                    help="prefill chunk length for the long-prompt "
                         "workload (0 = the default 32); must divide "
                         "the length buckets above it")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="prompt tokens of pending chunks each shard "
                         "may dispatch per scheduler step (0 = one "
                         "chunk_len per step for long-prompt)")
    ap.add_argument("--speculate-k", type=int, default=0,
                    help="draft tokens proposed per wave per tick "
                         "(0 = no speculation); the target verifies "
                         "the whole k+1 window in one dispatch")
    ap.add_argument("--draft", choices=("mlp", "table", "always-wrong"),
                    default="table",
                    help="draft model for --speculate-k: mlp = the "
                         "resident MLP baseline scoring token "
                         "embeddings; table = a per-expert bigram "
                         "table distilled online from verified greedy "
                         "transitions; always-wrong = adversarial "
                         "lower bound (every draft rejected)")
    ap.add_argument("--accept-floor", type=float, default=0.0,
                    help="fail the bursty speculative bench if the "
                         "draft acceptance rate lands below this "
                         "(0 = record only); CI pins the recorded "
                         "floor here")
    ap.add_argument("--hub", action="store_true",
                    help="serve a long-tail expert catalog through an "
                         "ExpertHub: --n-experts catalogued, --resident "
                         "device slots, Zipf traffic, token-identity "
                         "asserted against a fully-resident baseline")
    ap.add_argument("--n-experts", type=int, default=64,
                    help="hub catalog size (with --hub)")
    ap.add_argument("--resident", type=int, default=8,
                    help="hub device bank slots (with --hub)")
    ap.add_argument("--alpha", type=float, default=1.1,
                    help="Zipf exponent for the hub workload")
    ap.add_argument("--store", default=None,
                    help="expert checkpoint store dir for --hub "
                         "(default: a temp dir; every expert is "
                         "checkpointed cold so staging is real I/O)")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="also write machine-readable results (per-"
                         "scenario metrics + corrected compile counts + "
                         "sync counters) to this path")
    ap.add_argument("--trace", metavar="OUT", default=None,
                    help="(bursty speculative workload) run one extra "
                         "lap of the identical stream on the warm "
                         "speculative server with lifecycle tracing on, "
                         "write a Chrome trace_event JSON to OUT (and a "
                         "greppable OUT + 'l' JSONL sibling), assert "
                         "host_blocks parity with the tracing-off lap + "
                         "one complete per-request span chain, and add "
                         "a per-request stage breakdown to --json")
    ap.add_argument("--check-invariants", type=int, default=0,
                    metavar="N",
                    help="run the concurrency-gate conservation sweep "
                         "(PagePool.check + hub state machine + pin "
                         "accounting) every N scheduler steps; 0 = off")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host CPU devices (multi-device dry-run "
                         "for the banked placement path); 0 = leave the "
                         "platform's real device count")
    args = ap.parse_args()
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.rate <= 0:
        ap.error("--rate must be > 0")
    if args.devices:
        # must land before jax initialises its backend (first computation
        # happens inside build_server, so this is early enough)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    if args.trace and (args.hub or args.workload != "bursty"):
        print("# --trace is wired to the bursty speculative bench "
              "only; ignoring", flush=True)
        args.trace = None

    if args.hub:
        if args.requests < args.n_experts:
            ap.error(f"--hub needs --requests >= --n-experts "
                     f"({args.n_experts}): the stream starts with a "
                     "catalog sweep so every expert is served")
        if args.resident < 1 or args.resident > args.n_experts:
            ap.error("--resident must be in [1, --n-experts]")
        run_hub_bench(args)
        return

    if args.workload == "long-prompt":
        if args.kv != "paged":
            print("# long-prompt requires the paged layout; "
                  "forcing --kv paged", flush=True)
            args.kv = "paged"
        run_long_prompt_bench(args)
        return

    if args.workload == "bursty":
        if args.speculate_k < 1:
            ap.error("--workload bursty is the speculative comparison "
                     "bench; pass --speculate-k >= 1")
        run_speculative_bench(args)
        return

    from repro.serve import Request

    t0 = time.time()
    server, bench, names = build_server(args.n_per_dataset, args.epochs,
                                        args.max_batch, args.placement,
                                        args.executor, args.kv,
                                        check_every=args.check_invariants)
    print(f"# server up in {time.time()-t0:.1f}s "
          f"({len(names)} experts, placement={args.placement}, "
          f"executor={args.executor}, kv={args.kv})", flush=True)

    # warmup: populate jit caches so scenario 1 isn't charged compiles
    rng = np.random.default_rng(1)
    warm = [Request(uid=-(k + 1),
                    features=bench[names[k % len(names)]]["client_a"][0][k],
                    prompt=rng.integers(0, 100, size=40),
                    max_new_tokens=4) for k in range(len(names))]
    server.serve(warm)
    print("# warmup done", flush=True)

    print(_CSV_HEADER)
    results = []
    scenarios = (("shared-prefix", "uniform")
                 if args.workload == "shared-prefix"
                 else ("uniform", "skewed", "bursty"))
    for scenario in scenarios:
        r = run_scenario(scenario, server, bench, names,
                         args.requests, args.rate, args.seed)
        results.append(r)
        print(_csv_row(r, args), flush=True)
    from repro.serve.core import COMPILE_COUNTER_EXACT
    pf = total_prefill_tokens(server)
    totals = {
        # compile counts are *real* XLA executables (per-wrapper
        # _cache_size sums), not jit-wrapper creations — unless this
        # jax build lacks the API (then one-per-wrapper, flagged here)
        "compile_counter_exact": COMPILE_COUNTER_EXACT,
        "prefill_compiles": total_prefill_compiles(server),
        "decode_compiles": total_decode_compiles(server),
        "host_blocks": total_host_blocks(server),
        "tokens_generated": total_tokens(server),
        "host_blocks_per_tok": (total_host_blocks(server)
                                / max(total_tokens(server), 1)),
        "prefill_tokens_computed": pf[0],
        "prefill_tokens_submitted": pf[1],
    }
    assert_bounded_compiles(server)
    print(f"# total prefill compiles (warmup + scenarios): "
          f"{totals['prefill_compiles']}", flush=True)
    print(f"# host blocks per decoded token (warmup + scenarios): "
          f"{totals['host_blocks_per_tok']:.3f}", flush=True)
    if args.workload == "shared-prefix":
        sp = results[0]
        print(f"# shared-prefix: {sp['prefill_tokens_computed']} prefill "
              f"tokens computed for {sp['prefill_tokens_submitted']} "
              "submitted", flush=True)
        if args.kv == "paged":
            # the ISSUE's acceptance criterion: cohort prompts must be
            # prefilled once, not per request
            assert (sp["prefill_tokens_computed"]
                    < sp["prefill_tokens_submitted"]), (
                "paged KV showed no prefill savings on the "
                "shared-prefix workload")
    if args.json:
        payload = {"placement": args.placement, "executor": args.executor,
                   "kv": args.kv, "workload": args.workload,
                   "devices": args.devices, "requests": args.requests,
                   "rate": args.rate, "seed": args.seed,
                   "scenarios": results, "totals": totals}
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
