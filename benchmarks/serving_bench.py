"""Serving benchmark: throughput + latency percentiles under Poisson
traffic against the continuous-batching RoutedServer.

Arrivals are *virtual-time* Poisson processes; service is real measured
compute. The event loop submits every request whose arrival time has
passed, runs one scheduler step, charges its wall-clock duration to the
virtual clock, and records per-request latency = completion - arrival.
When the system is idle the clock jumps to the next arrival, so offered
load (not Python sleep jitter) determines queueing.

Three traffic scenarios (the ISSUE's acceptance matrix):
  uniform  — requests spread evenly over all experts
  skewed   — 80% of traffic hammers one expert (hot-expert queueing)
  bursty   — on/off arrivals: idle gaps, then bursts at 10x rate

crossed with two placement columns:
  per-device — PR 1's path: one independent ExpertEngine per expert
  banked     — plan_placement banks homogeneous experts into one
               vmapped/sharded dispatch over a mesh ``expert`` axis
               (``--devices N`` forces N host CPU devices so the mesh
               path runs on a laptop/CI box)

  PYTHONPATH=src python benchmarks/serving_bench.py [--requests 60] \
      [--placement {per-device,banked}] [--devices 8]

Output: one CSV-ish line per scenario,
  scenario,placement,n,throughput_rps,p50_ms,p99_ms,batches,prefill_compiles
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, "src")

import numpy as np

DATASETS = ["mnist", "har", "reuters"]


def build_server(n_per_dataset: int, epochs: int, max_batch: int,
                 placement: str):
    import jax
    from repro.configs import get_config
    from repro.core import ExpertRegistry, build_matcher, train_bank
    from repro.data import load_benchmark
    from repro.launch.mesh import make_expert_mesh
    from repro.models import build_model
    from repro.serve import ExpertEngine, RoutedServer, plan_placement

    bench = load_benchmark(names=DATASETS, n_per_dataset=n_per_dataset,
                           seed=0)
    names = list(bench)
    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=epochs, batch_size=64)
    cents = [(bench[n]["server"][0], bench[n]["server"][1]) for n in names]
    matcher = build_matcher(aes, names, cents)
    registry = ExpertRegistry()
    for i, n in enumerate(names):
        cfg = get_config("smollm-135m").reduced(name=f"expert-{n}")
        model = build_model(cfg)
        registry.add(n, ExpertEngine(
            model, model.init(jax.random.PRNGKey(i)), max_len=64))
    plan = None
    if placement == "banked":
        mesh = make_expert_mesh()
        plan = plan_placement(registry, mesh=mesh)
        print(f"# placement over {len(jax.devices())} device(s):",
              flush=True)
        for line in plan.describe(registry.names).splitlines():
            print(f"#   {line}", flush=True)
    server = RoutedServer(matcher, registry, max_batch=max_batch,
                          placement=plan)
    return server, bench, names


def total_prefill_compiles(server) -> int:
    st = server.stats
    # engine stats are per ExpertEngine; bank stats are per bank (each
    # bank serves several experts but counts its executables once)
    return (sum(e.prefill_compiles for e in st["engines"].values())
            + sum(b.prefill_compiles for b in st["banks"].values()))


def arrivals_for(scenario: str, n: int, rate: float,
                 rng: np.random.Generator) -> np.ndarray:
    """Arrival timestamps (seconds, sorted) for ``n`` requests."""
    if scenario == "bursty":
        # on/off: bursts of ~n/6 requests at 10x rate, gaps of 3/rate
        ts, t = [], 0.0
        while len(ts) < n:
            for _ in range(min(int(np.ceil(n / 6)), n - len(ts))):
                t += float(rng.exponential(1.0 / (10 * rate)))
                ts.append(t)
            t += 3.0 / rate
        return np.asarray(ts[:n])
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def expert_mix(scenario: str, n: int, n_experts: int,
               rng: np.random.Generator) -> np.ndarray:
    if scenario == "skewed":
        p = np.full(n_experts, 0.2 / max(n_experts - 1, 1))
        p[0] = 0.8
        return rng.choice(n_experts, size=n, p=p)
    return rng.integers(0, n_experts, size=n)


def run_scenario(scenario: str, server, bench, names,
                 n: int, rate: float, seed: int) -> dict:
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    t_arr = arrivals_for(scenario, n, rate, rng)
    which = expert_mix(scenario, n, len(names), rng)
    reqs = []
    for uid in range(n):
        x, _ = bench[names[which[uid]]]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[int(rng.integers(len(x)))],
            prompt=rng.integers(0, 100,
                                size=int(rng.integers(3, 48))),
            max_new_tokens=int(rng.integers(2, 12))))

    now, i, done_at = 0.0, 0, {}
    sched = server.scheduler
    batches0 = sched.stats["batches"]
    compiles0 = total_prefill_compiles(server)
    while i < n or sched.has_work:
        while i < n and t_arr[i] <= now:
            got = sched.submit([reqs[i]])
            if not got:    # queue full: let the scheduler make room
                break
            i += got
        if not sched.has_work:
            now = max(now, t_arr[i])  # idle: jump to next arrival
            continue
        t0 = time.perf_counter()
        resps = sched.step()
        now += time.perf_counter() - t0
        for r in resps:  # completed during this step
            done_at[r.uid] = now
    lat = np.asarray([done_at[u] - t_arr[u] for u in range(n)])
    return {"scenario": scenario, "n": n,
            "throughput_rps": n / max(now, 1e-9),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "batches": sched.stats["batches"] - batches0,
            "prefill_compiles": total_prefill_compiles(server) - compiles0}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean arrival rate, req/s of virtual time")
    ap.add_argument("--n-per-dataset", type=int, default=600)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--placement", choices=("per-device", "banked"),
                    default="per-device",
                    help="per-device: one ExpertEngine per expert (PR 1); "
                         "banked: plan_placement over a mesh expert axis")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host CPU devices (multi-device dry-run "
                         "for the banked placement path); 0 = leave the "
                         "platform's real device count")
    args = ap.parse_args()
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.rate <= 0:
        ap.error("--rate must be > 0")
    if args.devices:
        # must land before jax initialises its backend (first computation
        # happens inside build_server, so this is early enough)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    from repro.serve import Request

    t0 = time.time()
    server, bench, names = build_server(args.n_per_dataset, args.epochs,
                                        args.max_batch, args.placement)
    print(f"# server up in {time.time()-t0:.1f}s "
          f"({len(names)} experts, placement={args.placement})",
          flush=True)

    # warmup: populate jit caches so scenario 1 isn't charged compiles
    rng = np.random.default_rng(1)
    warm = [Request(uid=-(k + 1),
                    features=bench[names[k % len(names)]]["client_a"][0][k],
                    prompt=rng.integers(0, 100, size=40),
                    max_new_tokens=4) for k in range(len(names))]
    server.serve(warm)
    print("# warmup done", flush=True)

    print("scenario,placement,n,throughput_rps,p50_ms,p99_ms,batches,"
          "prefill_compiles")
    for scenario in ("uniform", "skewed", "bursty"):
        r = run_scenario(scenario, server, bench, names,
                         args.requests, args.rate, args.seed)
        print(f"{r['scenario']},{args.placement},{r['n']},"
              f"{r['throughput_rps']:.1f},"
              f"{r['p50_ms']:.1f},{r['p99_ms']:.1f},{r['batches']},"
              f"{r['prefill_compiles']}", flush=True)
    print(f"# total prefill compiles (warmup + scenarios): "
          f"{total_prefill_compiles(server)}", flush=True)


if __name__ == "__main__":
    main()
