"""Reproduction of the paper's Tables 1-4 on the synthetic analogues.

One function per table; each returns (rows, summary) and is invoked by
``benchmarks/run.py``. Paper reference numbers are embedded for the
side-by-side comparison written to EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_matcher, train_bank, train_mlp
from repro.core import mlp_baseline
from repro.data import load_benchmark
from repro.data.synthetic import SPECS

PAPER = {
    "table2": {"AE-MSE": (99.94, 99.91), "MLP-Softmax": (99.95, 99.97)},
    "table3": {"mnist": (100.0, 100.0), "stl10": (100.0, 100.0),
               "har": (100.0, 100.0), "reuters": (99.64, 99.56),
               "nlos": (99.92, 99.89), "db": (96.49, 95.36),
               "average": (99.34, 99.13)},
    "table4": {"mnist": (84.36, 83.40), "nlos": (71.78, 71.26),
               "db": (41.47, 44.41)},
}


def _build(n_per_dataset=2000, epochs=45, seed=0, names=None):
    bench = load_benchmark(names=names, n_per_dataset=n_per_dataset,
                           seed=seed)
    names = list(bench)
    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=epochs, batch_size=128)
    cents = [(bench[n]["server"][0], bench[n]["server"][1]) for n in names]
    matcher = build_matcher(aes, names, cents)
    return bench, names, matcher


def table1_datasets():
    rows = []
    for name, s in SPECS.items():
        rows.append({"dataset": name, "type": s.kind, "classes": s.n_classes,
                     "samples": s.n_samples, "dim": s.raw_dim,
                     "lc_sc": s.lc_sc})
    return rows, "6 datasets; counts match paper Table 1"


def _ca_accuracy(matcher, bench, names, client):
    accs = {}
    for i, n in enumerate(names):
        x, _ = bench[n][client]
        pred = np.asarray(matcher.assign_coarse(jnp.asarray(x)))
        accs[n] = 100.0 * float((pred == i).mean())
    accs["average"] = float(np.mean(list(accs.values())))
    return accs


def table3_coarse(n_per_dataset=2000, epochs=45):
    """CA accuracy, 6 datasets x clients A/B (paper Table 3)."""
    bench, names, matcher = _build(n_per_dataset, epochs)
    rows = []
    for client, tag in (("client_a", "Client A"), ("client_b", "Client B")):
        accs = _ca_accuracy(matcher, bench, names, client)
        for n in names + ["average"]:
            rows.append({"client": tag, "dataset": n, "ours": accs[n],
                         "paper": PAPER["table3"].get(n, (None, None))[
                             0 if client == "client_a" else 1]})
    avg_a = [r for r in rows if r["client"] == "Client A"
             and r["dataset"] == "average"][0]["ours"]
    return rows, f"CA avg Client A: {avg_a:.2f}% (paper: 99.34%)"


def table2_ca_methods(n_per_dataset=2000, epochs=45):
    """AE-MSE vs MLP-Softmax on 4 datasets (paper Table 2)."""
    four = ["stl10", "mnist", "har", "reuters"]
    bench, names, matcher = _build(n_per_dataset, epochs, names=four)
    xs = np.concatenate([bench[n]["server"][0] for n in names])
    ys = np.concatenate([np.full(len(bench[n]["server"][0]), i)
                         for i, n in enumerate(names)])
    mp, ms = train_mlp(xs, ys, n_classes=len(names), epochs=epochs,
                       batch_size=128)
    rows = []
    for client, tag in (("client_a", "Client A"), ("client_b", "Client B")):
        ae_acc = _ca_accuracy(matcher, bench, names, client)["average"]
        xa = np.concatenate([bench[n][client][0] for n in names])
        ya = np.concatenate([np.full(len(bench[n][client][0]), i)
                             for i, n in enumerate(names)])
        pred = np.asarray(mlp_baseline.predict(mp, ms, jnp.asarray(xa)))
        mlp_acc = 100.0 * float((pred == ya).mean())
        col = 0 if client == "client_a" else 1
        rows.append({"client": tag, "AE-MSE": ae_acc,
                     "AE-MSE paper": PAPER["table2"]["AE-MSE"][col],
                     "MLP-Softmax": mlp_acc,
                     "MLP paper": PAPER["table2"]["MLP-Softmax"][col]})
    return rows, (f"AE {rows[0]['AE-MSE']:.2f}% vs MLP "
                  f"{rows[0]['MLP-Softmax']:.2f}% (paper: 99.94/99.95)")


def table4_fine(n_per_dataset=2000, epochs=45):
    """FA accuracy on MNIST/NLOS/DB analogues (paper Table 4)."""
    targets = ["mnist", "nlos", "db"]
    bench, names, matcher = _build(n_per_dataset, epochs)
    rows = []
    for n in targets:
        i = names.index(n)
        for client, tag in (("client_a", "Client A"),
                            ("client_b", "Client B")):
            x, y = bench[n][client]
            fine = np.asarray(matcher.assign_fine(
                jnp.asarray(x), jnp.full(len(x), i)))
            acc = 100.0 * float((fine == y).mean())
            col = 0 if client == "client_a" else 1
            rows.append({"dataset": n, "client": tag, "ours": acc,
                         "paper": PAPER["table4"][n][col],
                         "classes": SPECS[n].n_classes})
    return rows, "; ".join(
        f"{r['dataset']}:{r['ours']:.1f}%(paper {r['paper']})"
        for r in rows if r["client"] == "Client A")
