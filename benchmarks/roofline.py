"""Roofline table builder: reads results/dryrun/*.json and emits the
per-(arch x shape x mesh) three-term analysis for EXPERIMENTS.md.

MODEL_FLOPS convention: 6*N*D for dense (N params, D tokens),
6*N_active*D for MoE; serving steps use 2*N(_active)*D. The ratio
MODEL_FLOPS / HLO_FLOPS shows how much compiled compute is "useful"
(catches remat/redundancy waste).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.launch.mesh import HW
from repro.models import SHAPES, build_model
from repro.models.common import tree_size


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic 'useful' FLOPs for the whole step (all devices)."""
    cfg = get_config(arch)
    sc = SHAPES[shape_name]
    model = build_model(cfg)
    n = tree_size(model.param_shapes())
    if cfg.n_experts:  # active params only
        expert = 3 * cfg.d_model * cfg.d_ff
        n = n - cfg.n_layers * (cfg.n_experts - cfg.experts_per_token) * expert
    tokens = sc.global_batch * (sc.seq_len if sc.mode != "decode" else 1)
    per_tok = 6 * n if sc.mode == "train" else 2 * n
    return float(per_tok) * tokens


def load_results(result_dir: str = "results/dryrun") -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def build_table(result_dir: str = "results/dryrun",
                multi_pod: Optional[bool] = False) -> str:
    rows = []
    for r in load_results(result_dir):
        if multi_pod is not None and r.get("multi_pod") != multi_pod:
            continue
        arch, shape = r["arch"], r["shape"]
        if r["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | — | — | — | — | skipped | "
                        f"{r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {arch} | {shape} | — | — | — | — | ERROR | "
                        f"{r.get('error', '')[:60]} |")
            continue
        rf = r["roofline"]
        mf = model_flops(arch, shape)
        hlo_total = r["flops_per_device"] * r["n_devices"]
        useful = mf / hlo_total if hlo_total else 0.0
        peak_gb = r["memory"]["peak_bytes"] / 2**30
        adj = r["memory"].get("peak_bytes_tpu_adj")
        note = f"peak {peak_gb:.1f} GiB"
        if adj:
            note += f" (tpu-adj {adj / 2**30:.1f})"
        rows.append(
            f"| {arch} | {shape} | {rf['t_compute_s']:.3g} | "
            f"{rf['t_memory_s']:.3g} | {rf['t_collective_s']:.3g} | "
            f"{useful:.2f} | {rf['bottleneck']} | {note} |")
    head = ("| arch | shape | t_compute (s) | t_memory (s) | "
            "t_collective (s) | useful-FLOPs ratio | bottleneck | notes |\n"
            "|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    print(build_table(args.dir, multi_pod=args.multi_pod))


if __name__ == "__main__":
    main()
