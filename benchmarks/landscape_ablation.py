"""Beyond-paper ablation of the ExpertMatcher landscape axes (Fig. 1).

The paper *describes* Resolution x Fusion x Metric but only evaluates
(coarse, top-1, MSE) and (fine, top-1, cosine). This ablation fills in the
grid on the synthetic benchmark:

  * Fusion: top-K CA accuracy (is the right expert in the top-K?)
  * Metric: MSE vs cosine for the coarse assignment
  * Kernel: jnp bank scoring vs the fused Pallas expert_score kernel
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import MatcherConfig, build_matcher, train_bank
from repro.data import load_benchmark

from .common import emit


def run(n_per_dataset=1500, epochs=40):
    bench = load_benchmark(n_per_dataset=n_per_dataset, seed=0)
    names = list(bench)
    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=epochs, batch_size=64)

    def ca_topk(matcher, k):
        hits, total = 0, 0
        for i, n in enumerate(names):
            x, _ = bench[n]["client_a"]
            idx, _ = matcher.assign_coarse_topk(jnp.asarray(x))
            hits += int((np.asarray(idx)[:, :k] == i).any(axis=1).sum())
            total += len(x)
        return 100.0 * hits / total

    rows = []
    # fusion axis
    m = build_matcher(aes, names, config=MatcherConfig(top_k=3))
    for k in (1, 2, 3):
        acc = ca_topk(m, k)
        rows.append(("fusion", f"top-{k}", acc))
        emit(f"landscape_fusion_top{k}", 0.0, f"CA@top{k}={acc:.2f}%")
    # metric axis
    for metric in ("mse", "cosine"):
        mm = build_matcher(aes, names,
                           config=MatcherConfig(metric=metric, top_k=1))
        acc = ca_topk(mm, 1)
        rows.append(("metric", metric, acc))
        emit(f"landscape_metric_{metric}", 0.0, f"CA={acc:.2f}%")
    # kernel-path equivalence (Pallas expert_score vs jnp bank scoring)
    mj = build_matcher(aes, names)
    x = jnp.asarray(bench[names[0]]["client_a"][0][:256])
    s_jnp = np.asarray(mj.coarse_scores(x))
    from repro.kernels import ops
    s_ker = np.asarray(ops.expert_score(mj.bank_params, x, mj.bank_states))
    agree = float((s_jnp.argmin(1) == s_ker.argmin(1)).mean())
    maxd = float(np.abs(s_jnp - s_ker).max())
    emit("landscape_kernel_vs_jnp", 0.0,
         f"argmin-agree={agree:.3f};maxdiff={maxd:.2e}")
    rows.append(("kernel", "pallas==jnp", 100 * agree))
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
