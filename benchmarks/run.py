"""Benchmark entry point: one function per paper table + perf benches.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--fast] [--tables-only]

Prints ``name,us_per_call,derived`` CSV rows per benchmark, then the
paper-table reproductions (ours vs paper side by side).
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes for CI (~2 min)")
    ap.add_argument("--tables-only", action="store_true")
    ap.add_argument("--kernels-only", action="store_true")
    args = ap.parse_args()

    n = 800 if args.fast else 2000
    epochs = 25 if args.fast else 45

    if not args.tables_only:
        from . import kernel_bench
        print("# --- kernel micro-benchmarks (name,us_per_call,derived) ---")
        t0 = time.time()
        kernel_bench.main()
        print(f"# kernels done in {time.time()-t0:.1f}s")
        if args.kernels_only:
            return

    from . import paper_tables
    from .common import emit

    print("# --- paper table reproductions ---")
    t0 = time.time()
    rows, summary = paper_tables.table1_datasets()
    emit("table1_datasets", (time.time() - t0) * 1e6, summary)

    t0 = time.time()
    rows, summary = paper_tables.table3_coarse(n, epochs)
    emit("table3_coarse_CA", (time.time() - t0) * 1e6, summary)
    for r in rows:
        paper = f"{r['paper']:.2f}" if r["paper"] is not None else "-"
        print(f"#   {r['client']:9s} {r['dataset']:8s} "
              f"ours={r['ours']:6.2f}%  paper={paper}%")

    t0 = time.time()
    rows, summary = paper_tables.table2_ca_methods(n, epochs)
    emit("table2_ae_vs_mlp", (time.time() - t0) * 1e6, summary)
    for r in rows:
        print(f"#   {r['client']:9s} AE-MSE ours={r['AE-MSE']:6.2f}% "
              f"(paper {r['AE-MSE paper']}%)  MLP ours="
              f"{r['MLP-Softmax']:6.2f}% (paper {r['MLP paper']}%)")

    t0 = time.time()
    rows, summary = paper_tables.table4_fine(n, epochs)
    emit("table4_fine_FA", (time.time() - t0) * 1e6, summary)
    for r in rows:
        print(f"#   {r['dataset']:6s} {r['client']:9s} "
              f"ours={r['ours']:6.2f}%  paper={r['paper']}%  "
              f"({r['classes']} classes)")

    if not args.fast:
        from . import landscape_ablation
        print("# --- beyond-paper landscape ablation (Fig. 1 grid) ---")
        landscape_ablation.run(n_per_dataset=min(n, 1500), epochs=epochs)


if __name__ == "__main__":
    main()
