"""Shared benchmark harness utilities."""
from __future__ import annotations

import sys
import time
from typing import Callable

import jax
import numpy as np


def timeit(fn: Callable, *args, n_warm: int = 2, n_iter: int = 10) -> float:
    """Median wall-time per call in microseconds (CPU; relative numbers)."""
    for _ in range(n_warm):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") or \
        isinstance(r, jax.Array) else None
    ts = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if isinstance(x, jax.Array)
            else x, r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()
