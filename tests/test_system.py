"""End-to-end system tests: the paper's pipeline as a serving system,
plus training-loop integration (loss goes down) and attention invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis: seeded fallback grid
    from _prop import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (ExpertRegistry, MatcherConfig, build_matcher,
                        train_bank)
from repro.data import load_benchmark, synthetic_token_stream
from repro.models import build_model
from repro.models.attention import attention
from repro.serve import ExpertEngine, Request, RoutedServer
from repro.train import Trainer


@pytest.fixture(scope="module")
def small_bench():
    return load_benchmark(names=["mnist", "har", "reuters"],
                          n_per_dataset=1200, seed=0)


@pytest.fixture(scope="module")
def small_matcher(small_bench):
    names = list(small_bench)
    aes, _ = train_bank([(n, small_bench[n]["server"][0]) for n in names],
                        epochs=40, batch_size=64)
    cents = [(small_bench[n]["server"][0], small_bench[n]["server"][1])
             for n in names]
    return build_matcher(aes, names, cents), names


def test_coarse_assignment_accuracy(small_matcher, small_bench):
    """The paper's core claim (Table 3): CA via min-MSE is near-perfect."""
    m, names = small_matcher
    for client in ("client_a", "client_b"):
        accs = []
        for i, n in enumerate(names):
            x, _ = small_bench[n][client]
            pred = np.asarray(m.assign_coarse(jnp.asarray(x)))
            accs.append((pred == i).mean())
        assert np.mean(accs) > 0.9, (client, accs)


def test_fine_assignment_beats_chance(small_matcher, small_bench):
    m, names = small_matcher
    i = names.index("mnist")
    x, y = small_bench["mnist"]["client_a"]
    fine = np.asarray(m.assign_fine(jnp.asarray(x),
                                    jnp.full(len(x), i)))
    n_cls = int(y.max()) + 1
    assert (fine == y).mean() > 2.0 / n_cls


def test_routed_server_end_to_end(small_matcher, small_bench):
    """Fig. 2 as a serving system: requests route to the right expert
    engine and produce generated tokens."""
    m, names = small_matcher
    reg = ExpertRegistry()
    for n in names:
        cfg = get_config("smollm-135m").reduced(name=f"expert-{n}")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(hash(n) % 2**31))
        reg.add(n, ExpertEngine(model, params, max_len=64))
    server = RoutedServer(m, reg, max_batch=4)
    reqs = []
    uid = 0
    rng = np.random.default_rng(0)
    for n in names:
        x, _ = small_bench[n]["client_a"]
        for j in range(3):
            reqs.append(Request(
                uid=uid, features=x[j],
                prompt=rng.integers(0, 100, size=rng.integers(4, 12)),
                max_new_tokens=4))
            uid += 1
    resps = server.serve(reqs)
    assert len(resps) == len(reqs)
    correct = sum(r.expert == names[i // 3] for i, r in enumerate(resps))
    assert correct / len(resps) > 0.8
    for r in resps:
        assert r.tokens.shape == (4,)
        assert r.fine_class >= 0


def test_trainer_reduces_loss():
    cfg = get_config("llama3.2-1b").reduced(n_layers=2, d_model=64,
                                            vocab_size=256)
    model = build_model(cfg)
    tr = Trainer(model, lr=3e-3, total_steps=60)
    stream = synthetic_token_stream(cfg.vocab_size, 32, 8, seed=0)
    hist = tr.fit(stream, steps=60, log_every=10)
    first, last = hist[0][1], hist[-1][1]
    assert last < first - 0.25, f"loss did not decrease: {first} -> {last}"


def test_trainer_microbatch_equivalence():
    """Gradient accumulation == full-batch step (same loss trajectory)."""
    cfg = get_config("llama3.2-1b").reduced(n_layers=2, d_model=64,
                                            vocab_size=128)
    stream = synthetic_token_stream(cfg.vocab_size, 16, 8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
    from repro.optim import constant_lr
    from repro.train.loop import init_train_state, make_train_step
    model = build_model(cfg)
    s0 = init_train_state(model, jax.random.PRNGKey(0))
    step1 = jax.jit(make_train_step(model, lr_fn=constant_lr(1e-3),
                                    microbatches=1))
    step4 = jax.jit(make_train_step(model, lr_fn=constant_lr(1e-3),
                                    microbatches=4))
    s1, m1 = step1(s0, batch)
    s4, m4 = step4(s0, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    l1 = jax.tree_util.tree_leaves(s1["params"])
    l4 = jax.tree_util.tree_leaves(s4["params"])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)


# -- attention invariants (hypothesis) --------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.sampled_from([64, 128]),
       st.sampled_from([0, 32]), st.booleans())
def test_flash_equals_plain_attention(b, s, window, causal):
    """Blockwise online-softmax == plain masked softmax for any
    (batch, seq, window, causality)."""
    ks = jax.random.split(jax.random.PRNGKey(b * s + window), 3)
    H, KV, dh = 4, 2, 16
    q = jax.random.normal(ks[0], (b, s, H, dh))
    k = jax.random.normal(ks[1], (b, s, KV, dh))
    v = jax.random.normal(ks[2], (b, s, KV, dh))
    pos = jnp.arange(s)
    plain = attention(q, k, v, q_pos=pos, kv_pos=pos, window=window,
                      chunk=0, causal=causal)
    flash = attention(q, k, v, q_pos=pos, kv_pos=pos, window=window,
                      chunk=16, causal=causal)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(flash),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_attention_ignores_empty_slots(seed):
    """kv_pos == -1 slots must contribute nothing, whatever their values."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    B, S, H, KV, dh = 1, 32, 2, 2, 8
    q = jax.random.normal(ks[0], (B, 1, H, dh))
    k = jax.random.normal(ks[1], (B, S, KV, dh))
    v = jax.random.normal(ks[2], (B, S, KV, dh))
    kv_pos = jnp.where(jnp.arange(S) < 20, jnp.arange(S), -1)
    o1 = attention(q, k, v, q_pos=jnp.asarray([25]), kv_pos=kv_pos)
    garbage = jax.random.normal(ks[3], (B, S, KV, dh)) * 100
    k2 = jnp.where((kv_pos == -1)[None, :, None, None], garbage, k)
    v2 = jnp.where((kv_pos == -1)[None, :, None, None], garbage, v)
    o2 = attention(q, k2, v2, q_pos=jnp.asarray([25]), kv_pos=kv_pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6)
