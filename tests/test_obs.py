"""Observability subsystem tests (``repro.obs`` + the O-rule gate).

The tentpole claims, each proven here against real serving traffic:

  * **propagation** — a trace id minted at ``Scheduler.submit`` follows
    the request through the hub lifecycle (park -> stage -> commit) and
    the engine's device spans all the way to ``request.finish``;
  * **span balance** — every ``begin_device`` handle is closed by the
    time traffic drains, including across the two rollback paths
    (``PagePoolExhausted`` requeue, speculative no-wrap fallback);
  * **zero new host blocks** — ``EngineStats.host_blocks`` is identical
    with tracing on and off, because device spans only ever close
    inside the engine's *existing* sync points;
  * **snapshot stability** — ``obs.snapshot()`` exposes one stable tree
    (scheduler / engines / kv / hub / executor) whose keys downstream
    dashboards may rely on;
  * **the static gate** — planted O001/O002/O003 violations are caught,
    and the compliant idioms pass (mirrors tests/test_analysis.py).
"""
import textwrap

import jax
import numpy as np
import pytest

from repro.analysis import obs_lint
from repro.core import ExpertRegistry
from repro.configs import get_config
from repro.models import build_model
from repro.obs import (Counter, DEFAULT_MS_BUCKETS, Gauge, Histogram,
                       MetricsRegistry, NULL_TRACER, Tracer)
from repro.serve import (ExpertEngine, ExpertHub, Request, RoutedServer,
                         Scheduler, SchedulerConfig, SchedulerStats)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("smollm-135m").reduced(name="obs-t")
    return build_model(cfg)


@pytest.fixture(scope="module")
def params2(model):
    return [model.init(jax.random.PRNGKey(s)) for s in range(2)]


def _reqs(rng, n, n_experts, lo=3, hi=28, max_new=(1, 5)):
    return [Request(uid=u, features=np.zeros(784, np.float32),
                    prompt=rng.integers(0, 50,
                                        size=int(rng.integers(lo, hi))),
                    max_new_tokens=int(rng.integers(*max_new)),
                    expert=int(u % n_experts))
            for u in range(n)]


def _by(recs, name):
    return [r for r in recs if r["name"] == name]


# -- metrics primitives ------------------------------------------------------


def test_metric_primitives_and_registry_tree():
    c, g = Counter(), Gauge()
    c.inc()
    c.inc(4)
    g.set(2.5)
    h = Histogram()
    assert h.snapshot()["p99"] == 0.0          # empty histogram is sane
    for v in (0.2, 0.2, 3.0, 40.0, 4000.0):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 5 and s["max"] == 4000.0
    assert s["p50"] <= s["p95"] <= s["p99"] <= 5000.0
    assert abs(s["mean"] - s["sum"] / 5) < 1e-9
    # percentiles are upper bounds from the literal bucket ladder
    assert s["p50"] in DEFAULT_MS_BUCKETS
    with pytest.raises(ValueError):
        Histogram(buckets=())
    with pytest.raises(ValueError):
        Histogram(buckets=(2.0, 1.0))

    obs = MetricsRegistry()
    obs.register("scheduler", lambda: {"submitted": c.value})
    obs.register("scheduler/latency/queue_ms", h)
    obs.register("engines/shard0", {"ticks": g})
    snap = obs.snapshot()
    assert snap["scheduler"]["submitted"] == 5
    assert snap["scheduler"]["latency"]["queue_ms"]["count"] == 5
    assert snap["engines"]["shard0"]["ticks"] == 2.5
    # re-registration replaces, not duplicates
    obs.register("engines/shard0", {"ticks": 7})
    assert obs.snapshot()["engines"]["shard0"]["ticks"] == 7


def test_null_tracer_spans_still_measure():
    """Disabled tracing must not starve stats consumers: the span's
    ``.ms`` is measured either way; only recording toggles."""
    with NULL_TRACER.span("hub.stage") as sp:
        x = sum(range(1000))
    assert x and sp.ms >= 0.0
    assert NULL_TRACER.begin_device("wave.prefill") is None
    NULL_TRACER.end_device(None)               # no-op by contract
    assert NULL_TRACER.records() == []


# -- propagation: park -> stage -> commit -> serve ---------------------------


def test_trace_id_propagates_through_hub_lifecycle(tmp_path, model,
                                                   params2):
    """One trace id per request, minted at submit, visible in the hub's
    park/stage/commit records, the engine's device spans and the finish
    event — the full cold-start chain of the acceptance criterion."""
    store = str(tmp_path / "store")
    hub = ExpertHub(model, n_slots=1, max_len=32, store=store)
    for i, p in enumerate(params2):
        hub.add_expert(f"ex{i}", p, cold=True)
    tracer = Tracer()
    srv = RoutedServer(None, hub.build_registry(), max_batch=4, hub=hub,
                       tracer=tracer)
    rng = np.random.default_rng(3)
    reqs = _reqs(rng, 6, n_experts=2)
    resps = srv.serve(reqs)
    assert len(resps) == 6
    assert srv.scheduler.stats.resident_stalls >= 1   # cold start parked

    recs = tracer.records()
    submits = _by(recs, "request.submit")
    trace_of = {r["args"]["uid"]: r["args"]["trace"] for r in submits}
    assert sorted(trace_of) == list(range(6))
    assert len(set(trace_of.values())) == 6 and 0 not in trace_of.values()

    parked = {t for r in _by(recs, "hub.park") for t in r["args"]["traces"]}
    assert parked and parked <= set(trace_of.values())
    assert _by(recs, "hub.stage"), "cold staging left no stage span"
    assert all(r["ph"] == "X" and r["dur"] > 0
               for r in _by(recs, "hub.stage"))
    commits = _by(recs, "hub.commit")
    assert commits and all(r["cat"] == "enqueue" for r in commits)

    waved = {t for r in _by(recs, "wave.prefill")
             for t in r["args"]["traces"]}
    finishes = _by(recs, "request.finish")
    assert {r["args"]["uid"] for r in finishes} == set(range(6))
    for r in finishes:
        a = r["args"]
        assert a["trace"] == trace_of[a["uid"]]
        assert a["total_ms"] >= a["queue_ms"] >= 0.0
        assert a["stalled_ms"] >= 0.0
    # at least one parked request completed the whole chain:
    # submit -> park -> (stage/commit happened) -> prefill -> finish
    assert parked & waved
    # stalled time was actually attributed to the parked rows
    stalled = {a["uid"]: a["stalled_ms"]
               for a in (r["args"] for r in finishes)}
    assert any(stalled[u] > 0.0 for u in stalled)

    assert tracer.open_device_count() == 0
    # the snapshot tree surfaces the hub's per-expert lifecycle metrics
    snap = srv.snapshot()
    ex = snap["hub"]["experts"]
    assert set(ex) == {"ex0", "ex1"}
    for row in ex.values():
        assert {"hits", "state", "pins", "misses", "stage_ms",
                "commit_ms", "resident_s"} <= set(row)
    assert any(row["stage_ms"] > 0 for row in ex.values())
    # scheduler latency histograms observed every finished request
    assert snap["scheduler"]["latency"]["queue_ms"]["count"] == 6


# -- span balance under the rollback paths -----------------------------------


def test_span_balance_under_pool_exhaustion(model, params2):
    """``PagePoolExhausted`` requeues must not leak device spans: the
    span only opens after admission succeeds, so the rollback path is
    balanced by construction — and the requeue leaves a ``kv.requeue``
    breadcrumb carrying the stalled rows' trace ids."""
    reg = ExpertRegistry()
    reg.add("ex0", ExpertEngine(model, params2[0], max_len=64,
                                kv_layout="paged", pool_pages=40))
    tracer = Tracer()
    sched = Scheduler(None, reg, config=SchedulerConfig(max_batch=4),
                      tracer=tracer)
    rng = np.random.default_rng(11)
    # 4-row waves of 33-48 token prompts own ~24 of 40 pages: wave two
    # cannot admit while wave one is resident -> the stall path fires
    reqs = [Request(uid=u, features=np.zeros(784, np.float32),
                    prompt=rng.integers(0, 100,
                                        size=int(rng.integers(33, 48))),
                    max_new_tokens=int(rng.integers(2, 7)), expert=0)
            for u in range(12)]
    sched.submit(reqs)
    out = sched.drain()
    assert len(out) == 12
    assert sched.stats.kv_stalls >= 1, \
        "tiny pool never stalled — test is vacuous"
    recs = tracer.records()
    requeues = _by(recs, "kv.requeue")
    assert requeues
    submit_traces = {r["args"]["trace"]
                     for r in _by(recs, "request.submit")}
    assert all(set(r["args"]["traces"]) <= submit_traces
               for r in requeues)
    assert tracer.open_device_count() == 0
    # every opened device span was also recorded closed
    dev = [r for r in recs if r["cat"] == "device"]
    assert len(dev) >= len(_by(recs, "wave.prefill"))
    # registry snapshot exposes the pool's exhaustion counter
    kv = sched.obs.snapshot()["kv"]["shard0"]
    assert kv["exhausted"] >= 1
    assert kv["page_allocs"] > kv["used"] >= 0


def test_span_balance_under_spec_fallback(model, params2):
    """The no-wrap gate's fallback (speculative wave demoted to plain
    decode) must stay balanced and leave a ``spec.fallback`` event:
    the wave's decode span opens lazily at the first tick, regardless
    of which path the gate chose."""
    eng = ExpertEngine(model, params2[0], kv_layout="paged", page_size=8,
                       speculate_k=4, draft="table", max_len=16,
                       min_len_bucket=8, batch_buckets=(1, 2))
    tracer = Tracer()
    eng.bind_tracer(tracer)
    p = np.random.default_rng(5).integers(0, 100, size=8).astype(np.int32)
    # Sb + steps = 17 > C = 16 trips the gate -> plain-decode fallback
    eng.admit([0, 1], [p, p.copy()], [10, 10])
    while eng.has_pending:
        eng.tick()
        eng.poll()
    assert eng.stats.spec_fallback_waves == 1
    assert eng.stats.verify_steps == 0
    recs = tracer.records()
    fb = _by(recs, "spec.fallback")
    assert len(fb) == 1
    assert _by(recs, "wave.decode"), "fallback wave left no decode span"
    assert not _by(recs, "wave.verify")   # gate-blocked: verify never ran
    assert tracer.open_device_count() == 0
    waves = {r["args"]["wave"] for r in _by(recs, "wave.prefill")}
    assert fb[0]["args"]["wave"] in waves


# -- zero new host blocks ----------------------------------------------------


def test_host_blocks_identical_with_tracing_on(model, params2):
    """The acceptance criterion's sync-safety half: the same traffic
    served with and without a live tracer performs exactly the same
    number of host-blocking syncs, and produces the same tokens."""
    rng = np.random.default_rng(7)
    reqs = _reqs(rng, 10, n_experts=2)

    def serve(tracer):
        reg = ExpertRegistry()
        for i, p in enumerate(params2):
            reg.add(f"ex{i}", ExpertEngine(model, p, max_len=32))
        sched = Scheduler(None, reg, tracer=tracer)
        sched.submit(reqs)
        out = {r.uid: r.tokens for r in sched.drain()}
        blocks = sum(reg[e].backend.stats.host_blocks for e in range(2))
        return out, blocks

    got_off, blocks_off = serve(None)
    tracer = Tracer()
    got_on, blocks_on = serve(tracer)
    assert blocks_on == blocks_off > 0
    for uid in got_off:
        np.testing.assert_array_equal(got_on[uid], got_off[uid],
                                      err_msg=str(uid))
    # and the trace really recorded the work it didn't perturb
    assert tracer.open_device_count() == 0
    assert len(_by(tracer.records(), "request.finish")) == 10


# -- snapshot tree stability -------------------------------------------------


def test_snapshot_tree_keys_are_stable(model, params2):
    """Downstream consumers key off this tree: pin the top-level groups
    and the per-group leaf names so drift is a reviewed change."""
    reg = ExpertRegistry()
    reg.add("ex0", ExpertEngine(model, params2[0], max_len=32,
                                kv_layout="paged", speculate_k=2,
                                draft="table"))
    sched = Scheduler(None, reg)
    rng = np.random.default_rng(0)
    sched.submit(_reqs(rng, 4, n_experts=1, lo=3, hi=12))
    sched.drain()
    snap = sched.obs.snapshot()
    assert sorted(snap) == ["engines", "executor", "kv", "scheduler"]
    stats_keys = set(SchedulerStats().as_dict())
    assert set(snap["scheduler"]) == stats_keys | {"latency"}
    assert snap["scheduler"]["responses"] == 4
    for h in ("queue_ms", "stalled_ms"):
        assert set(snap["scheduler"]["latency"][h]) == \
            {"count", "sum", "mean", "p50", "p95", "p99", "max"}
    assert snap["scheduler"]["latency"]["queue_ms"]["count"] == 4
    eng = snap["engines"]["shard0"]
    assert {"host_blocks", "decode_steps", "spec_fallback_waves"} <= \
        set(eng)
    assert eng["draft"] == {"name": "table", "kind": "BigramTableDraft"}
    assert set(snap["kv"]["shard0"]) == {"free", "used", "page_allocs",
                                         "page_releases", "exhausted"}
    assert snap["executor"]["name"] in ("serial", "overlapped")
    # the frozen stats snapshot a caller holds does not mutate under it
    held = sched.stats
    sched.submit(_reqs(rng, 2, n_experts=1, lo=3, hi=12))
    sched.drain()
    assert held.responses == 4 and sched.stats.responses == 6
    with pytest.raises(AttributeError):
        held.responses = 0


# -- the static gate: planted O001-O003 violations ---------------------------


def test_obs_lint_catches_tracer_call_in_jitted_fn():
    src = textwrap.dedent("""
        import jax

        @jax.jit
        def step(x, tracer):
            tracer.event("tick")     # fires at trace time only
            return x + 1
    """)
    vs = obs_lint.lint_source(src, "src/repro/serve/planted.py")
    assert any(v.rule == "O001" for v in vs), vs


def test_obs_lint_allows_host_side_tracing():
    src = textwrap.dedent("""
        import jax

        @jax.jit
        def step(x):
            return x + 1

        def drive(x, tracer):
            tracer.event("tick")
            y = step(x)
            return jax.device_get(y)
    """)
    assert not obs_lint.lint_source(src, "src/repro/serve/planted.py")


def test_obs_lint_catches_span_timing_enqueue():
    src = textwrap.dedent("""
        import jax.numpy as jnp

        def run(self, a, b):
            with self.tracer.span("wave"):
                y = jnp.dot(a, b)    # async dispatch: span sees enqueue
            return y
    """)
    vs = obs_lint.lint_source(src, "src/repro/serve/planted.py")
    assert any(v.rule == "O002" for v in vs), vs


def test_obs_lint_blesses_synced_span_and_enqueue_span():
    src = textwrap.dedent("""
        import jax.numpy as jnp
        import numpy as np

        def run_synced(self, a, b):
            with self.tracer.span("wave"):
                y = np.asarray(jnp.dot(a, b))   # sync inside the span
            return y

        def run_enqueue(self, a, b):
            # declared enqueue semantics: exempt by name
            with self.tracer.enqueue_span("hub.commit"):
                y = jnp.dot(a, b)
            return y
    """)
    assert not obs_lint.lint_source(src, "src/repro/serve/planted.py")


def test_obs_lint_catches_end_device_outside_sync_site():
    src = textwrap.dedent("""
        def harvest(self, w):
            self.tracer.end_device(w.sp_decode)   # work not done yet
            return w
    """)
    vs = obs_lint.lint_source(src, "src/repro/serve/planted.py")
    assert any(v.rule == "O002" for v in vs), vs


def test_obs_lint_blesses_end_device_at_sync_site():
    src = textwrap.dedent("""
        import jax

        def materialize(self, w):
            out = jax.device_get(w.tok)
            self.tracer.end_device(w.sp_decode)
            return out
    """)
    assert not obs_lint.lint_source(src, "src/repro/serve/planted.py")


def test_obs_lint_catches_computed_histogram_buckets():
    src = textwrap.dedent("""
        from repro.obs import Histogram

        def build(n):
            return Histogram(buckets=[10.0 ** i for i in range(n)])
    """)
    vs = obs_lint.lint_source(src, "src/repro/serve/planted.py")
    assert any(v.rule == "O003" for v in vs), vs


def test_obs_lint_blesses_literal_and_constant_buckets():
    src = textwrap.dedent("""
        from repro.obs import DEFAULT_MS_BUCKETS, Histogram

        LOCAL_BUCKETS = (1.0, 10.0, 100.0)

        def build():
            a = Histogram()                          # library default
            b = Histogram(buckets=(0.5, 5.0, 50.0))  # inline literal
            c = Histogram(buckets=DEFAULT_MS_BUCKETS)
            d = Histogram(LOCAL_BUCKETS)             # module literal
            return a, b, c, d
    """)
    assert not obs_lint.lint_source(src, "src/repro/serve/planted.py")


def test_repo_is_obs_clean():
    """The gate holds over the real tree (same entry the CI runs)."""
    assert obs_lint.run() == []
