"""Differential verification suite for speculative decoding.

The engine's speculative path (``EngineCore._verify_fn`` + the draft
models in ``serve.draft``) claims *bitwise* token identity with plain
one-token-per-tick greedy decode: the target expert scores the whole
draft window in one parallel causal pass, accepts the matched greedy
prefix, and rolls the rejected suffix back out of the KV cache. This
suite is the proof:

  * an identity grid over kv layout (ring/paged), placement
    (per-engine/banked) and ``k`` in {1, 2, 4, 8}, asserting exact
    token equality against a plain reference engine — including the
    ``k=1`` degenerate ladder and mixed per-row ``max_new`` (rows
    freeze at their caps mid-wave);
  * the adversarial ``always-wrong`` draft: zero acceptance, yet every
    verify still advances each active row by exactly one (corrected)
    token, so the wave terminates in ``max(max_new) - 1`` verifies;
  * page accounting: a retired speculative wave returns the pool to
    baseline (modulo prefix-cache pins, which evict cleanly); the
    wrap/COW geometry is gate-blocked onto the plain decode path and
    stays token-identical; a ``PagePoolExhausted`` admission rolls
    back transactionally;
  * executable budgets: ``executable_bounds()`` grows exactly one
    ``verify`` family, post-warmup compile counts are asserted exactly
    (under ``COMPILE_COUNTER_EXACT``), and the L006 lint extension
    blesses only bucket-derived ``_verify_fn`` shape arguments.

Property-style grids sample through ``tests/_prop.py`` (see its module
docstring): the container has no ``hypothesis``, so grids are fixed
and seeded — fully deterministic under CI.
"""
import textwrap

import jax
import numpy as np
import pytest

from repro.analysis import lint
from repro.configs import get_config
from repro.models import build_model
from repro.serve import (BankedEngine, ExpertEngine, PagePoolExhausted)
from repro.serve.core import COMPILE_COUNTER_EXACT

MAX_LEN = 32


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("smollm-135m").reduced(name="spec-diff")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(7))


def _mk_engine(tiny, **kw):
    model, params = tiny
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("min_len_bucket", 8)
    kw.setdefault("batch_buckets", (1, 2, 4))
    return ExpertEngine(model, params, **kw)


def _wave_a():
    """3 rows (pads to Bb=4), prompts <= 8 (Sb=8), mixed per-row caps.
    Gate: 8 + 6 + k <= 32 for every k <= 8 — all grid cells speculate."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, 100, size=n).astype(np.int32)
               for n in (5, 8, 6)]
    return prompts, [6, 4, 7]


def _run(engine, prompts, max_new, uid0=0):
    """Admit one wave on an ExpertEngine and drain it to {uid: tokens}."""
    uids = list(range(uid0, uid0 + len(prompts)))
    engine.admit(uids, list(prompts), list(max_new))
    out = {}
    while engine.has_pending:
        engine.tick()
        for uid, seq in engine.poll():
            out[uid] = seq
    return out


def _run_banked(engine, groups):
    engine.admit(groups)
    out = {}
    while engine.has_pending:
        engine.tick()
        for local, uid, seq in engine.poll():
            out[(local, uid)] = seq
    return out


@pytest.fixture(scope="module")
def plain_engine(tiny):
    """The one-token-per-tick reference every grid cell diffs against."""
    return _mk_engine(tiny)


@pytest.fixture(scope="module")
def ref_tokens(plain_engine):
    prompts, max_new = _wave_a()
    return _run(plain_engine, prompts, max_new)


# -- identity grid -----------------------------------------------------------


@pytest.mark.parametrize("kv,k", [
    ("ring", 1), ("ring", 2), ("ring", 4), ("ring", 8),
    ("paged", 2), ("paged", 4),
])
def test_speculative_identity_per_engine(tiny, ref_tokens, kv, k):
    """Every (layout, k) cell emits bitwise the reference tokens —
    including k=1, the degenerate one-draft ladder."""
    eng = _mk_engine(tiny, kv_layout=kv, speculate_k=k, draft="table")
    prompts, max_new = _wave_a()
    got = _run(eng, prompts, max_new)
    for uid, seq in ref_tokens.items():
        np.testing.assert_array_equal(got[uid], seq)
    assert eng.stats.verify_steps > 0
    assert eng.stats.spec_fallback_waves == 0
    assert eng.stats.decode_steps == eng.stats.verify_steps


def test_speculative_identity_across_waves(tiny, plain_engine):
    """An online draft keeps learning across waves; identity must hold
    on every wave shape it meets (Bb=2 then Bb=1, fresh length mix)."""
    spec = _mk_engine(tiny, speculate_k=2, draft="table")
    rng = np.random.default_rng(23)
    for uid0, caps in ((0, [5, 5]), (10, [6])):
        prompts = [rng.integers(0, 100,
                                size=int(rng.integers(3, 9))).astype(np.int32)
                   for _ in caps]
        want = _run(plain_engine, prompts, caps, uid0=uid0)
        got = _run(spec, prompts, caps, uid0=uid0)
        for uid, seq in want.items():
            np.testing.assert_array_equal(got[uid], seq)
    assert spec.stats.verify_steps > 0


@pytest.fixture(scope="module")
def banked_params(tiny):
    model, params = tiny
    return [params, model.init(jax.random.PRNGKey(8))]


def _banked_waves():
    rng = np.random.default_rng(3)
    g = lambda ns: [rng.integers(0, 100, size=n).astype(np.int32)
                    for n in ns]
    return {0: ([0, 1, 2], g((5, 8, 6)), [6, 4, 7]),
            1: ([3, 4], g((7, 4)), [5, 6])}


@pytest.fixture(scope="module")
def banked_ref(tiny, banked_params):
    model, _ = tiny
    eng = BankedEngine(model, banked_params, max_len=MAX_LEN,
                       min_len_bucket=8, batch_buckets=(1, 2, 4))
    return _run_banked(eng, _banked_waves())


@pytest.mark.parametrize("kv,k", [("ring", 2), ("paged", 4)])
def test_speculative_identity_banked(tiny, banked_params, banked_ref,
                                     kv, k):
    """Banked (E=2) speculation: one verify dispatch serves both
    experts' micro-batches and each expert's rows match its own plain
    reference. Uses the static MLP draft so all three draft models are
    exercised somewhere in the grid."""
    model, _ = tiny
    eng = BankedEngine(model, banked_params, max_len=MAX_LEN,
                       min_len_bucket=8, batch_buckets=(1, 2, 4),
                       kv_layout=kv, speculate_k=k, draft="mlp")
    got = _run_banked(eng, _banked_waves())
    for key, seq in banked_ref.items():
        np.testing.assert_array_equal(got[key], seq)
    assert eng.stats.verify_steps > 0
    assert eng.stats.spec_fallback_waves == 0


# -- adversarial draft: progress guarantee -----------------------------------


def test_always_wrong_draft_progress_guarantee(tiny, ref_tokens):
    """A draft that never matches accepts nothing — yet each verify
    emits the corrected greedy token, so rows advance exactly one per
    verify and the wave needs exactly max(max_new) - 1 verifies (the
    first token comes from prefill)."""
    eng = _mk_engine(tiny, speculate_k=2, draft="always-wrong")
    prompts, max_new = _wave_a()
    got = _run(eng, prompts, max_new)
    for uid, seq in ref_tokens.items():
        np.testing.assert_array_equal(got[uid], seq)
    st = eng.stats
    assert st.tokens_accepted == 0
    assert st.acceptance_rate == 0.0
    assert st.tokens_drafted > 0
    assert st.verify_steps == max(max_new) - 1


# -- page accounting ---------------------------------------------------------


def _evict_all(core):
    for e in range(core.pool.n_experts):
        core.prefix_cache.evict_for(e, core.pool.n_pages)


def test_spec_wave_pages_return_to_baseline(tiny):
    """After a speculative wave retires, the only live pool references
    belong to the prefix cache (registered prompt pages); evicting them
    restores the exact pre-admission counters. Optimistically-written
    then rejected suffix slots never show up as leaked pages — they
    live inside wave-owned decode pages released at retire."""
    eng = _mk_engine(tiny, kv_layout="paged", page_size=8,
                     speculate_k=2, draft="table")
    pool = eng.core.pool
    base = dict(pool.counters())
    prompts, max_new = _wave_a()
    _run(eng, prompts, max_new)
    assert eng.core.n_active == 0
    cache_pins = sum(1 for key in eng.core.prefix_cache._lru
                     if key[0] == "pg")
    assert pool.counters()["used"] == cache_pins
    _evict_all(eng.core)
    assert pool.counters() == base
    pool.check()


def test_spec_wrap_cow_wave_falls_back_identically(tiny):
    """The wrap geometry (decode overwrites prompt pages mid-page,
    COW-remapping shared ones) is exactly what the no-wrap gate keeps
    away from the verify path: the wave must fall back to plain decode,
    stay token-identical, and still settle its pages."""
    model, params = tiny
    mk = dict(max_len=16, min_len_bucket=8, batch_buckets=(1, 2))
    spec = ExpertEngine(model, params, kv_layout="paged", page_size=8,
                        speculate_k=4, draft="table", **mk)
    plain = ExpertEngine(model, params, **mk)
    p = np.random.default_rng(5).integers(0, 100, size=8).astype(np.int32)
    prompts, max_new = [p, p.copy()], [10, 10]   # Sb+steps = 17 > C=16
    want = _run(plain, prompts, max_new)
    base = dict(spec.core.pool.counters())
    got = _run(spec, prompts, max_new)
    for uid, seq in want.items():
        np.testing.assert_array_equal(got[uid], seq)
    st = spec.stats
    assert st.spec_fallback_waves == 1
    assert st.verify_steps == 0          # gate-blocked: no verify ran
    assert st.pages_copied > 0           # the dup row COW'd its wrap page
    # wrapping waves never register prefixes, so baseline needs no evict
    assert spec.core.pool.counters() == base
    spec.core.pool.check()


def test_spec_admission_pool_exhausted_rolls_back(tiny):
    """An admission that outgrows the pool raises PagePoolExhausted with
    *zero* net page movement — the transactional ledger unwinds every
    reference the partial plan took — and the identical admission
    succeeds once the resident wave retires."""
    eng = _mk_engine(tiny, kv_layout="paged", page_size=8, pool_pages=8,
                     speculate_k=2, draft="table")
    pool = eng.core.pool
    rng = np.random.default_rng(9)
    caps = [6, 4, 7]
    mk_prompts = lambda lo: [rng.integers(lo, lo + 90,
                                          size=n).astype(np.int32)
                             for n in (5, 8, 6)]
    prompts1, prompts2 = mk_prompts(0), mk_prompts(100)
    eng.admit([0, 1, 2], prompts1, caps)    # resident: 6 of 8 pages
    before = dict(pool.counters())
    with pytest.raises(PagePoolExhausted):
        eng.admit([10, 11, 12], prompts2, caps)
    assert pool.counters() == before
    pool.check()
    while eng.has_pending:                   # retire wave 1
        eng.tick()
        eng.poll()
    _evict_all(eng.core)
    got = _run(eng, prompts2, caps, uid0=10)
    assert sorted(got) == [10, 11, 12]
    assert all(len(got[10 + i]) == caps[i] for i in range(3))


# -- executable budgets ------------------------------------------------------


def test_executable_bounds_verify_family(tiny):
    spec = _mk_engine(tiny, speculate_k=2, draft="table")
    bounds = spec.core.executable_bounds()
    assert bounds["verify"] == len(spec.batch_buckets)
    plain = _mk_engine(tiny)
    assert plain.core.executable_bounds()["verify"] == 0


@pytest.mark.skipif(not COMPILE_COUNTER_EXACT,
                    reason="needs the pjit _cache_size probe")
def test_spec_compile_counts_exact(tiny):
    """Exact post-warmup executable census: a speculative wave mints
    one prefill and one verify executable — no decode — and repeat
    traffic at the same shape mints nothing. A gate-blocked wave then
    mints exactly the fallback decode executable."""
    eng = _mk_engine(tiny, speculate_k=2, draft="table")
    prompts, max_new = _wave_a()
    _run(eng, prompts, max_new)
    st = eng.stats
    assert (st.prefill_compiles, st.decode_compiles,
            st.verify_compiles) == (1, 0, 1)
    assert st.jit_cache_entries == 2
    _run(eng, [p + 1 for p in prompts], max_new, uid0=50)
    assert st.jit_cache_entries == 2
    # steps = 31: 8 + 31 + 2 > 32 trips the no-wrap gate -> plain decode
    _run(eng, prompts, [MAX_LEN] * 3, uid0=90)
    assert st.spec_fallback_waves == 1
    assert (st.decode_compiles, st.verify_compiles) == (1, 1)
    assert st.jit_cache_entries == 3


def test_lint_blesses_only_bucket_derived_verify_shapes():
    """L006 extension: ``_verify_fn``'s shape argument must be the
    engine-fixed ``speculate_k`` (or another bucket-ladder value); a k
    read off per-request data keys unbounded executables."""
    blessed = textwrap.dedent("""
        def tick(self, w, Bb):
            out = self._verify_fn(Bb, self.speculate_k)(self.params, w)
            return out
    """)
    assert not [v for v in lint.lint_source(
        blessed, "src/repro/serve/planted.py") if v.rule == "L006"]
    planted = textwrap.dedent("""
        def tick(self, w, req):
            k = req.draft_tokens.shape[0]
            out = self._verify_fn(4, k)(self.params, w)
            return out
    """)
    vs = lint.lint_source(planted, "src/repro/serve/planted.py")
    assert any(v.rule == "L006" for v in vs), vs
