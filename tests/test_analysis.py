"""repro.analysis contract-checker tests.

Two directions per pass: the repo itself must be clean (modulo the
justified ``baseline.toml`` entries), and a *planted* violation of each
class must be caught — a checker that never fires is indistinguishable
from one that works.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.analysis import (REPO_ROOT, Violation, apply_baseline,
                            format_report, load_baseline)
from repro.analysis import lint, pallas_check


# -- lint: repo is clean -----------------------------------------------------


def test_lint_repo_clean_under_baseline():
    active, suppressed = apply_baseline(lint.run(), load_baseline())
    errors = [v for v in active if v.severity == "error"]
    assert not errors, "\n" + format_report(errors)
    # the baseline must not rot: every stanza still matches a finding
    assert len(suppressed) == len(load_baseline()), (
        "stale baseline.toml stanza (suppresses nothing) — delete it")


# -- lint: planted violations ------------------------------------------------


def test_lint_catches_host_sync_in_jitted_fn():
    src = textwrap.dedent("""
        import jax

        @jax.jit
        def f(x):
            n = int(x.sum())        # host sync on a tracer
            return x * n
    """)
    vs = lint.lint_source(src, "src/repro/serve/planted.py")
    assert any(v.rule == "L001" for v in vs), vs


def test_lint_catches_tracer_branch():
    src = textwrap.dedent("""
        import jax

        @jax.jit
        def f(x):
            if x.sum() > 0:         # python branch on a device value
                return x
            return -x
    """)
    vs = lint.lint_source(src, "src/repro/serve/planted.py")
    assert any(v.rule == "L002" for v in vs), vs


def test_lint_catches_private_cache_size_use():
    src = textwrap.dedent("""
        def count(fn):
            return fn._cache_size()
    """)
    vs = lint.lint_source(src, "src/repro/launch/planted.py")
    assert any(v.rule == "L003" for v in vs), vs
    # ...but the guarded helper's home file is allowed to touch it
    assert not lint.lint_source(src, "src/repro/serve/core.py")


def test_lint_catches_unsynced_device_timing():
    src = textwrap.dedent("""
        import time
        import jax.numpy as jnp

        def bench(x):
            t0 = time.perf_counter()
            y = jnp.dot(x, x)       # enqueued, not executed
            return time.perf_counter() - t0, y
    """)
    vs = lint.lint_source(src, "benchmarks/planted.py")
    assert any(v.rule == "L004" for v in vs), vs


def test_lint_synced_timing_passes():
    src = textwrap.dedent("""
        import time
        import jax
        import jax.numpy as jnp

        def bench(x):
            t0 = time.perf_counter()
            y = jax.block_until_ready(jnp.dot(x, x))
            return time.perf_counter() - t0, y
    """)
    assert not lint.lint_source(src, "benchmarks/planted.py")


def test_lint_catches_lifecycle_leak():
    src = textwrap.dedent("""
        def admit(pool, local, stage):
            pages = pool.alloc(local, 4)
            stage(pages)            # can raise: pages leak
            return pages
    """)
    vs = lint.lint_source(src, "src/repro/serve/scheduler.py")
    assert any(v.rule == "L005" for v in vs), vs


def test_lint_lifecycle_release_in_finally_passes():
    src = textwrap.dedent("""
        def admit(pool, local, stage):
            pages = pool.alloc(local, 4)
            try:
                stage(pages)
            finally:
                pool.release(local, pages)
    """)
    assert not lint.lint_source(src, "src/repro/serve/scheduler.py")


def test_lint_catches_unbucketed_prefill_shape():
    """L006: a prefill/suffix dispatch keyed on a raw traffic shape
    mints executables per prompt length — the bucket bound is void."""
    src = textwrap.dedent("""
        def admit(self, toks):
            S = toks.shape[1]
            logits = self._prefill_fn(2, S)(self.params, toks)
            return logits
    """)
    vs = lint.lint_source(src, "src/repro/serve/planted.py")
    assert any(v.rule == "L006" for v in vs), vs
    src = textwrap.dedent("""
        def admit(self, toks):
            k = toks.shape[1] // 16
            out = self._suffix_fn(1, k)(self.params, toks)
            return out
    """)
    vs = lint.lint_source(src, "src/repro/serve/planted.py")
    assert any(v.rule == "L006" for v in vs), vs


def test_lint_bucket_derived_prefill_shapes_pass():
    """Lengths derived from the bucket/chunk geometry — bucket_for,
    chunk_len, len_buckets, chunk indices off the ladder — are the
    blessed currency and must not trip L006."""
    src = textwrap.dedent("""
        def admit(self, toks, rows):
            Bb, Sb = self.pad_shape(rows, toks.shape[1])
            logits = self._prefill_fn(Bb, Sb)(self.params, toks)
            for k in range(Sb // self.chunk_len):
                if k == 0:
                    out = self._prefill_fn(Bb, self.chunk_len)(
                        self.params, toks)
                else:
                    out = self._suffix_fn(Bb, k)(self.params, toks)
            top = self._prefill_fn(Bb, max(self.len_buckets))(
                self.params, toks)
            return logits, out, top
    """)
    vs = lint.lint_source(src, "src/repro/serve/planted.py")
    assert not [v for v in vs if v.rule == "L006"], vs


def test_lint_l006_clean_on_real_core():
    """The real engine core's dispatch sites must all derive from the
    bucket geometry (the rule was designed against them)."""
    path = os.path.join(os.path.dirname(__file__), "..", "src", "repro",
                        "serve", "core.py")
    with open(path) as f:
        src = f.read()
    vs = lint.lint_source(src, "src/repro/serve/core.py")
    assert not [v for v in vs if v.rule == "L006"], vs


# -- baseline parsing --------------------------------------------------------


def test_baseline_requires_reason(tmp_path):
    p = tmp_path / "b.toml"
    p.write_text('[[baseline]]\nrule = "L004"\nfile = "f.py"\n'
                 'func = "g"\n')
    with pytest.raises(ValueError, match="justification"):
        load_baseline(str(p))
    p.write_text('[[baseline]]\nrule = [1]\n')
    with pytest.raises(ValueError, match="unsupported"):
        load_baseline(str(p))


def test_baseline_suppression_is_keyed_not_line_based():
    v = Violation("L004", "f.py", 42, "Klass.fn", "msg")
    active, supp = apply_baseline(
        [v], [{"rule": "L004", "file": "f.py", "func": "Klass.fn",
               "reason": "r"}])
    assert not active and supp == [v]


# -- pallas: repo kernels + planted geometry bugs ----------------------------


def test_pallas_repo_kernels_have_no_errors():
    vs = pallas_check.run()
    errors = [v for v in vs if v.severity == "error"]
    assert not errors, "\n" + format_report(errors)


def _rec(grid, in_specs, in_shapes, **kw):
    defaults = dict(kernel_name="planted", path="src/repro/kernels/x.py",
                    line=1, grid=grid, in_specs=in_specs,
                    out_specs=[], scratch_shapes=[],
                    num_scalar_prefetch=0, in_shapes=in_shapes,
                    out_shapes=[], scalar_args=[])
    defaults.update(kw)
    return pallas_check.PallasCallRecord(**defaults)


class _Spec:
    def __init__(self, block_shape, index_map):
        self.block_shape = block_shape
        self.index_map = index_map


def test_pallas_catches_out_of_bounds_index_map():
    # grid (4,) over a (4, 64) operand in (1, 64) blocks, but the map
    # is off by one: the last grid point reads row 4 of 4
    rec = _rec(grid=(4,),
               in_specs=[_Spec((1, 64), lambda i: (i + 1, 0))],
               in_shapes=[((4, 64), np.float32)])
    vs = pallas_check.check_record(rec, "planted")
    assert any(v.rule == "P002" for v in vs), vs


def test_pallas_catches_nondividing_block():
    rec = _rec(grid=(2,),
               in_specs=[_Spec((10, 64), lambda i: (i, 0))],
               in_shapes=[((32, 64), np.float32)])
    vs = pallas_check.check_record(rec, "planted")
    assert any(v.rule == "P001" for v in vs), vs


def test_pallas_capture_sees_real_kernel_geometry():
    import jax
    import jax.numpy as jnp
    from repro.kernels.wkv_step import wkv_step_pallas
    B, H, P = 2, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    args = [jax.random.normal(k, (B, H, P)) for k in ks[:4]]
    u = jax.random.normal(ks[4], (H, P))
    S = jax.random.normal(ks[5], (B, H, P, P))
    with pallas_check.capture_pallas_calls() as recs:
        wkv_step_pallas(*args, u, S)
    assert len(recs) == 1
    assert recs[0].grid == (B, H)
    assert not [v for v in pallas_check.check_record(recs[0], "t")
                if v.severity == "error"]


# -- hlo: donation / callback checks (single-device, in-process) -------------


def test_hlo_donation_check_passes_on_real_donation():
    import jax
    import jax.numpy as jnp
    from repro.analysis.hlo_contracts import check_donation
    f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    x = jnp.zeros((128,), jnp.float32)
    assert check_donation(f, (x,), (0,), "ok") == []


def test_hlo_donation_check_catches_dropped_donation():
    import jax
    import jax.numpy as jnp
    from repro.analysis.hlo_contracts import check_donation
    # output dtype is narrower than the donated input: XLA cannot
    # reuse the buffer and silently drops the donation (warning only)
    f = jax.jit(lambda x: (x + 1).astype(jnp.bfloat16),
                donate_argnums=(0,))
    x = jnp.zeros((128,), jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        vs = check_donation(f, (x,), (0,), "planted")
    assert vs and vs[0].rule == "H001", vs


def test_hlo_clean_decode_flags_host_callback():
    import jax
    import jax.numpy as jnp
    from repro.analysis.hlo_contracts import check_clean_decode

    def noisy(x):
        jax.debug.print("x = {}", x.sum())
        return x * 2

    x = jnp.zeros((8,), jnp.float32)
    hlo = jax.jit(noisy).lower(x).compile().as_text()
    assert any(v.rule == "H002"
               for v in check_clean_decode(hlo, "planted"))
    clean = jax.jit(lambda x: x * 2).lower(x).compile().as_text()
    assert not check_clean_decode(clean, "clean")


# -- hlo: the full contract gate on a forced 8-device mesh -------------------


def test_hlo_contract_gate_clean_on_forced_mesh():
    """The real thing: every serving dispatch lowered on 8 forced CPU
    devices, H001-H004 asserted. Runs in a subprocess so the forced
    device count cannot leak into this process's jax runtime."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO_ROOT, "src"),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "hlo",
         "--fail-on-violation"],
        env=env, cwd=REPO_ROOT, capture_output=True, text=True,
        timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_all_passes_with_fail_gate():
    """`python -m repro.analysis --all --fail-on-violation` exits 0 on
    the repo: lint + pallas in-process, hlo re-exec'd onto the forced
    mesh, baseline applied — the exact command the CI analysis job
    runs."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO_ROOT, "src"),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--all",
         "--fail-on-violation"],
        env=env, cwd=REPO_ROOT, capture_output=True, text=True,
        timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "baselined" in proc.stdout
