"""Chunked suffix prefill + prefill/decode disaggregation tests:
token identity of the chunk ladder against ring and monolithic paged
serving on the traffic grids, whale/short interleaving under the
per-step prefill token budget, partial-prefix suffix savings strictly
below the storage-only baseline, exhaustion backpressure that never
disturbs a partially-chunked resident wave, exact executable-count
bounds for the chunk ladder, and config validation."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ExpertRegistry, build_matcher, train_bank
from repro.data import load_benchmark
from repro.models import build_model
from repro.serve import (ExpertEngine, PagePoolExhausted, Request,
                         RoutedServer)
from repro.serve.core import EngineCore


# -- config validation ------------------------------------------------------


def test_chunk_len_validation_errors():
    cfg = get_config("smollm-135m").reduced(name="chunk-val")
    model = build_model(cfg)
    with pytest.raises(ValueError, match="requires kv_layout='paged'"):
        ExpertEngine(model, None, max_len=64, kv_layout="ring",
                     chunk_len=16)
    with pytest.raises(ValueError, match="multiple of page_size"):
        ExpertEngine(model, None, max_len=64, kv_layout="paged",
                     chunk_len=12)
    with pytest.raises(ValueError, match="multiple of chunk_len"):
        ExpertEngine(model, None, max_len=64, kv_layout="paged",
                     chunk_len=40)
    with pytest.raises(ValueError, match="itself be a length bucket"):
        ExpertEngine(model, None, max_len=96, kv_layout="paged",
                     chunk_len=24)
    # a length bucket above chunk_len that is not a chunk multiple
    # cannot tile into whole chunks
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="multiples of chunk_len"):
        EngineCore(model, [params], max_len=48,
                   len_buckets=(16, 24, 48), kv_layout="paged",
                   chunk_len=16)


# -- fixtures ---------------------------------------------------------------


@pytest.fixture(scope="module")
def bench():
    return load_benchmark(names=["mnist", "har"], n_per_dataset=300,
                          seed=0)


@pytest.fixture(scope="module")
def matcher(bench):
    names = list(bench)
    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=8, batch_size=64)
    cents = [(bench[n]["server"][0], bench[n]["server"][1])
             for n in names]
    return build_matcher(aes, names, cents), names


@pytest.fixture(scope="module")
def shared_model():
    cfg = get_config("smollm-135m").reduced(name="chunk-t")
    model = build_model(cfg)
    params = [model.init(jax.random.PRNGKey(s)) for s in (0, 1)]
    return model, params


def _server(matcher, shared_model, kv, chunk_len=None, budget=0, **kw):
    m, names = matcher
    model, params = shared_model
    reg = ExpertRegistry()
    for n, p in zip(names, params):
        reg.add(n, ExpertEngine(model, p, max_len=64, kv_layout=kv,
                                chunk_len=chunk_len, **kw))
    return RoutedServer(m, reg, max_batch=4,
                        prefill_tokens_per_step=budget), reg


# -- token identity ---------------------------------------------------------


def test_chunked_token_identical_on_traffic_grids(matcher, bench,
                                                  shared_model):
    """The acceptance criterion: chunked suffix prefill (whale prompts
    split into chunk_len dispatches, interleaved with decode under a
    16-token/step budget) must be token-identical to the ring path on
    uniform / skewed / bursty traffic with mixed prompt lengths."""
    srv_r, _ = _server(matcher, shared_model, "ring")
    srv_c, reg_c = _server(matcher, shared_model, "paged",
                           chunk_len=16, budget=16)
    m, names = matcher
    uid0 = 0
    for scenario in ("uniform", "skewed", "bursty"):
        rng = np.random.default_rng(0xC0 + uid0)
        reqs = []
        for k in range(9):
            if scenario == "skewed":
                e = 0 if rng.random() < 0.8 else 1
            else:
                e = int(rng.integers(2))
            x, _ = bench[names[e]]["client_a"]
            reqs.append(Request(
                uid=uid0 + k, features=x[(uid0 + k) % 60],
                prompt=rng.integers(0, 100, size=int(rng.integers(1, 61))),
                max_new_tokens=int(rng.integers(1, 7))))
        uid0 += 9
        if scenario == "bursty":
            got_r = srv_r.serve(reqs)
            got_c = srv_c.serve(reqs)
        else:
            got_r, got_c = [], []
            for lo in range(0, len(reqs), 3):
                got_r += srv_r.serve(reqs[lo:lo + 3])
                got_c += srv_c.serve(reqs[lo:lo + 3])
        for a, b in zip(got_r, got_c):
            assert a.uid == b.uid and a.expert == b.expert, scenario
            np.testing.assert_array_equal(a.tokens, b.tokens,
                                          err_msg=f"{scenario}/{a.uid}")
        for e in range(2):
            reg_c[e].backend.core.pool.check()
    # whales actually went through the ladder (suffix executables live)
    assert sum(reg_c[e].backend.stats.suffix_compiles
               for e in range(2)) > 0


def test_whale_prefill_interleaves_with_decode(shared_model):
    """Disaggregation: while a whale's chunks are still pending under a
    one-chunk budget, a co-resident short wave must keep decoding (the
    whale wave is not decode-eligible until its last chunk lands), and
    every row must match the ring reference."""
    model, params = shared_model
    eng = ExpertEngine(model, params[0], max_len=64, kv_layout="paged",
                       chunk_len=16)
    ref = ExpertEngine(model, params[0], max_len=64, kv_layout="ring")
    rng = np.random.default_rng(21)
    shorts = [rng.integers(0, 100, size=10) for _ in range(2)]
    whale = rng.integers(0, 100, size=60)      # Sb = 64 -> 4 chunks
    eng.admit([0, 1], shorts, [8, 8], defer=True)
    eng.core.prefill_step(0)                   # shorts: Sb=16, one chunk
    assert not eng.core.has_pending_chunks
    eng.admit([9], [whale], [4], defer=True)
    assert eng.core.has_pending_chunks
    overlap = 0
    while eng.core.has_pending_chunks:
        advanced = eng.tick(defer=True)        # whale wave is gated out
        overlap += advanced
        eng.core.prefill_step(budget=1)        # exactly one chunk/step
        eng.harvest()
    assert overlap >= 2, "short wave never decoded while whale prefilled"
    while eng.n_active:
        eng.tick(defer=True)
        eng.harvest()
    got = dict(eng.poll())
    ref.admit([0, 1], shorts, [8, 8])
    ref.admit([9], [whale], [4])
    while ref.n_active:
        ref.tick()
    want = dict(ref.poll())
    assert set(got) == {0, 1, 9}
    for u in got:
        np.testing.assert_array_equal(got[u], want[u], err_msg=str(u))
    eng.core.pool.check()


def test_partial_prefix_suffix_savings_beats_storage_only(shared_model):
    """A cohort whale sharing a cached 32-token head must compute
    strictly fewer prefill tokens through the chunk ladder (head chunks
    are skipped, only the uncached suffix runs) than the storage-only
    paged baseline, which adopts the pages but recomputes every row in
    full — token-identically to ring."""
    model, params = shared_model
    # max_len=128 headroom: Sb=64 whales never wrap, so the head pages
    # survive in the prefix cache for the second whale to adopt
    mk = lambda cl: ExpertEngine(model, params[0], max_len=128,
                                 kv_layout="paged", chunk_len=cl)
    chunked, storage = mk(32), mk(None)
    ring = ExpertEngine(model, params[0], max_len=128, kv_layout="ring")
    rng = np.random.default_rng(33)
    head = rng.integers(0, 100, size=32)
    whales = [np.concatenate([head, rng.integers(0, 100, size=24)])
              for _ in range(2)]
    got = {}
    for name, eng in (("chunked", chunked), ("storage", storage),
                      ("ring", ring)):
        toks = {}
        for uid, w in enumerate(whales):   # sequential: cache populates
            eng.admit([uid], [w], [4])
            while eng.n_active:
                eng.tick()
            toks.update(dict(eng.poll()))
        got[name] = toks
    for u in (0, 1):
        np.testing.assert_array_equal(got["chunked"][u], got["ring"][u])
        np.testing.assert_array_equal(got["storage"][u], got["ring"][u])
    # whale 2: chunked computes only the 32-token suffix chunk; the
    # storage-only engine re-runs the full 64-token bucket
    assert chunked.stats.prefill_tokens_computed < \
        storage.stats.prefill_tokens_computed, \
        (chunked.stats, storage.stats)
    assert chunked.stats.prefix_pages_shared > 0
    chunked.core.pool.check()


# -- exhaustion while a wave is mid-chunk -----------------------------------


def test_exhaustion_preserves_partially_chunked_wave(shared_model):
    """Regression (the requeue-at-front fix): an admission that exhausts
    the pool while a resident wave still has pending prefill chunks
    must roll back without touching the partial wave's already-written
    pages — the wave finishes its remaining chunks and decodes to
    ring-identical tokens, and the retried admission then succeeds."""
    model, params = shared_model
    # Sb=64 whale: 8 prompt pages + 1 decode page = 9; a 12-page pool
    # hosts one whale but not two
    eng = ExpertEngine(model, params[0], max_len=128, kv_layout="paged",
                       chunk_len=32, pool_pages=12)
    ref = ExpertEngine(model, params[0], max_len=128, kv_layout="ring")
    rng = np.random.default_rng(44)
    w1 = rng.integers(0, 100, size=60)
    w2 = rng.integers(0, 100, size=60)
    eng.admit([0], [w1], [4], defer=True)
    assert eng.core.has_pending_chunks
    eng.core.prefill_step(budget=1)            # dispatch chunk 0 only
    assert eng.core.has_pending_chunks, "whale already fully prefilled"
    used = eng.core.pool.used_count(0)
    c = eng.core.pool.counters()
    assert c["used"] == used and c["free"] + c["used"] == 12, c
    with pytest.raises(PagePoolExhausted):
        eng.admit([1], [w2], [4], defer=True)
    # transactional: the partial wave's pages are exactly as they were
    assert eng.core.pool.used_count(0) == used
    assert eng.core.pool.counters() == c, "rollback moved the books"
    assert eng.core.has_pending_chunks and eng.n_active == 1
    eng.core.pool.check()
    eng.core.prefill_step(0)                   # finish the whale's chunks
    while eng.n_active:
        eng.tick(defer=True)
        eng.harvest()
    got = dict(eng.poll())
    eng.admit([1], [w2], [4])                  # pool has room again
    while eng.n_active:
        eng.tick()
    got.update(dict(eng.poll()))
    for uid, w in ((0, w1), (1, w2)):
        ref.admit([uid], [w], [4])
        while ref.n_active:
            ref.tick()
    want = dict(ref.poll())
    for u in (0, 1):
        np.testing.assert_array_equal(got[u], want[u], err_msg=str(u))
    eng.core.pool.check()


def test_chunked_pool_exhaustion_requeues_cleanly(matcher, bench,
                                                  shared_model):
    """Scheduler-level: whale traffic against a one-wave pool forces
    requeues while earlier waves are still chunk-pending/decoding; the
    chunked server must stall (never corrupt resident pages) and stay
    ring-identical."""
    srv_r, _ = _server(matcher, shared_model, "ring")
    srv_c, reg_c = _server(matcher, shared_model, "paged",
                           chunk_len=16, budget=16, pool_pages=40)
    m, names = matcher
    rng = np.random.default_rng(55)
    reqs = []
    for uid in range(16):
        nm = names[uid % 2]
        x, _ = bench[nm]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[uid % 60],
            prompt=rng.integers(0, 100, size=int(rng.integers(33, 48))),
            max_new_tokens=int(rng.integers(2, 7))))
    got_r = srv_r.serve(reqs)
    got_c = srv_c.serve(reqs)
    for a, b in zip(got_r, got_c):
        np.testing.assert_array_equal(a.tokens, b.tokens,
                                      err_msg=str(a.uid))
    assert srv_c.scheduler.stats.kv_stalls >= 1, \
        "tiny pool never stalled — test is vacuous"
    for e in range(2):
        reg_c[e].backend.core.pool.check()


# -- bounded executables ----------------------------------------------------


def test_chunked_executable_bounds_exact(shared_model):
    """Driving the full (batch, length) ladder must mint exactly the
    executables ``executable_bounds`` predicts — monolithic prefills
    only up to chunk_len, one suffix executable per (batch bucket,
    chunk index) — and re-running the same traffic must mint none."""
    from repro.serve.core import COMPILE_COUNTER_EXACT
    model, params = shared_model
    eng = ExpertEngine(model, params[0], max_len=64, kv_layout="paged",
                       batch_buckets=(1, 2), chunk_len=16)
    bounds = eng.core.executable_bounds()
    assert bounds == {"prefill": 4, "suffix": 6, "decode": 2,
                      "verify": 0}
    rng = np.random.default_rng(66)

    def drive():
        uid = [0]
        for nb in (1, 2):
            for sb in (8, 16, 32, 64):
                prompts = [rng.integers(0, 100, size=sb)
                           for _ in range(nb)]
                eng.admit(list(range(uid[0], uid[0] + nb)), prompts,
                          [2] * nb)
                uid[0] += nb
                while eng.n_active:
                    eng.tick()
                eng.poll()

    drive()
    st = eng.stats
    if COMPILE_COUNTER_EXACT:
        assert st.prefill_compiles == bounds["prefill"], st
        assert st.suffix_compiles == bounds["suffix"], st
        assert st.decode_compiles == bounds["decode"], st
    entries = st.jit_cache_entries
    assert entries <= sum(bounds.values())
    drive()                     # steady state: zero recompiles
    assert eng.stats.jit_cache_entries == entries
    eng.core.pool.check()


# -- banked 8-device mesh ---------------------------------------------------


MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_expert_mesh
from repro.models import build_model
from repro.serve import BankedEngine
from repro.serve.placement import _bank_submesh

assert len(jax.devices()) == 8, jax.devices()
cfg = get_config("smollm-135m").reduced(name="chunk-mesh")
model = build_model(cfg)
params = [model.init(jax.random.PRNGKey(i)) for i in range(2)]
rng = np.random.default_rng(0)
# whales and shorts: the whale rows run the suffix ladder on the mesh
groups = {0: ([0, 1], [rng.integers(0, 50, 60), rng.integers(0, 50, 9)],
              [4, 6]),
          1: ([2], [rng.integers(0, 50, 40)], [5])}

def run(mesh, chunk):
    bank = BankedEngine(model, params, max_len=64, kv_layout="paged",
                        chunk_len=16 if chunk else None, mesh=mesh)
    bank.admit(groups, defer=True)
    while bank.core.has_pending_chunks:
        bank.core.prefill_step(16)
        bank.tick(defer=True)
        bank.harvest()
    while bank.n_active:
        bank.tick(defer=True)
        bank.harvest()
    suffix = bank.stats.suffix_compiles
    return {f"{l}/{u}": t.tolist() for l, u, t in bank.poll()}, suffix

mesh = make_expert_mesh()
sub, devs = _bank_submesh(2, mesh)
assert sub is not None and dict(sub.shape) == {"expert": 2}, sub
sharded, suffix_sharded = run(sub, True)
single, _ = run(None, False)
print(json.dumps({
    "n_devices": len(jax.devices()), "bank_devices": len(devs),
    "suffix_sharded": suffix_sharded,
    "match": sharded == single}))
"""


@pytest.mark.slow
def test_chunked_banked_mesh_matches_monolithic_single_device():
    """A 2-expert paged bank sharded over a mesh expert axis, serving
    whales through the chunk ladder, must emit the same tokens as the
    unsharded monolithic-prefill bank (GSPMD numerics for the suffix
    executables' bank sharding)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", MESH_SCRIPT], capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 8 and res["bank_devices"] == 2, res
    assert res["suffix_sharded"] > 0, res
    assert res["match"], res
