"""Distributed-execution tests: actually RUN sharded steps on 8 host
devices (subprocess; the main test process keeps 1 device). This goes
beyond the dry-run's compile-only proof: it checks GSPMD numerics equal
single-device numerics for a sharded train step and a routed bank scoring.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import compat_make_mesh
from repro.models import build_model
from repro.optim import constant_lr
from repro.sharding import mesh_context
from repro.sharding.rules import batch_spec, param_specs
from repro.train.loop import init_train_state, make_train_step

assert len(jax.devices()) == 8, jax.devices()
mesh = compat_make_mesh((4, 2), ("data", "model"))

cfg = get_config("llama3.2-1b").reduced(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256)
model = build_model(cfg)
state = init_train_state(model, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256)
batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
step = make_train_step(model, lr_fn=constant_lr(1e-3))

# single-device reference
ref_state, ref_metrics = jax.jit(step)(state, batch)
ref_loss = float(ref_metrics["loss"])

# sharded execution on the 4x2 mesh
pshapes = jax.eval_shape(lambda: state)
sspecs = {
    "params": param_specs(pshapes["params"], mesh),
    "opt": {"m": param_specs(pshapes["params"], mesh),
            "v": param_specs(pshapes["params"], mesh), "step": P()},
    "step": P(),
}
bspecs = batch_spec(jax.eval_shape(lambda: batch), mesh)
named = lambda t: jax.tree_util.tree_map(
    lambda s: NamedSharding(mesh, s), t)
with mesh_context(mesh):
    state_sh = jax.device_put(state, named(sspecs))
    batch_sh = jax.device_put(batch, named(bspecs))
    jstep = jax.jit(step, in_shardings=(named(sspecs), named(bspecs)),
                    out_shardings=(named(sspecs), None))
    new_state, metrics = jstep(state_sh, batch_sh)
sh_loss = float(metrics["loss"])

# param agreement after one step
ref_leaves = jax.tree_util.tree_leaves(ref_state["params"])
sh_leaves = jax.tree_util.tree_leaves(jax.device_get(new_state["params"]))
max_diff = max(float(np.abs(np.asarray(a, np.float32)
                            - np.asarray(b, np.float32)).max())
               for a, b in zip(ref_leaves, sh_leaves))
print(json.dumps({"ref_loss": ref_loss, "sh_loss": sh_loss,
                  "max_param_diff": max_diff}))
"""


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["ref_loss"] - res["sh_loss"]) < 1e-4, res
    assert res["max_param_diff"] < 5e-4, res


PLACEMENT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_expert_mesh
from repro.models import build_model
from repro.serve import BankedEngine

assert len(jax.devices()) == 8, jax.devices()
cfg = get_config("smollm-135m").reduced(name="placed")
model = build_model(cfg)
params = [model.init(jax.random.PRNGKey(i)) for i in range(4)]
rng = np.random.default_rng(0)
groups = {i: ([i], [rng.integers(0, 50, 5 + 3 * i)], [4])
          for i in range(4)}

def run(mesh, deferred):
    # deferred=False is the blocking serial reference (each tick
    # materialises its token); deferred=True is the overlapped
    # executor's engine protocol: enqueue everything, harvest once per
    # step with a single batched device->host transfer per wave
    bank = BankedEngine(model, params, max_len=32, mesh=mesh)
    bank.admit(groups, defer=deferred)
    if deferred:
        bank.harvest()
    while bank.n_active:
        bank.tick(defer=deferred)
        if deferred:
            bank.harvest()
    out = {(l, u): t.tolist() for l, u, t in bank.poll()}
    return out, bank.stats.host_blocks

mesh = make_expert_mesh()  # (expert=8) -> bank submesh below
from repro.serve.placement import _bank_submesh
sub, devs = _bank_submesh(4, mesh)
assert sub is not None and dict(sub.shape) == {"expert": 4}, sub
sharded_serial, blocks_serial = run(sub, False)
sharded_over, blocks_over = run(sub, True)
single, _ = run(None, False)
print(json.dumps({
    "n_devices": len(jax.devices()), "bank_devices": len(devs),
    "match": all(single[k] == sharded_serial[k] for k in single),
    "match_overlapped": all(single[k] == sharded_over[k]
                            for k in single),
    "blocks_serial": blocks_serial, "blocks_over": blocks_over}))
"""


@pytest.mark.slow
def test_banked_placement_sharded_matches_single_device():
    """A 4-expert bank sharded over 4 of 8 host devices must emit the
    same tokens as the unsharded bank — under both the blocking serial
    protocol and the overlapped executor's deferred enqueue-then-harvest
    protocol, which must also host-block strictly less (GSPMD numerics +
    async dispatch check for the serving placement path)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", PLACEMENT_SCRIPT], capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 8 and res["bank_devices"] == 4, res
    assert res["match"], res
    assert res["match_overlapped"], res
    assert res["blocks_over"] < res["blocks_serial"], res
