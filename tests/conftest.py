import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# strictly dry-run-only, per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Deterministic property-testing profile. The property suites import
# strategies from tests/_prop.py (a seeded, fully deterministic
# fallback) when ``hypothesis`` is absent — which is the baked CI
# image. When a dev environment *does* have hypothesis, the CI profile
# (selected by the CI env var) derandomizes it: examples derive from
# the test name, no example database, no deadline flake — so a grid
# like tests/test_speculative.py replays bit-identically on every run.
try:
    import hypothesis  # noqa: F401
except ImportError:
    pass
else:
    from hypothesis import settings as _hsettings

    _hsettings.register_profile("ci", derandomize=True, database=None,
                                deadline=None, max_examples=24)
    _hsettings.register_profile("dev", deadline=None)
    _hsettings.load_profile("ci" if os.environ.get("CI") else "dev")
