"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture is instantiated as a REDUCED same-family
variant (2 layers, d_model <= 128, <= 4 experts) and runs one forward +
one train step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import build_model
from repro.models.common import ShapeConfig
from repro.train.loop import init_train_state, make_train_step
from repro.optim import constant_lr

B, S = 2, 32


def _batch(model, sc, seed=0):
    shapes = model.input_shapes(sc)
    key = jax.random.PRNGKey(seed)
    out = {}
    for k, v in shapes.items():
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(key, v.shape, 0,
                                        model.cfg.vocab_size)
        else:
            out[k] = jax.random.normal(key, v.shape, v.dtype) * 0.1
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    batch = _batch(model, ShapeConfig("t", S, B, "train"))
    loss0, _ = jax.jit(model.loss)(state["params"], batch)
    assert np.isfinite(float(loss0)), f"{arch}: NaN forward loss"
    step = jax.jit(make_train_step(model, lr_fn=constant_lr(1e-3)))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(state["params"])[1]
    assert np.isfinite(np.asarray(l0, np.float32)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(model, ShapeConfig("p", S, B, "prefill"))
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, capacity=S + 8))(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None] % cfg.vocab_size
    logits2, cache2 = jax.jit(model.decode)(params, cache, {"token": tok})
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x22b",
                                  "rwkv6-7b", "zamba2-7b",
                                  "seamless-m4t-large-v2", "internvl2-26b"])
def test_decode_matches_teacher_forcing(arch):
    """Incremental decode == teacher-forced prefill (cache correctness)."""
    cfg = get_config(arch).reduced()
    if cfg.n_experts:  # dropless capacity so routing is deterministic
        cfg = cfg.replace(
            moe_capacity_factor=float(cfg.n_experts) / cfg.experts_per_token)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    full = _batch(model, ShapeConfig("p", S + 4, B, "prefill"), seed=3)
    short = dict(full)
    short["tokens"] = full["tokens"][:, :full["tokens"].shape[1] - 4]
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, capacity=S + 8))(params, short)
    dec = jax.jit(model.decode)
    for i in range(4):
        tok = full["tokens"][:, -(4 - i)][:, None]
        logits, cache = dec(params, cache, {"token": tok})
    flogits, _ = jax.jit(model.prefill)(params, full)
    a = np.asarray(logits, np.float32)
    b = np.asarray(flogits, np.float32)
    rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-9)
    assert rel < 2e-2, f"{arch}: decode diverges from prefill (rel={rel})"


def test_rwkv_chunked_equals_scan_model_level():
    cfg = get_config("rwkv6-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(model, ShapeConfig("t", S, B, "train"))
    model.seq_mode = "chunked"
    l1, _ = jax.jit(model.loss)(params, batch)
    model.seq_mode = "scan"
    l2, _ = jax.jit(model.loss)(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


def test_moe_dispatch_matches_dense_oracle():
    cfg = get_config("olmoe-1b-7b").reduced()
    cfg = cfg.replace(moe_capacity_factor=float(cfg.n_experts)
                      / cfg.experts_per_token)  # dropless
    m_disp = build_model(cfg)
    params = m_disp.init(jax.random.PRNGKey(0))
    batch = _batch(m_disp, ShapeConfig("t", S, B, "train"))
    l1, _ = jax.jit(m_disp.loss)(params, batch)
    m_dense = build_model(cfg.replace(moe_impl="dense"))
    l2, _ = jax.jit(m_dense.loss)(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-3)


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters (guard against config drift)."""
    spec = {
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "seamless-m4t-large-v2": (48, 1024, 16, 16, 8192, 256206),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
    }
    for name, (L, D, H, KV, F, V) in spec.items():
        cfg = get_config(name)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, D, H, KV, F, V), (name, got)
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("mixtral-8x22b").n_experts == 8
    assert get_config("mixtral-8x22b").experts_per_token == 2
    assert get_config("mixtral-8x22b").sliding_window > 0
    assert get_config("olmoe-1b-7b").n_experts == 64
    assert get_config("olmoe-1b-7b").experts_per_token == 8
    assert get_config("qwen2-72b").qkv_bias
    assert get_config("qwen2.5-14b").qkv_bias


def test_swa_ring_cache_decode_matches_teacher_forcing():
    """Sliding-window arch: decoding past the window with a ring cache of
    window size must equal teacher-forced prefill (mixtral-style SWA)."""
    cfg = get_config("mixtral-8x22b").reduced(sliding_window=16)
    cfg = cfg.replace(
        moe_capacity_factor=float(cfg.n_experts) / cfg.experts_per_token)
    model = build_model(cfg)
    assert model.cache_capacity(64) == 16  # ring of window size
    params = model.init(jax.random.PRNGKey(0))
    S_total = 40
    full = _batch(model, ShapeConfig("p", S_total, B, "prefill"), seed=5)
    short = {"tokens": full["tokens"][:, :S_total - 6]}
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b))(params, short)
    assert cache["k"].shape[2] == 16
    dec = jax.jit(model.decode)
    for i in range(6):
        tok = full["tokens"][:, S_total - 6 + i][:, None]
        logits, cache = dec(params, cache, {"token": tok})
    flogits, _ = jax.jit(model.prefill)(params, full)
    a = np.asarray(logits, np.float32)
    b = np.asarray(flogits, np.float32)
    rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-9)
    assert rel < 2e-2, f"SWA ring decode diverges (rel={rel})"


def test_vlm_loss_ignores_stub_positions():
    """VLM loss is computed on text positions only; changing the stub
    embeddings changes logits but labels never cover stub slots."""
    cfg = get_config("internvl2-26b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(model, ShapeConfig("t", S, B, "train"))
    assert batch["tokens"].shape[1] == S - cfg.n_stub_embeds
    loss, _ = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    # stub embeddings participate in the forward pass (logits shift)...
    batch2 = dict(batch)
    batch2["stub_embeds"] = batch["stub_embeds"] + 1.0
    loss2, _ = jax.jit(model.loss)(params, batch2)
    assert abs(float(loss) - float(loss2)) > 1e-6
