"""Deterministic schedule fuzzer (rules S001-S002).

The suite is armed with a faulthandler hard timeout: a real deadlock
in the cooperative scheduler dumps every thread's stack and kills the
run instead of hanging CI (the interleaver's own structural deadlock
detection plus its watchdog should always fire first — the
faulthandler is the backstop behind the backstop).
"""
import faulthandler
import threading

import pytest

from repro.analysis import sanitizer as S

SUITE_TIMEOUT = 240.0


@pytest.fixture(autouse=True, scope="module")
def hard_timeout():
    on_main = threading.current_thread() is threading.main_thread()
    if on_main:
        faulthandler.dump_traceback_later(SUITE_TIMEOUT, exit=True)
    yield
    if on_main:
        faulthandler.cancel_dump_traceback_later()


# -- determinism -------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_replay_is_byte_deterministic(seed):
    r1 = S.fuzz_hub(seed)
    r2 = S.fuzz_hub(seed)
    assert r1.trace == r2.trace, S._diverge(r1.trace, r2.trace)
    assert r1.failures == [] and r2.failures == []
    assert r1.errors == [] and r2.errors == []
    # the workload really exercised the lifecycle
    assert r1.stats["loads"] >= 1


def test_different_seeds_take_different_schedules():
    assert S.fuzz_hub(0).trace != S.fuzz_hub(1).trace


# -- the planted negative ----------------------------------------------


def test_planted_lost_update_reproduces_under_documented_seed():
    got, want, tr1 = S.demo_lost_update(S.LOST_UPDATE_SEED,
                                        locked=False)
    assert got < want, (
        f"the planted unlocked read-modify-write conserved ({got} of "
        f"{want}) under seed {S.LOST_UPDATE_SEED} — the sanitizer "
        "lost its teeth")
    _, _, tr2 = S.demo_lost_update(S.LOST_UPDATE_SEED, locked=False)
    assert tr1 == tr2


def test_planted_lost_update_fixed_by_lock():
    got, want, _ = S.demo_lost_update(S.LOST_UPDATE_SEED, locked=True)
    assert got == want


# -- lifecycle invariants under interleavings --------------------------


def test_staging_failure_path_recovers(tmp_path):
    """Seeded regression for the staging-failure fix: the missing
    expert's load fails mid-fuzz; the worker's cold reset must happen
    under the hub lock, the failure must re-raise on the scheduler
    side, and every conservation invariant must still hold after."""
    r = S.fuzz_hub(S.FAIL_SEED, fail_expert=True)
    assert r.stats["stage_failures"] >= 1, \
        "workload never wanted the broken expert — dead seed"
    assert r.failures == []
    assert r.errors and set(r.errors) == {"FileNotFoundError"}
    # and the failure path replays deterministically too
    assert r.trace == S.fuzz_hub(S.FAIL_SEED, fail_expert=True).trace


def test_fuzz_leaves_no_threads_behind():
    before = {t.ident for t in threading.enumerate()}
    S.fuzz_hub(3)
    leftover = [t for t in threading.enumerate()
                if t.ident not in before and t.is_alive()]
    assert leftover == [], leftover


# -- scheduler machinery -----------------------------------------------


def test_deadlock_is_detected_not_hung():
    """Classic ABBA deadlock, forced via queue rendezvous so it occurs
    under every seed: the interleaver must abort structurally (no
    runnable thread) instead of wedging."""
    itl = S.Interleaver(0, watchdog=10.0)
    l1, l2 = S.ShimLock(itl), S.ShimLock(itl)
    q1, q2 = S.ShimQueue(itl), S.ShimQueue(itl)

    def peer_fn():
        with l2:
            q1.put(1)
            q2.get()
            l1.acquire()

    peer = S._ManagedThread(itl, target=peer_fn, name="peer")

    def driver():
        peer.start()
        with l1:
            q1.get()
            q2.put(1)
            l2.acquire()

    with pytest.raises(S._AbortError, match="deadlock"):
        itl.run(driver)
    itl.shutdown()
    assert "deadlock" in itl.aborted


def test_shim_lock_enforces_mutual_exclusion():
    itl = S.Interleaver(5)
    lock = S.ShimLock(itl)
    out = []

    def peer_fn():
        for _ in range(5):
            with lock:
                out.append(("peer", lock.owner))
                itl.yield_point("peer-crit")
                assert lock.owner == "peer"

    peer = S._ManagedThread(itl, target=peer_fn, name="peer")

    def driver():
        peer.start()
        for _ in range(5):
            with lock:
                out.append(("main", lock.owner))
                itl.yield_point("main-crit")
                assert lock.owner == "main"
        peer.join()

    itl.run(driver)
    itl.shutdown()
    assert len(out) == 10
    assert all(who == owner for who, owner in out)


def test_instrument_refuses_after_worker_spawn():
    class FakeHub:
        _stage_thread = object()

    with pytest.raises(RuntimeError, match="too late"):
        S.instrument(FakeHub(), S.Interleaver(0))


# -- the pass ----------------------------------------------------------


def test_sanitizer_pass_is_clean():
    assert S.run(seeds=(0,)) == []
