"""Paged KV cache tests: pool allocator invariants, prefix-cache
refcounting, paged-vs-ring token identity on the traffic grids,
shared-prefix prefill savings, copy-on-write under ring wrap, and
clean backpressure on pool exhaustion."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ExpertRegistry, build_matcher, train_bank
from repro.data import load_benchmark
from repro.models import build_model
from repro.serve import (ExpertEngine, PagePool, PagePoolExhausted,
                         PrefixCache, Request, RoutedServer, hash_chain,
                         plan_placement)

from _prop import given, settings, strategies as st


# -- allocator properties ---------------------------------------------------


@settings(max_examples=10)
@given(st.integers(1, 3), st.integers(4, 40), st.integers(1, 200))
def test_page_pool_refcount_free_list_invariants(E, n_pages, seed):
    """Random alloc/retain/release interleavings preserve the core
    invariant: every page is either free with refcount 0 or held with a
    positive refcount, exactly once — and a failed (oversized) alloc
    changes nothing."""
    rng = np.random.default_rng(seed)
    pool = PagePool(E, n_pages, page_size=8)
    held = {e: [] for e in range(E)}      # one entry per reference
    for _ in range(60):
        e = int(rng.integers(E))
        op = rng.random()
        if op < 0.45:
            n = int(rng.integers(0, n_pages + 2))
            free_before = pool.free_count(e)
            refs_before = pool.refs.copy()
            if n > free_before:
                with pytest.raises(PagePoolExhausted):
                    pool.alloc(e, n)
                # transactional: nothing moved
                assert pool.free_count(e) == free_before
                np.testing.assert_array_equal(pool.refs, refs_before)
            else:
                for p in pool.alloc(e, n):
                    held[e].append(p)
        elif op < 0.7 and held[e]:
            p = held[e][int(rng.integers(len(held[e])))]
            pool.retain(e, [p])
            held[e].append(p)
        elif held[e]:
            p = held[e].pop(int(rng.integers(len(held[e]))))
            pool.release(e, [p])
        pool.check()
        # the counters pair (sampled by --check-invariants) conserves
        # under every interleaving: free + used == E * n_pages, with
        # free agreeing with the per-expert free lists
        c = pool.counters()
        assert c["free"] + c["used"] == E * n_pages, c
        assert c["free"] == sum(pool.free_count(e2) for e2 in range(E))
        # refcounts mirror the shadow ledger exactly
        for e2 in range(E):
            want = np.bincount(held[e2], minlength=n_pages) \
                if held[e2] else np.zeros(n_pages, int)
            np.testing.assert_array_equal(pool.refs[e2], want)
    for e in range(E):
        for p in held[e]:
            pool.release(e, [p])
    pool.check()
    assert all(pool.free_count(e) == n_pages for e in range(E))


def test_pool_counters_track_residency_not_refcounts():
    """counters() counts page *residency* (off the free list), so a
    retain/release cycle on a held page must not move it — only the
    final release that returns the page to the free list does."""
    pool = PagePool(2, 10, 8)
    total = 2 * 10
    assert pool.counters() == {"free": total, "used": 0}
    a = pool.alloc(0, 3)
    b = pool.alloc(1, 5)
    assert pool.counters() == {"free": total - 8, "used": 8}
    pool.retain(0, a)                  # extra refs: residency unchanged
    assert pool.counters()["used"] == 8
    pool.release(0, a)
    assert pool.counters()["used"] == 8
    pool.release(0, a)                 # last ref: pages go free
    pool.release(1, b)
    assert pool.counters() == {"free": total, "used": 0}
    pool.check()


def test_page_pool_double_free_and_stale_retain_raise():
    pool = PagePool(1, 4, 8)
    (p,) = pool.alloc(0, 1)
    pool.release(0, [p])
    with pytest.raises(ValueError, match="double free"):
        pool.release(0, [p])
    with pytest.raises(ValueError, match="retain of free"):
        pool.retain(0, [p])


def test_prefix_cache_holds_refs_and_eviction_releases():
    pool = PagePool(1, 8, 8)
    cache = PrefixCache(pool, capacity=64)
    toks = np.arange(24, dtype=np.int32)
    chain = hash_chain(toks, 8)
    pages = pool.alloc(0, 3)
    cache.insert(0, 24, chain, pages, first_token=7)
    pool.release(0, pages)            # the "wave" retires its refs
    pool.check()
    assert pool.free_count(0) == 5    # cache still pins all three
    # adoption hands the caller its own references
    adopted = cache.adopt_prefix(0, chain)
    assert adopted == pages
    assert cache.first_token(0, 24, chain) == 7
    # a divergent second page stops the walk after the shared head
    other = toks.copy()
    other[10] = 99
    assert cache.adopt_prefix(0, hash_chain(other, 8)) == pages[:1]
    pool.release(0, pages[:1])
    # eviction releases the cache's refs; caller-held refs keep pages
    cache.evict_for(0, need=8)
    pool.check()
    pool.release(0, adopted)
    pool.check()
    assert pool.free_count(0) == 8


def test_prefix_cache_lru_eviction_under_churn():
    """Churn far past capacity: every LRU eviction must release its
    pool pin (the pool never runs dry from cache pressure alone), the
    live pin count must equal the page entries actually in the cache,
    and a full-cache cycle must return every refcount to baseline."""
    pool = PagePool(1, 32, 8)
    cache = PrefixCache(pool, capacity=8)
    baseline_free = pool.free_count(0)
    for k in range(40):                      # 40 distinct 2-page chains
        toks = np.full(16, k, np.int32)
        chain = hash_chain(toks, 8)
        pages = pool.alloc(0, 2)
        cache.insert(0, 16, chain, pages, first_token=k)
        pool.release(0, pages)               # the computing wave retires
        pool.check()
        # pinned pages == page entries currently indexed, exactly
        n_pg = sum(1 for key in cache._lru if key[0] == "pg")
        assert pool.used_count(0) == n_pg
        assert len(cache) <= 8
    assert cache.stats["evictions"] > 0
    # an entry evicted while a live row still holds the page must not
    # free it under the row
    toks = np.full(16, 99, np.int32)
    chain = hash_chain(toks, 8)
    pages = pool.alloc(0, 2)
    cache.insert(0, 16, chain, pages, first_token=1)
    cache.clear()                            # cache pin released...
    pool.check()
    assert all(pool.refs[0, p] == 1 for p in pages)  # ...row pin holds
    pool.release(0, pages)
    pool.check()
    assert pool.free_count(0) == baseline_free, \
        "refcounts did not return to baseline after a full-cache cycle"


def test_engine_rejects_unpageable_config():
    cfg = get_config("smollm-135m").reduced(name="odd-bucket")
    model = build_model(cfg)
    with pytest.raises(ValueError, match="multiple of page_size"):
        ExpertEngine(model, None, max_len=60, kv_layout="paged")
    cfg_r = get_config("rwkv6-7b").reduced(name="rwkv")
    rwkv = build_model(cfg_r)
    with pytest.raises(ValueError, match="paged KV"):
        ExpertEngine(rwkv, None, max_len=64, kv_layout="paged")


# -- serving fixtures -------------------------------------------------------


@pytest.fixture(scope="module")
def bench():
    return load_benchmark(names=["mnist", "har"], n_per_dataset=300,
                          seed=0)


@pytest.fixture(scope="module")
def matcher(bench):
    names = list(bench)
    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=8, batch_size=64)
    cents = [(bench[n]["server"][0], bench[n]["server"][1])
             for n in names]
    return build_matcher(aes, names, cents), names


@pytest.fixture(scope="module")
def shared_model():
    cfg = get_config("smollm-135m").reduced(name="paged-t")
    model = build_model(cfg)
    params = [model.init(jax.random.PRNGKey(s)) for s in (0, 1)]
    return model, params


def _server(matcher, shared_model, kv, **kw):
    m, names = matcher
    model, params = shared_model
    reg = ExpertRegistry()
    for n, p in zip(names, params):
        reg.add(n, ExpertEngine(model, p, max_len=64, kv_layout=kv, **kw))
    return RoutedServer(m, reg, max_batch=4), reg


def _traffic(bench, names, rng, n, shared=None, share_every=0):
    reqs = []
    for uid in range(n):
        nm = names[uid % 2]
        x, _ = bench[nm]["client_a"]
        if shared is not None and share_every and uid % share_every == 0:
            prompt = shared
        else:
            prompt = rng.integers(0, 100, size=int(rng.integers(1, 40)))
        reqs.append(Request(uid=uid, features=x[uid % 60], prompt=prompt,
                            max_new_tokens=int(rng.integers(1, 7))))
    return reqs


# -- token identity ---------------------------------------------------------


def test_paged_token_identical_to_ring_on_traffic_grids(matcher, bench,
                                                        shared_model):
    """The acceptance criterion: paged decode must be token-identical to
    the ring path on uniform / skewed / bursty shaped traffic (mixed
    prompt lengths, max_new, expert mixes), while the pool invariants
    hold throughout."""
    srv_r, _ = _server(matcher, shared_model, "ring")
    srv_p, reg_p = _server(matcher, shared_model, "paged")
    m, names = matcher
    uid0 = 0
    for scenario in ("uniform", "skewed", "bursty"):
        rng = np.random.default_rng(0xA0 + uid0)
        reqs = []
        for k in range(9):
            if scenario == "skewed":
                e = 0 if rng.random() < 0.8 else 1
            else:
                e = int(rng.integers(2))
            x, _ = bench[names[e]]["client_a"]
            reqs.append(Request(
                uid=uid0 + k, features=x[(uid0 + k) % 60],
                prompt=rng.integers(0, 100, size=int(rng.integers(1, 40))),
                max_new_tokens=int(rng.integers(1, 7))))
        uid0 += 9
        if scenario == "bursty":       # one burst, then drain
            got_r = srv_r.serve(reqs)
            got_p = srv_p.serve(reqs)
        else:                          # trickled submits
            got_r, got_p = [], []
            for lo in range(0, len(reqs), 3):
                got_r += srv_r.serve(reqs[lo:lo + 3])
                got_p += srv_p.serve(reqs[lo:lo + 3])
        for a, b in zip(got_r, got_p):
            assert a.uid == b.uid and a.expert == b.expert, scenario
            assert a.fine_class == b.fine_class
            np.testing.assert_array_equal(a.tokens, b.tokens,
                                          err_msg=f"{scenario}/{a.uid}")
        for e in range(2):
            reg_p[e].backend.core.pool.check()


def test_shared_prefix_cohort_prefill_savings(matcher, bench,
                                              shared_model):
    """Cohort traffic (identical prompts) must be deduplicated in-wave
    and served from the prefix cache across waves: strictly fewer
    prefill tokens computed than submitted, token-identically to ring."""
    srv_r, _ = _server(matcher, shared_model, "ring")
    srv_p, reg_p = _server(matcher, shared_model, "paged")
    m, names = matcher
    rng = np.random.default_rng(5)
    shared = rng.integers(0, 100, size=30)     # 32-bucket, no ring wrap
    x, _ = bench[names[0]]["client_a"]
    # one feature sample for the whole cohort: routing (and therefore
    # the expert whose stats we assert on) is deterministic
    mk = lambda uid, mn: Request(uid=uid, features=x[0],
                                 prompt=shared, max_new_tokens=mn)
    # first cohort coalesces into one wave: one computed row, three dups
    reqs1 = [mk(u, 2 + u % 3) for u in range(4)]
    # second cohort arrives after the first retired: full cache hits
    reqs2 = [mk(10 + u, 2 + u % 4) for u in range(3)]
    got_p = srv_p.serve(reqs1)
    got_p += srv_p.serve(reqs2)
    got_r = srv_r.serve(reqs1)
    got_r += srv_r.serve(reqs2)
    for a, b in zip(got_r, got_p):
        np.testing.assert_array_equal(a.tokens, b.tokens,
                                      err_msg=str(a.uid))
    e = names.index(got_p[0].expert)     # the cohort's (single) expert
    st = reg_p[e].backend.stats
    assert st.prefix_dup_rows >= 3
    assert st.prefix_full_hits >= 3, st
    assert st.prefill_tokens_computed < st.prefill_tokens_submitted, st
    # the second cohort needed no prefill dispatch at all
    assert st.prefill_rows_computed == 1, st
    cache = reg_p[e].backend.core.prefix_cache
    assert cache.stats["full_hits"] >= 3


def test_wrap_forces_copy_on_write_and_stays_identical(matcher, bench,
                                                       shared_model):
    """Prompts at the 64-bucket make decode wrap into prompt pages; a
    dup row sharing those pages must get its own copies (COW) — never
    corrupt its representative's pages — and match ring exactly."""
    srv_r, _ = _server(matcher, shared_model, "ring")
    srv_p, reg_p = _server(matcher, shared_model, "paged")
    m, names = matcher
    rng = np.random.default_rng(9)
    long = rng.integers(0, 100, size=60)       # Sb = 64 = capacity
    x, _ = bench[names[0]]["client_a"]
    # identical features: the whole cohort lands on one expert
    reqs = [Request(uid=u, features=x[0], prompt=long,
                    max_new_tokens=6) for u in range(3)]
    got_r = srv_r.serve(reqs)
    got_p = srv_p.serve(reqs)
    for a, b in zip(got_r, got_p):
        np.testing.assert_array_equal(a.tokens, b.tokens,
                                      err_msg=str(a.uid))
    e = names.index(got_p[0].expert)
    st = reg_p[e].backend.stats
    assert st.pages_copied >= 2, st
    pool = reg_p[e].backend.core.pool
    pool.check()
    # COW remaps moved references between pages but conserved the books
    c = pool.counters()
    assert c["free"] + c["used"] == pool.n_experts * pool.n_pages, c


# -- exhaustion / backpressure ----------------------------------------------


def test_pool_exhaustion_requeues_cleanly(matcher, bench, shared_model):
    """A pool sized for ~one wave forces admissions to stall while
    earlier waves decode; the scheduler must requeue (never corrupt
    resident rows' pages) and still produce ring-identical tokens."""
    srv_r, _ = _server(matcher, shared_model, "ring")
    srv_t, reg_t = _server(matcher, shared_model, "paged", pool_pages=40)
    m, names = matcher
    rng = np.random.default_rng(11)
    # long prompts: a 4-row wave owns 32 pages, so a second wave cannot
    # be admitted while the first is resident (40-page pool) — the
    # stall path must trigger
    reqs = []
    for uid in range(16):
        nm = names[uid % 2]
        x, _ = bench[nm]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[uid % 60],
            prompt=rng.integers(0, 100, size=int(rng.integers(33, 48))),
            max_new_tokens=int(rng.integers(2, 7))))
    got_r = srv_r.serve(reqs)
    got_t = srv_t.serve(reqs)
    for a, b in zip(got_r, got_t):
        np.testing.assert_array_equal(a.tokens, b.tokens,
                                      err_msg=str(a.uid))
    assert srv_t.scheduler.stats.kv_stalls >= 1, \
        "tiny pool never stalled — test is vacuous"
    for e in range(2):
        reg_t[e].backend.core.pool.check()
        # nothing leaked once drained (only prefix-cache pins remain)
        pool = reg_t[e].backend.core.pool
        cache_refs = sum(1 for k in reg_t[e].backend.core.prefix_cache._lru
                         if k[0] == "pg")
        assert pool.used_count(e=0) == cache_refs


def test_engine_admit_beyond_pool_raises_transactionally(shared_model):
    """An admission the pool can never host raises PagePoolExhausted
    without corrupting the resident wave's pages: the resident rows
    still decode to the same tokens as an unmolested engine."""
    model, params = shared_model
    eng = ExpertEngine(model, params[0], max_len=64, kv_layout="paged",
                       pool_pages=40)
    ref = ExpertEngine(model, params[0], max_len=64, kv_layout="ring")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 100, size=20) for _ in range(2)]
    eng.admit([0, 1], prompts, [4, 4], defer=True)
    ref.admit([0, 1], prompts, [4, 4])
    used_before = eng.core.pool.used_count(0)
    big = [rng.integers(0, 100, size=60) for _ in range(4)]
    with pytest.raises(PagePoolExhausted):
        eng.admit([2, 3, 4, 5], big, [4] * 4, defer=True)
    # transactional: the failed admission left no pages behind
    assert eng.core.pool.used_count(0) == used_before
    eng.core.pool.check()
    while eng.n_active:
        eng.tick()
    while ref.n_active:
        ref.tick()
    got, want = dict(eng.poll()), dict(ref.poll())
    for u in (0, 1):
        np.testing.assert_array_equal(got[u], want[u])


def test_rollback_with_cow_remaps_releases_everything(shared_model):
    """Regression: the dup branch's rollback-ledger entry aliased the
    row's mutable page list, so a COW remap before a mid-wave
    PagePoolExhausted corrupted the ledger — rollback double-freed the
    fresh COW page (ValueError instead of clean backpressure) and
    leaked the shared pages. Exhaustion during a COW-heavy wave must
    roll back to an empty pool."""
    model, params = shared_model
    # 9 pages: the computed row takes 8 (Sb = 64), the first dup's COW
    # takes the 9th, the second dup's COW must exhaust mid-plan
    eng = ExpertEngine(model, params[0], max_len=64, kv_layout="paged",
                       pool_pages=9)
    long = np.random.default_rng(0).integers(0, 100, size=60)
    with pytest.raises(PagePoolExhausted):
        eng.admit([0, 1, 2], [long] * 3, [6, 6, 6], defer=True)
    eng.core.pool.check()
    assert eng.core.pool.free_count(0) == 9, "rollback leaked pages"
    assert eng.n_active == 0


def test_pool_too_small_for_one_wave_surfaces(matcher, bench,
                                              shared_model):
    """When even an empty engine cannot host a wave, requeueing would
    spin forever — the scheduler must surface the configuration error."""
    srv, _ = _server(matcher, shared_model, "paged", pool_pages=4)
    m, names = matcher
    x, _ = bench[names[0]]["client_a"]
    srv.submit([Request(uid=0, features=x[0],
                        prompt=np.arange(40, dtype=np.int32),
                        max_new_tokens=4)])
    with pytest.raises(PagePoolExhausted):
        srv.scheduler.drain()


# -- banked placement -------------------------------------------------------


def test_paged_banked_matches_ring_per_engine(matcher, bench,
                                              shared_model):
    """Cross-layout x cross-placement: a paged *banked* server must be
    token-identical to the per-engine ring reference, with prefix
    sharing live inside the bank."""
    m, names = matcher
    model, params = shared_model
    srv_ref, _ = _server(matcher, shared_model, "ring")
    reg = ExpertRegistry()
    for n, p in zip(names, params):
        reg.add(n, ExpertEngine(model, p, max_len=64, kv_layout="paged"))
    plan = plan_placement(reg)
    assert plan.shards[0].banked and plan.shards[0].bank.kv_layout == \
        "paged"
    srv_b = RoutedServer(m, reg, max_batch=4, placement=plan)
    rng = np.random.default_rng(13)
    shared = rng.integers(0, 100, size=30)
    reqs = _traffic(bench, names, rng, 12, shared=shared, share_every=3)
    got_ref = srv_ref.serve(reqs)
    got_b = srv_b.serve(reqs)
    for a, b in zip(got_ref, got_b):
        assert a.expert == b.expert
        np.testing.assert_array_equal(a.tokens, b.tokens,
                                      err_msg=str(a.uid))
    assert plan.shards[0].bank.stats.prefix_dup_rows >= 1
    plan.shards[0].bank.core.pool.check()
