"""Serving subsystem tests: scheduler/engine/router behaviour under
mixed-shape traffic, plus kernel-vs-reference routing parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (ExpertRegistry, MatcherConfig, build_matcher,
                        train_bank)
from repro.core.autoencoder import bank_scores
from repro.data import load_benchmark
from repro.models import build_model
from repro.serve import (ExpertEngine, Request, Response, RoutedServer,
                         bucket_for, make_buckets)
from repro.serve.router import Router


@pytest.fixture(scope="module")
def bench():
    return load_benchmark(names=["mnist", "har"], n_per_dataset=400, seed=0)


@pytest.fixture(scope="module")
def matcher(bench):
    names = list(bench)
    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=12, batch_size=64)
    cents = [(bench[n]["server"][0], bench[n]["server"][1]) for n in names]
    return build_matcher(aes, names, cents), names


def _engine(seed=0, max_len=64):
    cfg = get_config("smollm-135m").reduced(name=f"eng-{seed}")
    model = build_model(cfg)
    return ExpertEngine(model, model.init(jax.random.PRNGKey(seed)),
                        max_len=max_len)


def _server(matcher, max_batch=4):
    m, names = matcher
    reg = ExpertRegistry()
    for i, n in enumerate(names):
        reg.add(n, _engine(seed=i))
    return RoutedServer(m, reg, max_batch=max_batch), names


# -- buckets ----------------------------------------------------------------


def test_bucket_ladder():
    assert make_buckets(8, 64) == (8, 16, 32, 64)
    assert make_buckets(1, 12) == (1, 2, 4, 8, 12)
    assert bucket_for(3, (4, 8)) == 4
    assert bucket_for(9, (4, 8)) == 8  # clamps to largest


# -- engine -----------------------------------------------------------------


def test_engine_rows_finish_independently():
    """A row with small max_new is harvested before its group retires."""
    eng = _engine()
    rng = np.random.default_rng(0)
    eng.admit([7, 8], [rng.integers(0, 50, 5), rng.integers(0, 50, 5)],
              max_new=[1, 6])
    early = dict(eng.poll())
    assert 7 in early and early[7].shape == (1,)   # done at prefill
    assert 8 not in early
    while eng.n_active:
        eng.tick()
    late = dict(eng.poll())
    assert late[8].shape == (6,)


def test_engine_generate_matches_seed_contract():
    eng = _engine()
    toks = np.random.default_rng(1).integers(0, 50, size=(3, 9))
    out = eng.generate(toks, 5)
    assert out.shape == (3, 5)
    assert out.dtype == np.int32


# -- routed server end to end ----------------------------------------------


def test_uid_mapping_out_of_order(matcher, bench):
    """Responses must map to the right uid even though execution order is
    grouped per expert / length bucket, not arrival order."""
    srv, names = _server(matcher)
    rng = np.random.default_rng(2)
    reqs, truth = [], {}
    # interleave experts and shapes so per-expert grouping reorders rows
    for uid in range(24):
        n = names[uid % 2]
        x, _ = bench[n]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[uid],
            prompt=rng.integers(0, 100, size=int(rng.integers(2, 40))),
            max_new_tokens=int(rng.integers(1, 9))))
        truth[uid] = n
    resps = srv.serve(reqs)
    assert [r.uid for r in resps] == [q.uid for q in reqs]
    acc = np.mean([r.expert == truth[r.uid] for r in resps])
    assert acc > 0.8
    for r, q in zip(resps, reqs):
        assert r.tokens.shape == (q.max_new_tokens,)
        assert r.fine_class >= 0
        assert r.coarse_scores is not None


def test_jit_cache_bounded_across_50_mixed_shape_requests(matcher, bench):
    """50 requests with ~unique (prompt len, max_new) combos must compile
    a bounded executable set: buckets, not request shapes, key the cache."""
    srv, names = _server(matcher)
    rng = np.random.default_rng(3)
    reqs = []
    for uid in range(50):
        n = names[uid % 2]
        x, _ = bench[n]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[uid % 100],
            prompt=rng.integers(0, 100, size=1 + (uid * 7) % 60),
            max_new_tokens=1 + uid % 12))
    resps = srv.serve(reqs)
    assert len(resps) == 50
    for e in range(len(srv.registry)):
        st = srv.registry[e].backend.stats
        n_len = len(srv.registry[e].backend.len_buckets)
        n_bat = len(srv.registry[e].backend.batch_buckets)
        assert st.prefill_compiles <= n_len * n_bat
        assert st.decode_compiles <= n_bat
        # the practical bound the ISSUE cares about: far fewer distinct
        # executables than distinct request shapes
        assert st.jit_cache_entries <= 20, st
    # and replaying the identical traffic compiles nothing new
    before = [srv.registry[e].backend.stats.jit_cache_entries
              for e in range(len(srv.registry))]
    srv.serve(reqs)
    after = [srv.registry[e].backend.stats.jit_cache_entries
             for e in range(len(srv.registry))]
    assert before == after


def test_continuous_batching_coalesces_across_submits(matcher, bench):
    """Requests from separate submit() calls join one micro-batch."""
    srv, names = _server(matcher, max_batch=8)
    x, _ = bench[names[0]]["client_a"]
    rng = np.random.default_rng(4)
    mk = lambda uid: Request(uid=uid, features=x[0],
                             prompt=rng.integers(0, 100, size=10),
                             max_new_tokens=2)
    srv.submit([mk(0), mk(1)])
    srv.submit([mk(2), mk(3)])          # second call, same expert+bucket
    while srv.scheduler.has_work:
        srv.step()
    eng = srv.registry[0].backend
    assert eng.stats.prefill_calls == 1  # one coalesced micro-batch
    assert eng.stats.rows_served == 4


def test_backpressure_prefix_admission(matcher, bench):
    srv, names = _server(matcher)
    srv.scheduler.config.max_queue = 3
    x, _ = bench[names[0]]["client_a"]
    reqs = [Request(uid=u, features=x[u], prompt=np.arange(5),
                    max_new_tokens=1) for u in range(6)]
    assert srv.submit(reqs) == 3         # prefix admitted, tail rejected
    assert srv.scheduler.stats["rejected"] == 3
    got, todo = {}, reqs[3:]             # resubmit only the rejected tail
    while todo or srv.scheduler.has_work:
        if todo:
            todo = todo[srv.scheduler.submit(todo):]
        for r in srv.step():
            got[r.uid] = r
    assert sorted(got) == list(range(6))


# -- router -----------------------------------------------------------------


def test_router_fingerprint_cache_consistency(matcher, bench):
    m, names = matcher
    router = Router(m)
    x, _ = bench[names[0]]["client_a"]
    r1 = router.route(x[:16])
    assert r1.cache_hits == 0
    r2 = router.route(x[:16])
    assert r2.cache_hits == 16
    np.testing.assert_array_equal(r1.coarse, r2.coarse)
    np.testing.assert_array_equal(r1.fine, r2.fine)
    np.testing.assert_allclose(r1.coarse_score, r2.coarse_score)


def test_max_batch_above_engine_bucket_is_capped(matcher, bench):
    """Scheduler max_batch larger than the engine's biggest batch bucket
    must split micro-batches instead of crashing admit()."""
    srv, names = _server(matcher, max_batch=32)
    x, _ = bench[names[0]]["client_a"]
    reqs = [Request(uid=u, features=x[0], prompt=np.arange(6),
                    max_new_tokens=1) for u in range(20)]
    resps = srv.serve(reqs)
    assert len(resps) == 20
    assert srv.registry[0].backend.stats.prefill_calls >= 2  # split


def test_none_backend_completes_and_uid_is_reusable(matcher, bench):
    m, names = matcher
    from repro.core import ExpertRegistry
    reg = ExpertRegistry()
    for n in names:
        reg.add(n, None)  # no engines at all
    srv = RoutedServer(m, reg)
    x, _ = bench[names[0]]["client_a"]
    req = Request(uid=1, features=x[0], prompt=np.arange(4),
                  max_new_tokens=3)
    r1 = srv.serve([req])
    assert r1[0].tokens.shape == (3,) and not r1[0].tokens.any()
    r2 = srv.serve([req])  # uid free again after completion
    assert r2[0].uid == 1
    assert not srv.scheduler._meta  # no in-flight leak


def test_router_chunks_oversized_batches(matcher, bench):
    """Batches beyond the largest row bucket are routed in chunks and
    still produce reference-identical decisions."""
    m, names = matcher
    small = Router(m, max_rows=16)
    ref = Router(m)
    x = bench[names[0]]["client_a"][0][:40]   # 40 rows > max_rows=16
    got = small.route(x)
    want = ref.route(x)
    np.testing.assert_array_equal(got.coarse, want.coarse)
    np.testing.assert_array_equal(got.fine, want.fine)


def test_router_lru_eviction(matcher, bench):
    m, names = matcher
    router = Router(m, cache_size=8)
    x, _ = bench[names[0]]["client_a"]
    router.route(x[:32])
    assert len(router._lru) == 8


# -- kernel vs reference parity --------------------------------------------


def test_coarse_kernel_parity_with_trained_bn_state(matcher, bench):
    """use_kernel=True must score with the real BatchNorm statistics:
    on a trained AE bank (non-trivial BN state) the Pallas path and the
    reference bank_scores must agree (regression for the dropped
    bank_states bug)."""
    m, names = matcher
    st = np.asarray(m.bank_states["mean"])
    assert np.abs(st).max() > 1e-3, "BN state is trivial; test is vacuous"
    x, _ = bench[names[0]]["client_a"]
    x = jnp.asarray(x[:64])
    from repro.core.matcher import ExpertMatcher
    km = ExpertMatcher(m.bank_params, m.bank_states, names, m.centroids,
                       m.centroid_mask, MatcherConfig(use_kernel=True))
    got = np.asarray(km.coarse_scores(x))
    want = np.asarray(bank_scores(m.bank_params, m.bank_states, x))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)
    # and the routing decision is identical
    np.testing.assert_array_equal(np.asarray(km.assign_coarse(x)),
                                  np.asarray(m.assign_coarse(x)))


def test_fine_kernel_parity_with_reference(matcher, bench):
    """Router's grouped Pallas cosine path == matcher.assign_fine."""
    m, names = matcher
    router = Router(m, use_fine_kernel=True)
    ref_router = Router(m, use_fine_kernel=False)
    xs = np.concatenate([bench[n]["client_a"][0][:20] for n in names])
    got = router.route(xs)
    want = ref_router.route(xs)
    np.testing.assert_array_equal(got.coarse, want.coarse)
    np.testing.assert_array_equal(got.fine, want.fine)
    # cross-check against the matcher's own fine path
    direct = np.asarray(m.assign_fine(
        jnp.asarray(xs), jnp.asarray(got.coarse[:, 0])))
    np.testing.assert_array_equal(got.fine, direct)
