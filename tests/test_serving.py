"""Serving subsystem tests: scheduler/engine/router behaviour under
mixed-shape traffic, banked placement equivalence, plus
kernel-vs-reference routing parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (ExpertRegistry, MatcherConfig, build_matcher,
                        train_bank)
from repro.core.autoencoder import bank_scores
from repro.data import load_benchmark
from repro.models import build_model
from repro.serve import (BankMember, BankedEngine, ExpertEngine, Request,
                         Response, RoutedServer, bucket_for, make_buckets,
                         plan_placement)
from repro.serve.router import Router

# deterministic grid strategies (always the fallback module: the
# equivalence test samples explicitly via .sample(rng), which the real
# hypothesis API does not expose)
from _prop import strategies as grid_st


@pytest.fixture(scope="module")
def bench():
    return load_benchmark(names=["mnist", "har"], n_per_dataset=400, seed=0)


@pytest.fixture(scope="module")
def matcher(bench):
    names = list(bench)
    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=12, batch_size=64)
    cents = [(bench[n]["server"][0], bench[n]["server"][1]) for n in names]
    return build_matcher(aes, names, cents), names


def _engine(seed=0, max_len=64):
    cfg = get_config("smollm-135m").reduced(name=f"eng-{seed}")
    model = build_model(cfg)
    return ExpertEngine(model, model.init(jax.random.PRNGKey(seed)),
                        max_len=max_len)


def _server(matcher, max_batch=4):
    m, names = matcher
    reg = ExpertRegistry()
    for i, n in enumerate(names):
        reg.add(n, _engine(seed=i))
    return RoutedServer(m, reg, max_batch=max_batch), names


# -- buckets ----------------------------------------------------------------


def test_bucket_ladder():
    assert make_buckets(8, 64) == (8, 16, 32, 64)
    assert make_buckets(1, 12) == (1, 2, 4, 8, 12)
    assert bucket_for(3, (4, 8)) == 4
    assert bucket_for(9, (4, 8)) == 8  # clamps to largest


def test_make_buckets_validates_inputs():
    """lo > hi used to silently return (hi,), so ExpertEngine(max_len=4,
    min_len_bucket=8) built a ladder that ignored min_len_bucket."""
    assert make_buckets(8, 8) == (8,)
    assert make_buckets(3, 3) == (3,)
    with pytest.raises(ValueError):
        make_buckets(8, 4)
    with pytest.raises(ValueError):
        make_buckets(0, 4)
    with pytest.raises(ValueError):
        make_buckets(-2, -1)
    cfg = get_config("smollm-135m").reduced(name="buckets-smoke")
    model = build_model(cfg)
    with pytest.raises(ValueError):
        ExpertEngine(model, None, max_len=4, min_len_bucket=8)
    assert bucket_for(1, (4, 8)) == 4
    assert bucket_for(8, (4, 8)) == 8  # exact hit picks its own bucket


# -- engine -----------------------------------------------------------------


def test_admit_rejects_empty_micro_batch_and_generate_handles_zero_rows():
    """Regression: a B=0 admit crashed with a bare ValueError escaping
    from max() deep inside padding; generate() on zero rows crashed the
    same way. Empty admits are now rejected loudly and zero-row
    generate returns an empty (0, max_new) array."""
    eng = _engine(seed=13, max_len=32)
    with pytest.raises(ValueError, match="empty micro-batch"):
        eng.admit([], [], [])
    out = eng.generate(np.zeros((0, 5), np.int32), 4)
    assert out.shape == (0, 4)
    assert out.dtype == np.int32
    assert eng.n_active == 0 and not eng.has_pending
    # the engine still serves normally afterwards
    got = eng.generate(np.arange(6, dtype=np.int32)[None, :], 2)
    assert got.shape == (1, 2)


def test_compile_counters_count_executables_not_wrappers():
    """EngineStats.prefill_compiles/decode_compiles must report real
    XLA executables (per-wrapper _cache_size sums), not jit-wrapper
    creations: a wrapper that exists but never ran holds no executable,
    and a silently recompiling wrapper would count per compile."""
    from repro.serve.core import COMPILE_COUNTER_EXACT, _wrapper_compiles
    if not COMPILE_COUNTER_EXACT:
        pytest.skip("this jax build lacks jit._cache_size(); counters "
                    "degrade to one-per-wrapper (flagged, not silent)")
    eng = _engine(seed=14, max_len=32)
    # wrapper created but never called -> no executable yet (the old
    # counter charged a compile at wrapper creation)
    eng.core._prefill_fn(1, 8)
    assert len(eng.core._prefill_fns) == 1
    assert eng.stats.prefill_compiles == 0
    rng = np.random.default_rng(0)
    eng.admit([0], [rng.integers(0, 50, 5)], [2])
    assert eng.stats.prefill_compiles == 1
    assert eng.stats.decode_compiles == 0      # no decode ran yet
    eng.tick()
    assert eng.stats.decode_compiles == 1
    # same-bucket traffic mints no new executable
    eng.admit([1], [rng.integers(0, 50, 6)], [1])
    assert eng.stats.prefill_compiles == 1
    # a new length bucket does
    eng.admit([2], [rng.integers(0, 50, 20)], [1])
    assert eng.stats.prefill_compiles == 2
    # the counter is exactly the sum over wrappers of real cache sizes
    assert eng.stats.prefill_compiles == sum(
        _wrapper_compiles(f) for f in eng.core._prefill_fns.values())
    while eng.n_active:
        eng.tick()
    eng.poll()


def test_engine_rows_finish_independently():
    """A row with small max_new is harvested before its group retires."""
    eng = _engine()
    rng = np.random.default_rng(0)
    eng.admit([7, 8], [rng.integers(0, 50, 5), rng.integers(0, 50, 5)],
              max_new=[1, 6])
    early = dict(eng.poll())
    assert 7 in early and early[7].shape == (1,)   # done at prefill
    assert 8 not in early
    while eng.n_active:
        eng.tick()
    late = dict(eng.poll())
    assert late[8].shape == (6,)


def test_engine_generate_matches_seed_contract():
    eng = _engine()
    toks = np.random.default_rng(1).integers(0, 50, size=(3, 9))
    out = eng.generate(toks, 5)
    assert out.shape == (3, 5)
    assert out.dtype == np.int32


def test_generate_does_not_steal_scheduler_rows():
    """Regression: generate() used to admit rows under uids 0..B-1 and
    drain poll() wholesale — colliding with scheduler-owned uids and
    silently consuming their finished rows."""
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 50, 6), rng.integers(0, 50, 4)]
    gen_toks = rng.integers(0, 50, size=(2, 5))

    # reference: the scheduler-owned rows served on a pristine engine
    ref = _engine(seed=3)
    ref.admit([0, 1], prompts, max_new=[3, 4])
    while ref.n_active:
        ref.tick()
    want = dict(ref.poll())

    # same engine params, but generate() interleaves with the admitted
    # group mid-flight — scheduler uids 0..1 overlap generate's rows
    eng = _engine(seed=3)
    eng.admit([0, 1], prompts, max_new=[3, 4])
    eng.tick()
    out = eng.generate(gen_toks, 2)
    assert out.shape == (2, 2)
    while eng.n_active:
        eng.tick()
    got = dict(eng.poll())
    assert set(got) == {0, 1}, "scheduler rows were stolen by generate()"
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])

    # and generate()'s own output matches a non-interleaved call
    ref2 = _engine(seed=3)
    np.testing.assert_array_equal(out, ref2.generate(gen_toks, 2))


def test_drain_delivers_rows_finished_during_generate(matcher, bench):
    """Regression: generate() interleaved mid-decode can tick a
    scheduler group to completion and re-queue its rows; has_work must
    then still report pending output or drain() strands the response."""
    srv, names = _server(matcher)
    x, _ = bench[names[0]]["client_a"]
    srv.submit([Request(uid=7, features=x[0], prompt=np.arange(5),
                        max_new_tokens=3)])
    srv.step()                      # admitted, still decoding
    # find the engine serving uid 7 and run a long generate() on it
    sched = srv.scheduler
    eng = next(srv.registry[e].backend for e in range(len(srv.registry))
               if srv.registry[e].backend.n_active)
    eng.generate(np.arange(4)[None, :], 8)
    assert sched.has_work, "finished-but-unpolled rows must keep work"
    got = sched.drain()
    assert [r.uid for r in got] == [7]
    assert got[0].tokens.shape == (3,)
    assert not sched._meta


# -- routed server end to end ----------------------------------------------


def test_uid_mapping_out_of_order(matcher, bench):
    """Responses must map to the right uid even though execution order is
    grouped per expert / length bucket, not arrival order."""
    srv, names = _server(matcher)
    rng = np.random.default_rng(2)
    reqs, truth = [], {}
    # interleave experts and shapes so per-expert grouping reorders rows
    for uid in range(24):
        n = names[uid % 2]
        x, _ = bench[n]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[uid],
            prompt=rng.integers(0, 100, size=int(rng.integers(2, 40))),
            max_new_tokens=int(rng.integers(1, 9))))
        truth[uid] = n
    resps = srv.serve(reqs)
    assert [r.uid for r in resps] == [q.uid for q in reqs]
    acc = np.mean([r.expert == truth[r.uid] for r in resps])
    assert acc > 0.8
    for r, q in zip(resps, reqs):
        assert r.tokens.shape == (q.max_new_tokens,)
        assert r.fine_class >= 0
        assert r.coarse_scores is not None


def test_jit_cache_bounded_across_50_mixed_shape_requests(matcher, bench):
    """50 requests with ~unique (prompt len, max_new) combos must compile
    a bounded executable set: buckets, not request shapes, key the cache."""
    srv, names = _server(matcher)
    rng = np.random.default_rng(3)
    reqs = []
    for uid in range(50):
        n = names[uid % 2]
        x, _ = bench[n]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[uid % 100],
            prompt=rng.integers(0, 100, size=1 + (uid * 7) % 60),
            max_new_tokens=1 + uid % 12))
    resps = srv.serve(reqs)
    assert len(resps) == 50
    for e in range(len(srv.registry)):
        st = srv.registry[e].backend.stats
        n_len = len(srv.registry[e].backend.len_buckets)
        n_bat = len(srv.registry[e].backend.batch_buckets)
        assert st.prefill_compiles <= n_len * n_bat
        assert st.decode_compiles <= n_bat
        # the practical bound the ISSUE cares about: far fewer distinct
        # executables than distinct request shapes
        assert st.jit_cache_entries <= 20, st
    # and replaying the identical traffic compiles nothing new
    before = [srv.registry[e].backend.stats.jit_cache_entries
              for e in range(len(srv.registry))]
    srv.serve(reqs)
    after = [srv.registry[e].backend.stats.jit_cache_entries
             for e in range(len(srv.registry))]
    assert before == after


def test_continuous_batching_coalesces_across_submits(matcher, bench):
    """Requests from separate submit() calls join one micro-batch."""
    srv, names = _server(matcher, max_batch=8)
    x, _ = bench[names[0]]["client_a"]
    rng = np.random.default_rng(4)
    mk = lambda uid: Request(uid=uid, features=x[0],
                             prompt=rng.integers(0, 100, size=10),
                             max_new_tokens=2)
    srv.submit([mk(0), mk(1)])
    srv.submit([mk(2), mk(3)])          # second call, same expert+bucket
    while srv.scheduler.has_work:
        srv.step()
    eng = srv.registry[0].backend
    assert eng.stats.prefill_calls == 1  # one coalesced micro-batch
    assert eng.stats.rows_served == 4


def test_backpressure_prefix_admission(matcher, bench):
    srv, names = _server(matcher)
    srv.scheduler.config.max_queue = 3
    x, _ = bench[names[0]]["client_a"]
    reqs = [Request(uid=u, features=x[u], prompt=np.arange(5),
                    max_new_tokens=1) for u in range(6)]
    assert srv.submit(reqs) == 3         # prefix admitted, tail rejected
    assert srv.scheduler.stats.rejected == 3
    got, todo = {}, reqs[3:]             # resubmit only the rejected tail
    while todo or srv.scheduler.has_work:
        if todo:
            todo = todo[srv.scheduler.submit(todo):]
        for r in srv.step():
            got[r.uid] = r
    assert sorted(got) == list(range(6))


def test_sparse_bucket_age_promotion_prevents_starvation(matcher, bench):
    """Regression: admission always popped the fullest length bucket, so
    under sustained traffic concentrated in one bucket a request parked
    in a sparse bucket starved until the flood ended."""
    srv, names = _server(matcher, max_batch=4)
    srv.scheduler.config.promote_after = 2
    x, _ = bench[names[0]]["client_a"]
    rng = np.random.default_rng(6)
    # one long-prompt request lands alone in the 32-bucket...
    srv.submit([Request(uid=0, features=x[0],
                        prompt=rng.integers(0, 100, size=30),
                        max_new_tokens=1)])
    # ...while a sustained flood keeps the 8-bucket the fullest forever
    done_during_flood = set()
    uid = 1
    for _ in range(10):
        srv.submit([Request(uid=uid + k, features=x[0],
                            prompt=rng.integers(0, 100, size=7),
                            max_new_tokens=1) for k in range(4)])
        uid += 4
        for r in srv.step():
            done_during_flood.add(r.uid)
    assert 0 in done_during_flood, \
        "sparse-bucket request starved through 10 flooded rounds"
    assert srv.scheduler.stats.promotions >= 1
    # drain the rest; nothing is lost or duplicated
    rest = {r.uid for r in srv.scheduler.drain()}
    assert done_during_flood | rest == set(range(uid))
    # skip counters are pruned once their buckets drain (no lifetime
    # growth, which matters for legacy backends keyed by raw lengths)
    assert not srv.scheduler._skips


# -- router -----------------------------------------------------------------


def test_router_fingerprint_cache_consistency(matcher, bench):
    m, names = matcher
    router = Router(m)
    x, _ = bench[names[0]]["client_a"]
    r1 = router.route(x[:16])
    assert r1.cache_hits == 0
    r2 = router.route(x[:16])
    assert r2.cache_hits == 16
    np.testing.assert_array_equal(r1.coarse, r2.coarse)
    np.testing.assert_array_equal(r1.fine, r2.fine)
    np.testing.assert_allclose(r1.coarse_score, r2.coarse_score)


def test_max_batch_above_engine_bucket_is_capped(matcher, bench):
    """Scheduler max_batch larger than the engine's biggest batch bucket
    must split micro-batches instead of crashing admit()."""
    srv, names = _server(matcher, max_batch=32)
    x, _ = bench[names[0]]["client_a"]
    reqs = [Request(uid=u, features=x[0], prompt=np.arange(6),
                    max_new_tokens=1) for u in range(20)]
    resps = srv.serve(reqs)
    assert len(resps) == 20
    assert srv.registry[0].backend.stats.prefill_calls >= 2  # split


def test_none_backend_completes_and_uid_is_reusable(matcher, bench):
    m, names = matcher
    from repro.core import ExpertRegistry
    reg = ExpertRegistry()
    for n in names:
        reg.add(n, None)  # no engines at all
    srv = RoutedServer(m, reg)
    x, _ = bench[names[0]]["client_a"]
    req = Request(uid=1, features=x[0], prompt=np.arange(4),
                  max_new_tokens=3)
    r1 = srv.serve([req])
    assert r1[0].tokens.shape == (3,) and not r1[0].tokens.any()
    r2 = srv.serve([req])  # uid free again after completion
    assert r2[0].uid == 1
    assert not srv.scheduler._meta  # no in-flight leak


def test_router_chunks_oversized_batches(matcher, bench):
    """Batches beyond the largest row bucket are routed in chunks and
    still produce reference-identical decisions."""
    m, names = matcher
    small = Router(m, max_rows=16)
    ref = Router(m)
    x = bench[names[0]]["client_a"][0][:40]   # 40 rows > max_rows=16
    got = small.route(x)
    want = ref.route(x)
    np.testing.assert_array_equal(got.coarse, want.coarse)
    np.testing.assert_array_equal(got.fine, want.fine)


def test_router_lru_eviction(matcher, bench):
    m, names = matcher
    router = Router(m, cache_size=8)
    x, _ = bench[names[0]]["client_a"]
    router.route(x[:32])
    assert len(router._lru) == 8


def test_router_lru_stores_copies_not_chunk_views(matcher, bench):
    """Regression: cached (coarse, score) rows were *views* into each
    routed chunk's full (rows, top_k) arrays, pinning every chunk in
    memory for the LRU entry's lifetime. A full cache must hold only
    O(top_k)-sized owned values."""
    m, names = matcher
    router = Router(m, cache_size=64)
    xs = np.concatenate([bench[n]["client_a"][0][:24] for n in names])
    router.route(xs)
    assert len(router._lru) > 0
    top_k = m.config.top_k
    for c, s, f in router._lru.values():
        assert c.base is None and s.base is None, \
            "LRU entry is a view pinning its whole routed chunk"
        assert c.nbytes <= top_k * 8 and s.nbytes <= top_k * 8
        assert isinstance(f, int)
    # cached decisions still replay exactly
    r1 = router.route(xs[:8])
    assert r1.cache_hits == 8


# -- sharded expert placement ------------------------------------------------


def _registries(matcher, seeds=(0, 1), max_len=64):
    """Two registries with *identical* engine params: one left per-engine,
    one to be banked by plan_placement."""
    m, names = matcher
    cfg = get_config("smollm-135m").reduced(name="placed")
    model = build_model(cfg)
    params = [model.init(jax.random.PRNGKey(s)) for s in seeds]
    regs = []
    for _ in range(2):
        reg = ExpertRegistry()
        for n, p in zip(names, params):
            reg.add(n, ExpertEngine(model, p, max_len=max_len))
        regs.append(reg)
    return regs


def test_plan_placement_banks_homogeneous_experts(matcher):
    m, names = matcher
    _, reg = _registries(matcher)
    # add a heterogeneous third entry: must stay a singleton shard
    cfg = get_config("smollm-135m").reduced(name="odd", d_model=64)
    odd = build_model(cfg)
    reg.add("odd", ExpertEngine(odd, odd.init(jax.random.PRNGKey(9)),
                                max_len=64))
    plan = plan_placement(reg)
    banked = [s for s in plan.shards if s.banked]
    solo = [s for s in plan.shards if not s.banked]
    assert len(banked) == 1 and banked[0].experts == (0, 1)
    assert len(solo) == 1 and solo[0].experts == (2,)
    assert plan.shard_of == {0: banked[0].sid, 1: banked[0].sid,
                             2: solo[0].sid}
    # registry entries were rebound to BankMember handles
    for e in (0, 1):
        be = reg[e].backend
        assert isinstance(be, BankMember)
        assert be.pad_shape(3, 9) == (4, 16)
    assert isinstance(reg[2].backend, ExpertEngine)
    bank = banked[0].bank
    assert isinstance(bank, BankedEngine) and bank.n_experts == 2


def test_dispatch_moe_experts_stay_singleton(matcher):
    """Capacity-dispatch MoE outputs depend on the padded batch size
    (capacity ~ total tokens), so banking them would break the
    token-identical contract — the planner must leave them solo."""
    _, reg = _registries(matcher)
    cfg = get_config("mixtral-8x22b").reduced(name="moe-pair")
    assert cfg.n_experts and cfg.moe_impl == "dispatch"
    moe = build_model(cfg)
    for i in (0, 1):
        reg.add(f"moe{i}", ExpertEngine(
            moe, moe.init(jax.random.PRNGKey(20 + i)), max_len=64))
    plan = plan_placement(reg)
    banked = [s for s in plan.shards if s.banked]
    assert len(banked) == 1 and banked[0].experts == (0, 1)
    solo_experts = {s.experts[0] for s in plan.shards if not s.banked}
    assert solo_experts == {2, 3}
    assert isinstance(reg[2].backend, ExpertEngine)


def test_forgotten_placement_plan_fails_fast(matcher):
    """plan_placement rebinds registry backends; wiring that registry
    into a server *without* the plan must raise up front, not crash
    deep inside admission at serve time."""
    m, names = matcher
    _, reg = _registries(matcher)
    plan = plan_placement(reg)
    with pytest.raises(ValueError, match="placement"):
        RoutedServer(m, reg)
    with pytest.raises(ValueError, match="already bank-placed"):
        plan_placement(reg)          # re-planning a planned registry
    # and a stale plan paired with a different registry fails fast too
    _, other = _registries(matcher)
    other_plan = plan_placement(other)
    del other_plan
    with pytest.raises(ValueError, match="does not match registry"):
        RoutedServer(m, other, placement=plan)
    # a registry grown after planning is uncovered -> fail fast, not hang
    from repro.serve import Scheduler
    reg.add("late", None)
    with pytest.raises(ValueError, match="does not cover"):
        Scheduler(None, reg, placement=plan)


def test_banked_jit_cache_is_per_bank_not_per_expert(matcher, bench):
    """The bank's executable count is bounded by its own bucket ladders
    *total* — co-locating K experts must not multiply compiles by K."""
    m, names = matcher
    _, reg = _registries(matcher)
    plan = plan_placement(reg)
    srv = RoutedServer(m, reg, max_batch=4, placement=plan)
    rng = np.random.default_rng(8)
    reqs = []
    for uid in range(30):
        n = names[uid % 2]
        x, _ = bench[n]["client_a"]
        reqs.append(Request(uid=uid, features=x[uid % 80],
                            prompt=rng.integers(0, 100,
                                                size=1 + (uid * 5) % 50),
                            max_new_tokens=1 + uid % 6))
    resps = srv.serve(reqs)
    assert len(resps) == 30
    bank = plan.shards[0].bank
    n_len, n_bat = len(bank.len_buckets), len(bank.batch_buckets)
    assert bank.stats.prefill_compiles <= n_len * n_bat
    assert bank.stats.decode_compiles <= n_bat
    # replaying identical traffic compiles nothing new
    before = bank.stats.jit_cache_entries
    srv.serve([Request(uid=100 + r.uid, features=reqs[i].features,
                       prompt=reqs[i].prompt,
                       max_new_tokens=reqs[i].max_new_tokens)
               for i, r in enumerate(resps)])
    assert bank.stats.jit_cache_entries == before


def test_banked_matches_per_engine_token_identical(matcher, bench):
    """Equivalence: the banked placement must produce token-identical
    responses to the per-engine path on the same request stream —
    property-style over the deterministic _prop grids."""
    m, names = matcher
    reg_ref, reg_bank = _registries(matcher)
    # cross-executor on top of cross-placement: the per-engine reference
    # runs the blocking serial dispatch, the banked server the default
    # overlapped one — tokens must still be identical
    srv_ref = RoutedServer(m, reg_ref, max_batch=4, executor="serial")
    plan = plan_placement(reg_bank)
    assert len([s for s in plan.shards if s.banked]) == 1
    srv_bank = RoutedServer(m, reg_bank, max_batch=4, placement=plan,
                            executor="overlapped")

    n_req = grid_st.integers(3, 8)
    plen = grid_st.integers(1, 40)
    mnew = grid_st.integers(1, 6)
    rng = np.random.default_rng(0xE7)
    uid = 0
    for _ in range(6):   # six property examples over the grid
        reqs = []
        for _ in range(n_req.sample(rng)):
            n = names[uid % 2]
            x, _ = bench[n]["client_a"]
            reqs.append(Request(
                uid=uid, features=x[uid % 60],
                prompt=rng.integers(0, 100, size=plen.sample(rng)),
                max_new_tokens=mnew.sample(rng)))
            uid += 1
        got_ref = srv_ref.serve(reqs)
        got_bank = srv_bank.serve(reqs)
        for a, b in zip(got_ref, got_bank):
            assert a.uid == b.uid
            assert a.expert == b.expert
            assert a.fine_class == b.fine_class
            np.testing.assert_array_equal(a.tokens, b.tokens)
            # shard ids demux through the placement plan; the unplaced
            # server falls back to one implicit shard per expert
            assert b.shard == plan.shard_of[reg_bank.names.index(b.expert)]
            assert a.shard == reg_ref.names.index(a.expert)


# -- unified core & async dispatch -------------------------------------------


def test_engines_are_shims_over_one_core(matcher):
    """ExpertEngine and BankedEngine must share EngineCore (no parallel
    residency/bucketing/harvest implementations kept aligned by test)."""
    from repro.serve import EngineCore
    _, reg = _registries(matcher)
    solo = reg[0].backend
    assert isinstance(solo.core, EngineCore)
    assert solo.core.n_experts == 1
    plan = plan_placement(reg)
    bank = plan.shards[0].bank
    assert isinstance(bank.core, EngineCore)
    assert bank.core.n_experts == 2
    assert type(solo.core) is type(bank.core)
    # neither shim re-implements the machinery: tick/harvest/poll resolve
    # to the one core
    for shim in (solo, bank):
        for meth in ("tick", "harvest", "poll"):
            assert hasattr(shim.core, meth)


def test_deferred_dispatch_keeps_tokens_on_device_until_harvest():
    """defer=True must enqueue only: emitted planes stay device buffers
    (no host block) until harvest() moves them in one batched transfer."""
    import jax as _jax
    eng = _engine(seed=15, max_len=32)
    rng = np.random.default_rng(1)
    eng.admit([1, 2], [rng.integers(0, 50, 5), rng.integers(0, 50, 4)],
              [1, 3], defer=True)
    assert eng.poll() == [] and eng.n_active == 1
    w = eng.core._active[0]
    assert isinstance(w.tok, _jax.Array)
    assert isinstance(w.emitted[0], _jax.Array) and w.n_host == 0
    assert eng.stats.host_blocks == 0
    eng.harvest()                      # one batched transfer
    assert eng.stats.host_blocks == 1
    assert dict(eng.poll())[1].shape == (1,)
    eng.tick(defer=True)
    eng.tick(defer=True)
    assert eng.stats.host_blocks == 1  # decode ticks never blocked
    assert all(isinstance(p, _jax.Array) for p in w.emitted[w.n_host:])
    eng.harvest()
    assert eng.stats.host_blocks == 2  # one transfer for both planes
    assert dict(eng.poll())[2].shape == (3,)
    assert eng.n_active == 0


def _scenario_rounds(scenario, names, bench, rng, n_req, uid0):
    """Per-round request batches emulating the bench's traffic mixes:
    uniform (spread over experts), skewed (80% on expert 0), bursty
    (everything in one burst, then idle rounds)."""
    reqs = []
    for k in range(n_req):
        if scenario == "skewed":
            e = 0 if rng.random() < 0.8 else int(rng.integers(
                1, len(names)))
        else:
            e = int(rng.integers(len(names)))
        n = names[e]
        x, _ = bench[n]["client_a"]
        reqs.append(Request(
            uid=uid0 + k, features=x[int(rng.integers(60))],
            prompt=rng.integers(0, 100, size=int(rng.integers(1, 40))),
            max_new_tokens=int(rng.integers(1, 7))))
    if scenario == "bursty":
        return [reqs, [], []]
    return [reqs[i:i + 3] for i in range(0, len(reqs), 3)]


def _run_rounds(srv, rounds, gen_at=None):
    """Drive submit/step round by round; optionally interleave a
    blocking generate() on expert 0's engine mid-stream."""
    got, gen_out = {}, None
    for k, batch in enumerate(rounds):
        if batch:
            srv.submit(batch)
        if gen_at is not None and k == gen_at:
            gen_out = srv.registry[0].backend.generate(
                (np.arange(6)[None, :] % 50).astype(np.int32), 4)
        for r in srv.step():
            got[r.uid] = r
    for r in srv.scheduler.drain():
        got[r.uid] = r
    return got, gen_out


def test_overlapped_token_identical_to_serial_on_scenarios(matcher, bench):
    """The overlapped executor must be token-identical to the serial
    reference on the bench's uniform/skewed/bursty traffic shapes
    (property grid over prompt lengths / max_new / expert mixes), with
    an interleaved generate() call mid-stream — while issuing strictly
    fewer host-blocking syncs."""
    m, names = matcher
    reg_s, reg_o = _registries(matcher)   # identical engine params
    srv_s = RoutedServer(m, reg_s, max_batch=4, executor="serial")
    srv_o = RoutedServer(m, reg_o, max_batch=4, executor="overlapped")
    assert srv_s.scheduler.executor.name == "serial"
    assert srv_o.scheduler.executor.name == "overlapped"
    blocks = lambda reg: sum(reg[e].backend.stats.host_blocks
                             for e in range(len(reg)))
    tokens = lambda reg: sum(reg[e].backend.stats.tokens_generated
                             for e in range(len(reg)))
    uid0 = 0
    for scenario in ("uniform", "skewed", "bursty"):
        rng = np.random.default_rng(0xB0 + uid0)
        rounds = _scenario_rounds(scenario, names, bench, rng, 9, uid0)
        uid0 += 9
        got_s, gen_s = _run_rounds(srv_s, rounds, gen_at=1)
        got_o, gen_o = _run_rounds(srv_o, rounds, gen_at=1)
        assert set(got_s) == set(got_o) and len(got_s) == 9, scenario
        for uid in got_s:
            a, b = got_s[uid], got_o[uid]
            assert a.expert == b.expert, (scenario, uid)
            assert a.fine_class == b.fine_class
            np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_array_equal(gen_s, gen_o)
    assert tokens(reg_s) == tokens(reg_o)
    assert blocks(reg_o) < blocks(reg_s), \
        "overlapped must host-block strictly less than serial"


def test_overlapped_host_blocks_bounded_per_step(matcher, bench):
    """The acceptance invariant: with the overlapped executor a
    scheduler step blocks the host at most once per resident wave
    (waves active before the step + waves admitted by it)."""
    m, names = matcher
    _, reg = _registries(matcher)
    srv = RoutedServer(m, reg, max_batch=4, executor="overlapped")
    sched = srv.scheduler
    blocks = lambda: sum(reg[e].backend.stats.host_blocks
                         for e in range(len(reg)))
    active = lambda: sum(reg[e].backend.n_active
                         for e in range(len(reg)))
    rng = np.random.default_rng(0xC1)
    uid, steps = 0, 0
    while uid < 18 or sched.has_work:
        if uid < 18 and steps % 2 == 0:
            reqs = []
            for k in range(3):
                n = names[(uid + k) % 2]
                x, _ = bench[n]["client_a"]
                reqs.append(Request(
                    uid=uid + k, features=x[(uid + k) % 60],
                    prompt=rng.integers(0, 100,
                                        size=int(rng.integers(2, 30))),
                    max_new_tokens=int(rng.integers(1, 6))))
            uid += srv.submit(reqs)
        b0, a0, n0 = blocks(), active(), sched.stats.batches
        srv.step()
        admitted = sched.stats.batches - n0
        assert blocks() - b0 <= a0 + admitted, \
            (f"step {steps}: {blocks() - b0} host blocks for "
             f"{a0} resident + {admitted} admitted waves")
        steps += 1
    assert not sched._meta


# -- kernel vs reference parity --------------------------------------------


def test_coarse_kernel_parity_with_trained_bn_state(matcher, bench):
    """use_kernel=True must score with the real BatchNorm statistics:
    on a trained AE bank (non-trivial BN state) the Pallas path and the
    reference bank_scores must agree (regression for the dropped
    bank_states bug)."""
    m, names = matcher
    st = np.asarray(m.bank_states["mean"])
    assert np.abs(st).max() > 1e-3, "BN state is trivial; test is vacuous"
    x, _ = bench[names[0]]["client_a"]
    x = jnp.asarray(x[:64])
    from repro.core.matcher import ExpertMatcher
    km = ExpertMatcher(m.bank_params, m.bank_states, names, m.centroids,
                       m.centroid_mask, MatcherConfig(use_kernel=True))
    got = np.asarray(km.coarse_scores(x))
    want = np.asarray(bank_scores(m.bank_params, m.bank_states, x))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)
    # and the routing decision is identical
    np.testing.assert_array_equal(np.asarray(km.assign_coarse(x)),
                                  np.asarray(m.assign_coarse(x)))


def test_fine_kernel_parity_with_reference(matcher, bench):
    """Router's grouped Pallas cosine path == matcher.assign_fine."""
    m, names = matcher
    router = Router(m, use_fine_kernel=True)
    ref_router = Router(m, use_fine_kernel=False)
    xs = np.concatenate([bench[n]["client_a"][0][:20] for n in names])
    got = router.route(xs)
    want = ref_router.route(xs)
    np.testing.assert_array_equal(got.coarse, want.coarse)
    np.testing.assert_array_equal(got.fine, want.fine)
    # cross-check against the matcher's own fine path
    direct = np.asarray(m.assign_fine(
        jnp.asarray(xs), jnp.asarray(got.coarse[:, 0])))
    np.testing.assert_array_equal(got.fine, direct)
