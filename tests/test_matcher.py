"""Unit + property tests for the ExpertMatcher core (the paper's method)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis: seeded fallback grid
    from _prop import given, settings, strategies as st

from repro.core import (MatcherConfig, build_matcher, init_ae, recon_mse,
                        stack_bank, train_ae)
from repro.core.autoencoder import bank_scores
from repro.core.matcher import _cos


def _mini_bank(K=3, in_dim=32, hid=8, seed=0):
    aes = []
    for k in range(K):
        aes.append(init_ae(jax.random.PRNGKey(seed + k), in_dim, hid))
    return aes


def test_bank_scores_shape_and_finite():
    aes = _mini_bank()
    bp, bs = stack_bank(aes)
    x = jax.random.uniform(jax.random.PRNGKey(9), (17, 32))
    s = bank_scores(bp, bs, x)
    assert s.shape == (17, 3)
    assert np.isfinite(np.asarray(s)).all()
    assert (np.asarray(s) >= 0).all()  # MSE is non-negative


def test_matcher_coarse_matches_bank_argmin():
    aes = _mini_bank(K=4)
    m = build_matcher(aes, [f"d{i}" for i in range(4)])
    x = jax.random.uniform(jax.random.PRNGKey(1), (11, 32))
    s = m.coarse_scores(x)
    assert np.array_equal(np.asarray(m.assign_coarse(x)),
                          np.asarray(s).argmin(-1))


def test_topk_fusion_ordering():
    aes = _mini_bank(K=5)
    m = build_matcher(aes, list("abcde"), config=MatcherConfig(top_k=3))
    x = jax.random.uniform(jax.random.PRNGKey(2), (7, 32))
    idx, scores = m.assign_coarse_topk(x)
    assert idx.shape == (7, 3)
    s = np.asarray(scores)
    assert (np.diff(s, axis=1) >= -1e-6).all()  # ascending MSE
    assert np.array_equal(np.asarray(idx[:, 0]),
                          np.asarray(m.assign_coarse(x)))


def test_bank_permutation_equivariance():
    """Permuting the AE bank permutes score columns — no hidden state ties
    scores to bank order (the paper's modularity property)."""
    aes = _mini_bank(K=4)
    x = jax.random.uniform(jax.random.PRNGKey(3), (9, 32))
    m1 = build_matcher(aes, list("abcd"))
    perm = [2, 0, 3, 1]
    m2 = build_matcher([aes[p] for p in perm], list("cadb"))
    s1 = np.asarray(m1.coarse_scores(x))
    s2 = np.asarray(m2.coarse_scores(x))
    np.testing.assert_allclose(s1[:, perm], s2, rtol=1e-6)


def test_fine_assignment_prefers_own_centroid():
    """Samples clustered near distinct prototypes route to their class."""
    rng = np.random.default_rng(0)
    protos = rng.normal(size=(3, 32)).astype(np.float32)
    xs = np.concatenate([protos[i] + 0.05 * rng.normal(
        size=(30, 32)).astype(np.float32) for i in range(3)])
    ys = np.repeat(np.arange(3), 30)
    ae = train_ae(xs, epochs=30, batch_size=32, in_dim=32, hid_dim=16)
    m = build_matcher([ae], ["toy"], centroid_data=[(xs, ys)])
    fine = np.asarray(m.assign_fine(jnp.asarray(xs),
                                    jnp.zeros(len(xs), jnp.int32)))
    assert (fine == ys).mean() > 0.9


def test_trained_bank_separates_two_distributions():
    rng = np.random.default_rng(1)
    a = rng.uniform(0, 1, size=(400, 32)).astype(np.float32) ** 3  # skewed
    b = np.tile(np.linspace(0, 1, 32, dtype=np.float32), (400, 1)) \
        + 0.1 * rng.normal(size=(400, 32)).astype(np.float32)
    ae_a = train_ae(a[:300], epochs=25, batch_size=64, in_dim=32, hid_dim=8)
    ae_b = train_ae(b[:300], epochs=25, batch_size=64, in_dim=32, hid_dim=8)
    m = build_matcher([ae_a, ae_b], ["a", "b"])
    pa = np.asarray(m.assign_coarse(jnp.asarray(a[300:])))
    pb = np.asarray(m.assign_coarse(jnp.asarray(b[300:])))
    assert (pa == 0).mean() > 0.9
    assert (pb == 1).mean() > 0.9


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 16), st.integers(2, 6),
       st.floats(0.1, 10.0, allow_nan=False))
def test_mse_scale_property(b, k, scale):
    """MSE(s*x, AE(s*x)) under a *linear-ish* AE scales ~quadratically only
    if relu path unchanged; we assert the weaker, always-true property:
    scores stay finite and non-negative under input scaling."""
    aes = _mini_bank(K=k, seed=7)
    bp, bs = stack_bank(aes)
    x = jax.random.uniform(jax.random.PRNGKey(b), (b, 32)) * scale
    s = np.asarray(bank_scores(bp, bs, x))
    assert s.shape == (b, k)
    assert np.isfinite(s).all() and (s >= 0).all()


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 8), st.integers(2, 10))
def test_route_consistency_property(b, k):
    """route() must agree with its components for any bank size."""
    aes = _mini_bank(K=k, seed=3)
    cents = [(np.random.default_rng(i).normal(size=(12, 32)).astype(np.float32),
              np.random.default_rng(i).integers(0, 3, 12)) for i in range(k)]
    m = build_matcher(aes, [str(i) for i in range(k)], centroid_data=cents)
    x = jax.random.uniform(jax.random.PRNGKey(b * k), (b, 32))
    r = m.route(x)
    assert np.array_equal(np.asarray(r["coarse"][:, 0]),
                          np.asarray(m.assign_coarse(x)))
    fine = np.asarray(r["fine"])
    assert fine.shape == (b,)
    assert (fine >= 0).all() and (fine < 12).all()


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 5), st.integers(1, 7))
def test_cosine_bounds_property(b, m_):
    a = jax.random.normal(jax.random.PRNGKey(b), (b, 16))
    c = jax.random.normal(jax.random.PRNGKey(m_ + 100), (m_, 1, 16))
    sim = np.asarray(_cos(c, a[None]))
    assert (sim <= 1.0 + 1e-5).all() and (sim >= -1.0 - 1e-5).all()


def test_perfect_reconstruction_scores_zero():
    """An identity AE (W2 = pinv path) gives ~0 MSE — argmin must pick it."""
    params, state = init_ae(jax.random.PRNGKey(0), 8, 8)
    # construct an exact identity: enc = I (BN folded out), dec = I
    params = dict(params)
    params["w_enc"] = jnp.eye(8)
    params["b_enc"] = jnp.zeros(8) + 5.0  # keep relu active
    params["w_dec"] = jnp.eye(8)
    params["b_dec"] = -(jnp.zeros(8) + 5.0)
    state = {"mean": jnp.zeros(8), "var": jnp.ones(8) - 1e-5,
             "count": jnp.ones(())}
    x = jax.random.uniform(jax.random.PRNGKey(1), (5, 8))
    mse, _ = recon_mse(params, state, x)
    assert float(jnp.max(mse)) < 1e-3
