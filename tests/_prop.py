"""Minimal deterministic fallback for ``hypothesis`` property tests.

The container image does not ship ``hypothesis`` (and the assignment
forbids installing new packages), but the property tests are too valuable
to skip wholesale. This module implements the tiny subset of the
hypothesis API the suite uses — ``given``, ``settings`` and the
``integers`` / ``floats`` / ``booleans`` / ``sampled_from`` strategies —
by materialising a fixed, seeded sample of examples per test and running
the test body once per example. When the real hypothesis is available the
test modules import it instead (see their guarded imports), so this file
only defines behaviour for the degraded environment.

Not supported (not needed by this suite): shrinking, ``assume``,
composite strategies, stateful testing.

Determinism contract: this fallback is seeded (``0xE7``) and draws a
fixed example grid, so runs replay bit-identically everywhere. When the
real hypothesis *is* installed, ``tests/conftest.py`` registers a
matching "ci" profile (``derandomize=True``, no example database,
selected under the ``CI`` env var) so property runs are equally
deterministic there — the speculative differential suite relies on
this to diff exact token sequences across runs.
"""
from __future__ import annotations

import math

import numpy as np

_FALLBACK_EXAMPLES = 12  # examples per test when hypothesis is absent


class _Strategy:
    def __init__(self, sampler):
        self._sampler = sampler

    def sample(self, rng: np.random.Generator):
        return self._sampler(rng)


class strategies:  # mirrors ``hypothesis.strategies`` as a namespace
    @staticmethod
    def integers(min_value, max_value):
        lo, hi = int(min_value), int(max_value)
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    @staticmethod
    def floats(min_value, max_value, **_kw):
        lo, hi = float(min_value), float(max_value)

        def draw(rng):
            v = float(rng.uniform(lo, hi))
            return v if math.isfinite(v) else lo
        return _Strategy(draw)

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])


def settings(max_examples=None, **_kw):
    """Stand-in for ``hypothesis.settings``: honours ``max_examples``
    (capped at the fallback budget — each example typically jit-compiles
    a fresh shape, so examples are much pricier here than under real
    hypothesis); everything else is ignored."""
    def deco(fn):
        if max_examples is not None:
            fn._max_examples = min(int(max_examples), _FALLBACK_EXAMPLES)
        return fn
    return deco


def given(*strats):
    """Run the wrapped test over a fixed seeded grid of examples."""
    def deco(fn):
        # NB: deliberately no functools.wraps — pytest must see the
        # zero-arg wrapper signature, not the original one, or it treats
        # the strategy-filled parameters as missing fixtures.
        def wrapper():
            rng = np.random.default_rng(0xE7)
            n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
            for _ in range(n):
                drawn = tuple(s.sample(rng) for s in strats)
                fn(*drawn)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
