"""Optimizer, data pipeline, checkpoint, sharding-rule and HLO-analysis
substrate tests (unit + hypothesis properties)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis: seeded fallback grid
    from _prop import given, settings, strategies as st

from repro.data import (adaptive_avg_pool_1d, load_benchmark, generate,
                        server_client_split, synthetic_token_stream, to_784)
from repro.optim import adamw_init, adamw_update, cosine_warmup, step_decay
from repro.checkpoint import load_pytree, save_pytree


# -- optim ------------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    target = jnp.asarray([1.0, 1.0])

    @jax.jit
    def step(params, opt):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw_update(g, opt, params, jnp.float32(0.05))

    for _ in range(300):
        params, opt = step(params, opt)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=1e-2)


def test_adamw_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full(4, 1e6)}
    p2, _ = adamw_update(g, opt, params, jnp.float32(0.1), clip_norm=1.0)
    assert np.abs(np.asarray(p2["w"])).max() < 1.0


def test_step_decay_schedule():
    fn = step_decay(1e-2, every_steps=10)
    assert float(fn(jnp.asarray(0))) == pytest.approx(1e-2)
    assert float(fn(jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(fn(jnp.asarray(25))) == pytest.approx(1e-4)


def test_cosine_warmup_monotone_warmup():
    fn = cosine_warmup(1.0, warmup_steps=10, total_steps=100)
    vals = [float(fn(jnp.asarray(i))) for i in range(12)]
    assert all(b >= a for a, b in zip(vals[:10], vals[1:11]))
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


# -- data -------------------------------------------------------------------


def test_split_protocol_sizes_and_disjoint():
    x = np.arange(1000, dtype=np.float32)[:, None].repeat(4, 1)
    y = np.zeros(1000, np.int32)
    s = server_client_split(x, y, seed=0)
    assert len(s["server"][0]) == 500
    assert len(s["client_a"][0]) == 250
    assert len(s["client_b"][0]) == 250
    ids = [set(s[k][0][:, 0].tolist()) for k in
           ("server", "client_a", "client_b")]
    assert not (ids[0] & ids[1]) and not (ids[0] & ids[2]) \
        and not (ids[1] & ids[2])


@pytest.mark.parametrize("name", ["mnist", "stl10", "har", "reuters",
                                  "nlos", "db"])
def test_generators_shapes_and_classes(name):
    from repro.data.synthetic import SPECS
    x, y = generate(name, n=120, seed=0)
    assert len(x) == len(y) == 120
    assert int(y.max()) + 1 == SPECS[name].n_classes
    x784 = to_784(x)
    assert x784.shape == (120, 784)
    assert np.isfinite(x784).all()


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 50), st.integers(784, 3000))
def test_adaptive_pool_preserves_mean(n, d):
    x = np.random.default_rng(n).normal(size=(n, d)).astype(np.float32)
    out = adaptive_avg_pool_1d(x, 784)
    assert out.shape == (n, 784)
    np.testing.assert_allclose(out.mean(), x.mean(), atol=0.05)


def test_token_stream_structure():
    it = synthetic_token_stream(1000, 64, 4, seed=0)
    b = next(it)
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 1000).all()


# -- checkpoint -------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(5, dtype=jnp.float32)},
            "c": [jnp.ones((2, 3)), jnp.zeros((4,), jnp.int32)],
            "d": jnp.asarray(2.5)}
    save_pytree(tree, str(tmp_path / "ckpt"))
    back = load_pytree(str(tmp_path / "ckpt"))
    flat1 = jax.tree_util.tree_leaves(tree)
    flat2 = jax.tree_util.tree_leaves(back)
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- sharding rules ---------------------------------------------------------


def test_param_rules_moe_vs_dense_disambiguation():
    """Regression: stacked dense (L, D, F) must NOT match the MoE expert
    rule and shard the layer dim (cost 10x; found in dry-run debugging)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import spec_for_leaf
    mesh_shape = {"data": 16, "model": 16}
    dense = spec_for_leaf("layers/mlp/w_gate", (16, 2048, 8192), mesh_shape)
    assert dense == P(None, None, "model")
    moe = spec_for_leaf("layers/moe/w_gate", (16, 64, 2048, 1024), mesh_shape)
    assert moe == P(None, "model", None, None)  # 64 experts / 16-way axis
    moe8 = spec_for_leaf("layers/moe/w_gate", (56, 8, 6144, 16384),
                         mesh_shape)
    assert moe8 == P(None, None, None, "model")  # 8 experts -> TP fallback


def test_param_rules_divisibility_fallback():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import spec_for_leaf
    mesh_shape = {"data": 16, "model": 16}
    # vocab 92553 (odd) cannot shard over 16 -> feature dim fallback
    emb = spec_for_leaf("embed", (92553, 6144), mesh_shape)
    assert emb == P(None, "model")
    # norms always replicated
    assert spec_for_leaf("layers/ln1", (80, 8192), mesh_shape) == P(None, None)


def test_cache_specs_long_context_sequence_sharding():
    from jax.sharding import PartitionSpec as P
    import jax as _jax
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.rules import cache_specs
    mesh = make_host_mesh()
    tree = {"k": _jax.ShapeDtypeStruct((16, 1, 4096, 8, 128), jnp.bfloat16),
            "t": _jax.ShapeDtypeStruct((), jnp.int32)}
    specs = cache_specs(tree, mesh, batch_size=1)
    assert specs["t"] == P()


# -- hlo analysis -----------------------------------------------------------


def test_module_cost_expands_scan_loops():
    from repro.launch.hlo_analysis import module_cost
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)

    def unrolled(x, w):
        for i in range(8):
            x = x @ w[i]
        return x

    def scanned(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    cu = jax.jit(unrolled).lower(x, w).compile()
    cs = jax.jit(scanned).lower(x, w).compile()
    fu = module_cost(cu.as_text())["flops"]
    fs = module_cost(cs.as_text())["flops"]
    assert fu == pytest.approx(2 * 128 ** 3 * 8, rel=0.01)
    assert fs == pytest.approx(fu, rel=0.01)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 100))
def test_checkpoint_roundtrip_property(seed):
    """Random pytree shapes/dtypes survive save/load byte-exact."""
    import tempfile
    rng = np.random.default_rng(seed)
    tree = {
        "a": jnp.asarray(rng.normal(size=(rng.integers(1, 8),
                                          rng.integers(1, 8))),
                         jnp.float32),
        "b": {"c": jnp.asarray(rng.integers(0, 100, size=(5,)), jnp.int32),
              "d": [jnp.asarray(rng.normal(size=(3,)), jnp.bfloat16)]},
    }
    with tempfile.TemporaryDirectory() as d:
        save_pytree(tree, d)
        back = load_pytree(d, like=tree)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
