"""Static lockset/race pass (rules R001-R004).

Two halves, mirroring the other analysis-pass tests:

  * the real serving unit (hub / scheduler / kvcache) is clean — the
    refactored expert lifecycle honours its own THREAD_CONTRACT;
  * every rule fires on a planted synthetic unit and stays quiet on
    the matching clean variant, so the checker's teeth are themselves
    under test.

The planted units are tiny self-contained modules sharing one contract
header; ``analyze_unit`` consumes {path: source} directly, so no files
are written.
"""
import textwrap

from repro.analysis import races
from repro.analysis.races import analyze_unit

CONTRACT = textwrap.dedent('''
    THREAD_CONTRACT = {
        "lock": "_lock",
        "lock_aliases": ["_lock", "_cv"],
        "threads": {
            "scheduler": ["Hub.step"],
            "stager": ["Hub._stage_loop"],
        },
        "lock_guarded": {
            "fields": ["catalog", "_wanted"],
            "entry_fields": ["state", "params", "slot"],
            "stats_fields": ["loads"],
        },
        "queue_handoffs": ["_stage_q"],
        "single_writer": {"scheduler": ["_index"]},
        "blocking_calls": ["load_expert", "join", "sleep", "wait"],
        "publish_order": {"state": {"staged": ["params"],
                                    "resident": ["slot"]}},
    }
''')

CLEAN = CONTRACT + textwrap.dedent('''
    class Hub:
        def __init__(self):
            self._wanted = {}
            self.catalog = []
            self._index = {}

        def step(self, e):
            with self._lock:
                self._wanted[e] = True
                c = self.catalog[e]
                c.slot = e
                c.state = "resident"
            self._index[e] = 1

        def _stage_loop(self):
            job = self._stage_q.get()
            p = load_expert(job)
            with self._lock:
                c = self.catalog[job]
                c.params = p
                c.state = "staged"
                self.stats.loads += 1
                self._cv.wait(1.0)
''')


def _check(src):
    return analyze_unit({"unit/hub.py": src})


def _rules(vs):
    return sorted({v.rule for v in vs})


# -- the real unit -----------------------------------------------------


def test_repo_unit_is_clean():
    assert races.run() == []


def test_repo_contract_is_declared_and_literal():
    sources = {}
    import os
    for rel in races.DEFAULT_UNIT:
        with open(os.path.join(races.REPO_ROOT, rel)) as fh:
            sources[rel] = fh.read()
    contract, path, _ = races._find_contract(sources)
    assert path == "src/repro/serve/hub.py"
    assert contract is not None
    for key in ("lock", "threads", "lock_guarded", "single_writer",
                "queue_handoffs", "blocking_calls", "publish_order"):
        assert key in contract, key
    assert set(contract["threads"]) == {"scheduler", "stager"}


# -- planted positives / clean negatives -------------------------------


def test_clean_synthetic_unit():
    assert _check(CLEAN) == []


def test_missing_contract_is_r001():
    vs = _check("class Hub:\n    pass\n")
    assert _rules(vs) == ["R001"]
    assert "THREAD_CONTRACT" in vs[0].msg


def test_non_literal_contract_is_r001():
    vs = _check("THREAD_CONTRACT = {'lock': make_lock()}\n")
    assert _rules(vs) == ["R001"]
    assert "literal" in vs[0].msg


def test_r001_unguarded_lock_guarded_field():
    src = CLEAN.replace(
        "        self._index[e] = 1",
        "        self._index[e] = 1\n"
        "        self._wanted.pop(e, None)")
    vs = _check(src)
    assert any(v.rule == "R001" and "_wanted" in v.msg for v in vs)


def test_r001_unguarded_entry_field():
    src = CLEAN.replace(
        "        self._index[e] = 1",
        "        self._index[e] = 1\n"
        "        self.catalog[e].state = 'cold'")
    vs = _check(src)
    # the unlocked catalog access and the unlocked entry-state write
    assert any(v.rule == "R001" and "'state'" in v.msg for v in vs)


def test_r001_single_writer_reached_from_wrong_thread():
    src = CLEAN.replace(
        "            self.stats.loads += 1",
        "            self.stats.loads += 1\n"
        "            n = len(self._index)")
    vs = _check(src)
    assert any(v.rule == "R001" and "single-writer" in v.msg
               for v in vs)


def test_r001_locked_helper_called_without_lock():
    src = CLEAN.replace(
        "        self._index[e] = 1",
        "        self._index[e] = 1\n"
        "        self._drop_locked(e)") + textwrap.dedent('''
        class Hub2(Hub):
            def _drop_locked(self, e):
                pass
    ''')
    vs = _check(src)
    assert any(v.rule == "R001" and "_locked" in v.msg for v in vs)


def test_r001_shared_attr_missing_from_contract():
    src = CLEAN.replace(
        "        self._index[e] = 1",
        "        self._index[e] = 1\n"
        "        self._scratch = e").replace(
        "            self._cv.wait(1.0)",
        "            self._cv.wait(1.0)\n"
        "            x = self._scratch")
    vs = _check(src)
    assert any(v.rule == "R001" and "_scratch" in v.msg
               and "no THREAD_CONTRACT category" in v.msg for v in vs)


def test_r001_contract_drift_on_dead_entry_point():
    src = CLEAN.replace('"Hub.step"', '"Hub.step_gone"')
    vs = _check(src)
    assert any(v.rule == "R001" and "drift" in v.msg for v in vs)


def test_r002_reacquire_designated_lock():
    src = CLEAN.replace(
        "            c.state = \"resident\"",
        "            c.state = \"resident\"\n"
        "            with self._lock:\n"
        "                pass")
    vs = _check(src)
    assert any(v.rule == "R002" and "re-acquiring" in v.msg for v in vs)


def test_r002_transitive_self_deadlock():
    src = CLEAN.replace(
        "            c.state = \"resident\"",
        "            c.state = \"resident\"\n"
        "            self.helper()") + textwrap.dedent('''
        class Hub3(Hub):
            def helper(self):
                with self._lock:
                    pass
    ''')
    vs = _check(src)
    assert any(v.rule == "R002" and "transitive" in v.msg for v in vs)


def test_r002_inconsistent_lock_order():
    src = CLEAN + textwrap.dedent('''
        class Two:
            def ab(self):
                with self.a_lock:
                    with self.b_lock:
                        pass

            def ba(self):
                with self.b_lock:
                    with self.a_lock:
                        pass
    ''')
    vs = _check(src)
    assert any(v.rule == "R002" and "inconsistent lock order" in v.msg
               for v in vs)


def test_r003_blocking_io_under_lock():
    src = CLEAN.replace(
        "        p = load_expert(job)\n"
        "        with self._lock:",
        "        with self._lock:\n"
        "            p = load_expert(job)")
    vs = _check(src)
    assert any(v.rule == "R003" and "load_expert" in v.msg for v in vs)


def test_r003_condition_wait_is_exempt():
    # CLEAN already waits on self._cv (a designated-lock alias) while
    # holding the lock: a cv wait *releases* the lock, so no R003
    assert not any(v.rule == "R003" for v in _check(CLEAN))


def test_r004_publish_before_payload():
    src = CLEAN.replace(
        "            c.params = p\n"
        "            c.state = \"staged\"",
        "            c.state = \"staged\"\n"
        "            c.params = p")
    vs = _check(src)
    assert any(v.rule == "R004" and "half-constructed" in v.msg
               for v in vs)


def test_r004_publish_after_payload_cleared():
    src = CLEAN.replace(
        "            c.params = p\n"
        "            c.state = \"staged\"",
        "            c.params = None\n"
        "            c.state = \"staged\"")
    vs = _check(src)
    assert any(v.rule == "R004" and "cleared to None" in v.msg
               for v in vs)
