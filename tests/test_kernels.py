"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.expert_score import pad_to_lane


def _bank(K, D, H, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    params = {
        "w_enc": jax.random.normal(ks[0], (K, D, H)) * 0.03,
        "b_enc": jax.random.normal(ks[1], (K, H)) * 0.01,
        "bn_scale": 1.0 + jax.random.normal(ks[2], (K, H)) * 0.1,
        "bn_bias": jax.random.normal(ks[3], (K, H)) * 0.05,
        "w_dec": jax.random.normal(ks[4], (K, H, D)) * 0.03,
        "b_dec": jax.random.normal(ks[5], (K, D)) * 0.01,
    }
    states = {"mean": jax.random.normal(ks[6], (K, H)) * 0.1,
              "var": 1.0 + jax.random.uniform(ks[7], (K, H)),
              "count": jnp.ones((K,))}
    return params, states


# interpret-mode sizes are capped for tier-1 runtime: the multi-tile
# grid case (B=256 > block_m) uses the small-D bank, not the 784-dim one
@pytest.mark.parametrize("B,D,H,K", [
    (32, 784, 128, 6), (128, 512, 64, 10),
    (16, 100, 32, 3), (256, 100, 32, 3),
])
def test_expert_score_shapes(B, D, H, K):
    params, states = _bank(K, D, H, seed=B + K)
    x = jax.random.uniform(jax.random.PRNGKey(B), (B, D))
    got = np.asarray(ops.expert_score(params, x, states))
    folded = ops.fold_bank(params, states)
    Dp = pad_to_lane(D)
    xp = jnp.pad(x, ((0, 0), (0, Dp - D)))
    want = np.asarray(ref.expert_score_ref(
        xp, folded["w1"], folded["b1"], folded["w2"], folded["b2"],
        d_real=D))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_expert_score_matches_ae_bank_math():
    """Kernel == the actual matcher scoring path (BN folding is exact)."""
    from repro.core.autoencoder import bank_scores
    params, states = _bank(5, 784, 128)
    x = jax.random.uniform(jax.random.PRNGKey(7), (64, 784))
    got = np.asarray(ops.expert_score(params, x, states))
    want = np.asarray(bank_scores(params, states, x))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


@pytest.mark.parametrize("B,M,h", [(32, 10, 128), (64, 3, 64), (16, 17, 32)])
def test_cosine_scores(B, M, h):
    z = jax.random.normal(jax.random.PRNGKey(B), (B, h))
    c = jax.random.normal(jax.random.PRNGKey(M), (M, h))
    mask = (jnp.arange(M) < max(M - 2, 1)).astype(jnp.float32)
    got = np.asarray(ops.cosine_scores(z, c, mask))
    want = np.asarray(ref.cosine_scores_ref(z, c, mask))
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5, atol=1e-6)
    assert (np.isinf(got) == np.isinf(want)).all()


@pytest.mark.parametrize("B,H,KV,dh,S,win,dtype", [
    (4, 8, 2, 64, 1024, 0, jnp.float32),
    (2, 4, 4, 64, 512, 0, jnp.float32),
    (4, 8, 2, 64, 1024, 256, jnp.float32),
    (1, 16, 2, 128, 1024, 0, jnp.float32),
    (2, 8, 2, 64, 512, 0, jnp.bfloat16),
])
def test_decode_attention(B, H, KV, dh, S, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, dh), dtype)
    t = S - S // 3
    q_pos = jnp.asarray(t, jnp.int32)
    kv_pos = jnp.where(jnp.arange(S) <= t, jnp.arange(S), -1).astype(jnp.int32)
    got = np.asarray(ops.decode_attention(q, k, v, q_pos, kv_pos,
                                          window=win, block_s=256),
                     np.float32)
    want = np.asarray(ref.decode_attention_ref(q, k, v, q_pos, kv_pos,
                                               window=win), np.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_decode_attention_ring_cache_semantics():
    """Scrambled (ring) slot order must not change the result."""
    B, H, KV, dh, S = 2, 4, 2, 32, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, S, KV, dh))
    v = jax.random.normal(ks[2], (B, S, KV, dh))
    q_pos = jnp.asarray(300, jnp.int32)
    kv_pos = jnp.arange(S) + 300 - S + 1  # ring holding last S positions
    perm = np.random.default_rng(0).permutation(S)
    got1 = np.asarray(ops.decode_attention(q, k, v, q_pos,
                                           kv_pos.astype(jnp.int32),
                                           window=128, block_s=64))
    got2 = np.asarray(ops.decode_attention(
        q, k[:, perm], v[:, perm], q_pos,
        kv_pos[perm].astype(jnp.int32), window=128, block_s=64))
    np.testing.assert_allclose(got1, got2, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,H,KV,dh,page,nlp,win,dtype", [
    (3, 8, 2, 64, 8, 8, 0, jnp.float32),
    (2, 4, 4, 64, 16, 4, 0, jnp.float32),
    (3, 8, 2, 64, 8, 8, 24, jnp.float32),    # sliding window
    (1, 16, 2, 128, 8, 4, 0, jnp.float32),
    (2, 8, 2, 64, 8, 8, 0, jnp.bfloat16),
])
def test_paged_decode_attention_parity(B, H, KV, dh, page, nlp, win,
                                       dtype):
    """Paged kernel == ring kernel on the gathered dense view == jnp
    reference, through a scrambled page table with shared pages between
    rows and trash-backed (never-written) logical tail pages — the
    interpret=True Pallas path the serving kernels rely on."""
    from repro.kernels.decode_attention import paged_decode_attention_pallas
    from repro.models.attention import paged_gather
    C = nlp * page
    P1 = 3 * B * nlp + 1                       # pool + trash page
    ks = jax.random.split(jax.random.PRNGKey(C + H), 3)
    kp = jax.random.normal(ks[0], (P1, page, KV, dh), dtype)
    vp = jax.random.normal(ks[1], (P1, page, KV, dh), dtype)
    q = jax.random.normal(ks[2], (B, H, dh), dtype)
    t = C - C // 3                             # last pages unwritten
    n_valid = -(-t // page)
    rng = np.random.default_rng(0)
    perm = rng.permutation(P1 - 1)             # scrambled physical order
    tbl = np.full((B, nlp), P1 - 1, np.int32)  # tail -> trash
    for b in range(B):
        tbl[b, :n_valid] = perm[b * nlp:b * nlp + n_valid]
    tbl[1:, 0] = tbl[0, 0]                     # rows share a prefix page
    q_pos = jnp.asarray(t - 1, jnp.int32)
    kv_pos = jnp.where(jnp.arange(C) < t, jnp.arange(C), -1).astype(
        jnp.int32)
    tblj = jnp.asarray(tbl)
    got = np.asarray(paged_decode_attention_pallas(
        q, kp, vp, tblj, q_pos, kv_pos, window=win), np.float32)
    want = np.asarray(ref.paged_decode_attention_ref(
        q, kp, vp, tblj, q_pos, kv_pos, window=win), np.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
    # triangulate against the ring kernel on the gathered dense view
    kd, vd = paged_gather(kp, vp, tblj)
    ring = np.asarray(ops.decode_attention(q, kd, vd, q_pos, kv_pos,
                                           window=win, block_s=page),
                      np.float32)
    np.testing.assert_allclose(got, ring, rtol=tol, atol=tol)


def test_paged_decode_attention_page_table_remap_invariance():
    """Remapping rows to different physical pages with identical
    contents must not change the output (storage layout is invisible
    to the attention math)."""
    B, H, KV, dh, page, nlp = 2, 4, 2, 32, 8, 4
    C = nlp * page
    P1 = 2 * B * nlp + 1
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    kp = jax.random.normal(ks[0], (P1, page, KV, dh))
    vp = jax.random.normal(ks[1], (P1, page, KV, dh))
    q = jax.random.normal(ks[2], (B, H, dh))
    tbl1 = np.arange(B * nlp, dtype=np.int32).reshape(B, nlp)
    # duplicate contents into a disjoint region, remap row 1 there
    kp = kp.at[B * nlp:2 * B * nlp].set(kp[:B * nlp])
    vp = vp.at[B * nlp:2 * B * nlp].set(vp[:B * nlp])
    tbl2 = tbl1.copy()
    tbl2[1] += B * nlp
    q_pos = jnp.asarray(C - 1, jnp.int32)
    kv_pos = jnp.arange(C, dtype=jnp.int32)
    a = np.asarray(ops.paged_decode_attention(
        q, kp, vp, jnp.asarray(tbl1), q_pos, kv_pos))
    b = np.asarray(ops.paged_decode_attention(
        q, kp, vp, jnp.asarray(tbl2), q_pos, kv_pos))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("B,H,P", [(2, 4, 32), (1, 8, 64), (4, 2, 16)])
def test_wkv_decode_step(B, H, P):
    from repro.kernels.wkv_step import wkv_step_pallas
    from repro.models.rwkv6 import wkv_step as wkv_oracle
    ks = jax.random.split(jax.random.PRNGKey(B * P), 6)
    r = jax.random.normal(ks[0], (B, H, P))
    k = jax.random.normal(ks[1], (B, H, P))
    v = jax.random.normal(ks[2], (B, H, P))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, P)) * 0.5)
    u = jax.random.normal(ks[4], (H, P)) * 0.2
    S = jax.random.normal(ks[5], (B, H, P, P))
    o_k, S_k = wkv_step_pallas(r, k, v, logw, u, S)
    S_ref, o_ref = wkv_oracle(S, r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(S_k), np.asarray(S_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("B,H", [(1, 2), (3, 4), (2, 8)])
def test_wkv_step_parity_grid(B, H, dtype):
    """Interpret-mode kernel vs oracle over the (batch, head, dtype)
    grid the serving path actually exercises: both sides upcast to f32
    in-kernel, so bf16 activations must agree to f32-rounding level,
    not just bf16 precision — a regression here means the kernel
    dropped its internal upcast."""
    from repro.kernels.wkv_step import wkv_step_pallas
    from repro.models.rwkv6 import wkv_step as wkv_oracle
    P = 32
    ks = jax.random.split(jax.random.PRNGKey(B * 100 + H), 6)
    r = jax.random.normal(ks[0], (B, H, P)).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, P)).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, P)).astype(dtype)
    logw = (-jnp.exp(jax.random.normal(ks[3], (B, H, P)) * 0.5)
            ).astype(dtype)
    u = (jax.random.normal(ks[4], (H, P)) * 0.2).astype(dtype)
    S = jax.random.normal(ks[5], (B, H, P, P))   # state stays f32
    o_k, S_k = wkv_step_pallas(r, k, v, logw, u, S)
    assert o_k.dtype == jnp.float32 and S_k.dtype == jnp.float32
    S_ref, o_ref = wkv_oracle(S, r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(np.asarray(S_k), np.asarray(S_ref),
                               rtol=5e-5, atol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_wkv_step_chain_matches_scan(dtype):
    """T chained kernel decode steps reproduce wkv_scan's outputs and
    final state — the decode loop is the scan, one token at a time."""
    from repro.kernels.wkv_step import wkv_step_pallas
    from repro.models.rwkv6 import wkv_scan
    B, T, H, P = 2, 5, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    r = jax.random.normal(ks[0], (B, T, H, P)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, H, P)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, H, P)).astype(dtype)
    logw = (-jnp.exp(jax.random.normal(ks[3], (B, T, H, P)) * 0.5)
            ).astype(dtype)
    u = jnp.zeros((H, P), dtype) + 0.1
    o_scan, S_scan = wkv_scan(r, k, v, logw, u)
    S = jnp.zeros((B, H, P, P), jnp.float32)
    outs = []
    for t in range(T):
        o, S = wkv_step_pallas(r[:, t], k[:, t], v[:, t],
                               logw[:, t], u, S)
        outs.append(o)
    o_chain = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_chain), np.asarray(o_scan),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_scan),
                               rtol=1e-4, atol=1e-4)
