"""Expert hub tests: lifecycle state machine, checkpoint-store
round-trip, NotResident backpressure, refcounted residency,
popularity-weighted eviction, paged slot recycling, and token identity
against both a fully-resident hub and the per-engine serving path."""
import jax
import numpy as np
import pytest

from repro.checkpoint import (list_experts, load_expert, save_expert)
from repro.configs import get_config
from repro.core import ExpertRegistry, ExpertSpec
from repro.models import build_model
from repro.serve import (ExpertEngine, ExpertHub, HubMember, NotResident,
                         Request, RoutedServer, Scheduler,
                         plan_placement)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("smollm-135m").reduced(name="hub-t")
    return build_model(cfg)


@pytest.fixture(scope="module")
def params4(model):
    return [model.init(jax.random.PRNGKey(s)) for s in range(4)]


def _mk_hub(model, params, n_slots, **kw):
    hub = ExpertHub(model, n_slots=n_slots, max_len=32, **kw)
    for i, p in enumerate(params):
        hub.add_expert(f"ex{i}", p)
    return hub


def _reqs(rng, n, n_experts, max_len=28):
    return [Request(uid=u, features=np.zeros(784, np.float32),
                    prompt=rng.integers(0, 50,
                                        size=int(rng.integers(3, max_len))),
                    max_new_tokens=int(rng.integers(1, 5)),
                    expert=int(rng.integers(n_experts)))
            for u in range(n)]


# -- checkpoint store --------------------------------------------------------


def test_expert_store_roundtrip(tmp_path, model, params4):
    root = str(tmp_path / "store")
    save_expert(root, "alpha", params4[0], meta={"arch": "smollm"})
    save_expert(root, "beta", params4[1])
    assert list_experts(root) == ["alpha", "beta"]
    back = load_expert(root, "alpha")
    for a, b in zip(jax.tree_util.tree_leaves(params4[0]),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- shared catalog entry type ----------------------------------------------


def test_expert_spec_is_the_shared_catalog_type(model, params4):
    """Placement grouping, hub slot compatibility and registry entries
    all read one ExpertSpec: equal geometry -> equal (hashable) specs;
    the planner publishes the spec it grouped by on the entry."""
    e0 = ExpertEngine(model, params4[0], max_len=64)
    e1 = ExpertEngine(model, params4[1], max_len=64)
    e2 = ExpertEngine(model, params4[2], max_len=32)   # different ladder
    s0, s1, s2 = map(ExpertSpec.of_engine, (e0, e1, e2))
    assert s0 == s1 and hash(s0) == hash(s1)
    assert s0 != s2
    assert s0.bankable
    reg = ExpertRegistry()
    reg.add("a", e0)
    reg.add("b", e1)
    plan = plan_placement(reg)
    assert reg[0].spec == reg[1].spec == s0
    assert len([s for s in plan.shards if s.banked]) == 1
    hub = ExpertHub(model, n_slots=2, max_len=64)
    assert hub.spec == s0          # same geometry -> same spec
    hub.add_expert("c", params4[0])
    assert hub.build_registry()[0].spec == s0


def test_dispatch_moe_spec_not_bankable():
    cfg = get_config("mixtral-8x22b").reduced(name="moe-spec")
    assert cfg.n_experts and cfg.moe_impl == "dispatch"
    moe = build_model(cfg)
    spec = ExpertSpec(arch=cfg.replace(name=""), max_len=64,
                      len_buckets=(8, 64), batch_buckets=(1, 16))
    assert not spec.bankable
    with pytest.raises(ValueError, match="slot bank"):
        ExpertHub(moe, n_slots=2, max_len=64)


# -- lifecycle state machine -------------------------------------------------


def test_hub_lifecycle_cold_to_resident_to_evicted(tmp_path, model,
                                                   params4):
    store = str(tmp_path / "store")
    hub = ExpertHub(model, n_slots=1, max_len=32, store=store)
    e0 = hub.add_expert("cold0", params4[0], cold=True)
    e1 = hub.add_expert("cold1", params4[1], cold=True)
    assert [hub.catalog[e].state for e in (e0, e1)] == ["cold", "cold"]
    assert list_experts(store) == ["cold0", "cold1"]
    # acquire records the want and raises: the NotResident outcome
    with pytest.raises(NotResident):
        hub.acquire(e0)
    assert hub.has_wanted and hub.stats.resident_misses == 1
    while hub.has_wanted:
        hub.service(block=True)
    assert hub.catalog[e0].state == "resident"
    assert hub.acquire(e0) == 0 and hub.slot_of(e0) == 0
    assert hub.stats.loads == 1 and hub.stats.stage_count == 1
    # faulting in the second expert evicts the first (single slot)
    with pytest.raises(NotResident):
        hub.acquire(e1)
    while hub.has_wanted:
        hub.service(block=True)
    assert hub.catalog[e1].state == "resident"
    assert hub.catalog[e0].state == "staged"   # host copy retained
    assert hub.stats.evictions == 1
    # re-acquiring e0 needs no cold-tier stage (host cache hit)
    with pytest.raises(NotResident):
        hub.acquire(e0)
    while hub.has_wanted:
        hub.service(block=True)
    assert hub.stats.stage_count == 2          # e0+e1 staged once each
    assert hub.stats.stage_cache_hits == 1
    hub.check()


def test_pinned_expert_is_not_evictable(model, params4):
    hub = _mk_hub(model, params4[:2], 1)
    hub.want(0)
    hub.service(block=True)
    hub.pin(0, 2)
    hub.want(1)
    assert hub.service(block=True) == 0        # slot pinned: no commit
    assert hub.catalog[1].state != "resident"
    hub.unpin(0)
    assert hub.service() == 0                  # still one pin left
    hub.unpin(0)
    assert hub.service() == 1                  # now evictable
    assert hub.catalog[1].state == "resident"
    assert hub.catalog[0].state == "staged"
    with pytest.raises(ValueError, match="unpin below zero"):
        hub.unpin(0)
    with pytest.raises(ValueError, match="non-resident"):
        hub.pin(0)
    hub.check()


def test_active_wave_blocks_eviction_even_when_pin_free(model, params4):
    """A row's pin drops at harvest, but its wave (and pages, when
    paged) lives until every member row retires — the hub must not
    recycle a slot an active wave still references."""
    hub = _mk_hub(model, params4[:2], 1, kv_layout="paged")
    hub.want(0)
    hub.service(block=True)
    rng = np.random.default_rng(0)
    # two rows, one finishes at prefill: its pin would drop first
    hub.bank.admit({0: ([("t", 1), ("t", 2)],
                        [rng.integers(0, 50, 9), rng.integers(0, 50, 9)],
                        [1, 4])}, defer=True)
    hub.want(1)
    assert hub.service() == 0, "evicted a slot with an active wave"
    assert hub.catalog[0].state == "resident"
    while hub.bank.n_active:
        hub.bank.tick()
    hub.bank.poll()
    assert hub.service() == 1                  # wave retired: evictable
    assert hub.catalog[1].state == "resident"
    hub.bank.core.pool.check()
    hub.check()


# -- serving integration -----------------------------------------------------


def test_hub_token_identical_to_resident_and_per_engine(model, params4):
    """The acceptance property: a 2-slot hub over 4 experts serves
    token-identically to (a) a fully-resident 4-slot hub and (b) the
    plain per-engine path, with evictions and stalls actually
    happening."""
    rng = np.random.default_rng(7)
    reqs = _reqs(rng, 20, 4)

    hub_small = _mk_hub(model, params4, 2)
    srv_small = RoutedServer(None, hub_small.build_registry(),
                             max_batch=4, hub=hub_small)
    hub_full = _mk_hub(model, params4, 4)
    srv_full = RoutedServer(None, hub_full.build_registry(),
                            max_batch=4, hub=hub_full)
    reg = ExpertRegistry()
    for i, p in enumerate(params4):
        reg.add(f"ex{i}", ExpertEngine(model, p, max_len=32))
    sched = Scheduler(None, reg)       # router-less per-engine path

    got_small = srv_small.serve(reqs)
    got_full = srv_full.serve(reqs)
    sched.submit(reqs)
    got_eng = {r.uid: r for r in sched.drain()}
    for a, b in zip(got_small, got_full):
        assert a.uid == b.uid and a.expert == b.expert
        np.testing.assert_array_equal(a.tokens, b.tokens,
                                      err_msg=str(a.uid))
        c = got_eng[a.uid]
        assert c.expert == a.expert
        np.testing.assert_array_equal(a.tokens, c.tokens,
                                      err_msg=str(a.uid))
    assert hub_small.stats.evictions > 0
    assert hub_full.stats.evictions == 0
    assert srv_small.scheduler.stats.resident_stalls > 0
    # pins all released, maps consistent
    assert all(c.pins == 0 for c in hub_small.catalog)
    hub_small.check()
    st = srv_small.stats
    assert "hub" in st and st["hub"].loads >= 2


def test_cold_start_parks_then_serves(tmp_path, model, params4):
    """A request routed to a cold expert must park (NotResident), stage
    in the background, and complete with the same tokens a warm engine
    produces."""
    store = str(tmp_path / "store")
    hub = ExpertHub(model, n_slots=1, max_len=32, store=store)
    for i, p in enumerate(params4[:2]):
        hub.add_expert(f"ex{i}", p, cold=True)
    srv = RoutedServer(None, hub.build_registry(), max_batch=4, hub=hub)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 50, size=10)
    [r] = srv.serve([Request(uid=0, features=np.zeros(784, np.float32),
                             prompt=prompt, max_new_tokens=4, expert=1)])
    assert r.expert == "ex1" and r.tokens.shape == (4,)
    assert srv.scheduler.stats.resident_stalls >= 1
    assert hub.stats.stage_count >= 1
    ref = ExpertEngine(model, params4[1], max_len=32)
    np.testing.assert_array_equal(r.tokens,
                                  ref.generate(prompt[None, :], 4)[0])


def test_popularity_keeps_hot_expert_resident(model, params4):
    """Eviction is popularity-weighted: the expert with the most hits
    is never displaced while colder candidates exist."""
    hub = _mk_hub(model, params4, 2)
    srv = RoutedServer(None, hub.build_registry(), max_batch=4, hub=hub)
    rng = np.random.default_rng(11)
    uid = 0
    for rnd in range(6):
        batch = [Request(uid=uid + k, features=np.zeros(784, np.float32),
                         prompt=rng.integers(0, 50, size=8),
                         max_new_tokens=2,
                         expert=0 if k < 3 else 1 + (rnd + k) % 3)
                 for k in range(4)]
        uid += 4
        srv.serve(batch)
        assert 0 in hub.resident_experts, \
            f"hot expert evicted in round {rnd}"
    assert hub.stats.evictions > 0
    hub.check()


def test_paged_slot_recycle_invalidates_prefix_cache(model, params4):
    """Recycling a slot for a new expert must drop the old expert's
    cached prefixes (they describe KV the new expert never computed)
    and leave zero live pages; re-serving the first expert afterwards
    is still token-identical to a fresh engine."""
    hub = _mk_hub(model, params4[:2], 1, kv_layout="paged")
    srv = RoutedServer(None, hub.build_registry(), max_batch=4, hub=hub)
    rng = np.random.default_rng(5)
    shared = rng.integers(0, 50, size=16)
    mk = lambda uid, e: Request(uid=uid,
                                features=np.zeros(784, np.float32),
                                prompt=shared, max_new_tokens=3, expert=e)
    srv.serve([mk(0, 0), mk(1, 0)])            # populates prefix cache
    cache = hub.bank.core.prefix_cache
    n_stale, drops0 = len(cache), cache.stats["evictions"]
    assert n_stale > 0
    # ex1 gets the slot AND sends the very prompt ex0 cached: without
    # invalidation it would adopt ex0's KV pages and decode garbage
    [r2] = srv.serve([mk(2, 1)])
    assert hub.stats.evictions == 1
    assert cache.stats["evictions"] >= drops0 + n_stale, \
        "stale prefixes survived the slot recycle"
    ref1 = ExpertEngine(model, params4[1], max_len=32, kv_layout="paged")
    np.testing.assert_array_equal(
        r2.tokens, ref1.generate(shared[None, :], 3)[0])
    [r3] = srv.serve([mk(3, 0)])               # ex0 returns to the slot
    ref0 = ExpertEngine(model, params4[0], max_len=32, kv_layout="paged")
    np.testing.assert_array_equal(
        r3.tokens, ref0.generate(shared[None, :], 3)[0])
    hub.bank.core.pool.check()
    hub.check()


def test_hub_warmup_prevents_steady_state_compiles(model, params4):
    hub = _mk_hub(model, params4, 2)
    srv = RoutedServer(None, hub.build_registry(), max_batch=4, hub=hub)
    hub.warmup(max_batch=4)
    jit0 = hub.bank.stats.jit_cache_entries + hub.install_compiles
    assert jit0 > 0
    rng = np.random.default_rng(13)
    srv.serve(_reqs(rng, 16, 4))
    assert hub.bank.stats.jit_cache_entries + hub.install_compiles == jit0
    assert srv.scheduler.stats.orphaned == 0, \
        "warmup leaked rows into the scheduler's poll stream"


def test_hub_pool_too_small_unwinds_pins_and_rows(model, params4):
    """The fatal PagePoolExhausted (pool can't host even one wave) must
    re-raise with the popped rows back in their queues and zero pins —
    a leaked pin would make the expert permanently unevictable."""
    hub = _mk_hub(model, params4[:2], 1, kv_layout="paged",
                  pool_pages=2)
    hub.want(0)
    hub.service(block=True)
    srv = RoutedServer(None, hub.build_registry(), max_batch=4, hub=hub)
    srv.submit([Request(uid=0, features=np.zeros(784, np.float32),
                        prompt=np.arange(30, dtype=np.int32),
                        max_new_tokens=3, expert=0)])
    with pytest.raises(Exception, match="pages"):
        srv.scheduler.drain()
    assert all(c.pins == 0 for c in hub.catalog), "leaked pins"
    assert srv.scheduler.n_queued == 1          # row requeued, not lost
    hub.check()


def test_staging_failure_is_loud_but_retryable(tmp_path, model, params4):
    """A broken checkpoint must raise out of service() — but leave the
    entry retryable (back to cold, want dropped) instead of wedged in
    'staging' forever with its rows parked."""
    import shutil
    store = str(tmp_path / "store")
    hub = ExpertHub(model, n_slots=1, max_len=32, store=store)
    e = hub.add_expert("frail", params4[0], cold=True)
    shutil.rmtree(store)                      # corrupt the cold tier
    with pytest.raises(NotResident):
        hub.acquire(e)
    with pytest.raises(Exception):
        while hub.has_wanted:
            hub.service(block=True)
    assert hub.catalog[e].state == "cold"     # not wedged in 'staging'
    assert not hub.has_wanted
    # restore the checkpoint: the same expert stages fine on retry
    from repro.checkpoint import save_expert
    save_expert(store, "frail", params4[0])
    with pytest.raises(NotResident):
        hub.acquire(e)
    while hub.has_wanted:
        hub.service(block=True)
    assert hub.catalog[e].state == "resident"
    hub.check()


def test_staging_failure_still_trims_host_cache(tmp_path, model,
                                                params4):
    """The host-cache cap must hold on the staging-failure exit too:
    service() re-raises a broken stage, but its finally-trim still
    returns over-cap staged copies to the cold tier. Regression for
    the exception-path leak the lifecycle review flagged — before the
    fix, every raise skipped _trim_host() and a flaky cold tier could
    pin the whole catalog in host memory."""
    import shutil
    store = str(tmp_path / "store")
    hub = ExpertHub(model, n_slots=1, max_len=32, store=store)
    e0 = hub.add_expert("ex0", params4[0], cold=True)
    e1 = hub.add_expert("ex1", params4[1], cold=True)
    e2 = hub.add_expert("ex2", params4[2], cold=True)
    for e in (e0, e1):                  # rotate both through the slot
        with pytest.raises(NotResident):
            hub.acquire(e)
        while hub.has_wanted:
            hub.service(block=True)
    # ex0 was evicted with its host copy retained (fast reloads)
    assert hub.catalog[e0].state == "staged"
    assert hub.catalog[e0].params is not None
    hub.host_cache = 0                  # now cap the host tier
    shutil.rmtree(store)                # and break the cold tier
    with pytest.raises(NotResident):
        hub.acquire(e2)
    with pytest.raises(Exception):
        while hub.has_wanted:
            hub.service(block=True)
    # the failing service still enforced the cap on its way out
    assert hub.catalog[e0].state == "cold"
    assert hub.catalog[e0].params is None
    # and nothing leaked: failed entry retryable, no pins, no stragglers
    assert hub.catalog[e2].state == "cold"
    assert not hub.has_wanted and not hub._staging
    assert all(c.pins == 0 for c in hub.catalog)
    from repro.checkpoint import save_expert
    for i, name in enumerate(("ex0", "ex1", "ex2")):
        save_expert(store, name, params4[i])
    with pytest.raises(NotResident):
        hub.acquire(e2)                 # restored tier: full recovery
    while hub.has_wanted:
        hub.service(block=True)
    assert hub.catalog[e2].state == "resident"
    hub.check()


def test_host_cache_bounds_staged_copies(tmp_path, model, params4):
    """With host_cache set, evicted experts' host copies are trimmed
    back to the cold tier (least popular first) instead of growing
    toward the whole catalog."""
    store = str(tmp_path / "store")
    hub = ExpertHub(model, n_slots=1, max_len=32, store=store,
                    host_cache=1)
    for i, p in enumerate(params4):
        hub.add_expert(f"ex{i}", p, cold=True)
    srv = RoutedServer(None, hub.build_registry(), max_batch=4, hub=hub)
    rng = np.random.default_rng(17)
    for uid, e in enumerate([0, 1, 2, 3]):    # rotate all four through
        srv.serve([Request(uid=uid, features=np.zeros(784, np.float32),
                           prompt=rng.integers(0, 50, size=8),
                           max_new_tokens=2, expert=e)])
    held = [c for c in hub.catalog
            if c.state == "staged" and c.params is not None]
    assert len(held) <= 1, [c.name for c in held]
    # trimmed entries went back to cold and can still be re-served
    [r] = srv.serve([Request(uid=99, features=np.zeros(784, np.float32),
                             prompt=rng.integers(0, 50, size=8),
                             max_new_tokens=2, expert=0)])
    assert r.expert == "ex0"
    hub.check()


def test_store_rejects_unsafe_expert_names(tmp_path, params4):
    from repro.checkpoint import save_expert
    root = str(tmp_path / "store")
    for bad in ("a/b", "..", ".hidden", "", "a b"):
        with pytest.raises(ValueError, match="safe store"):
            save_expert(root, bad, params4[0])
    save_expert(root, "ok-name_1.0@v2+x", params4[0])  # all allowed


# -- wiring guards -----------------------------------------------------------


def test_hub_wiring_guards(model, params4):
    hub = _mk_hub(model, params4[:2], 1)
    reg = hub.build_registry()
    with pytest.raises(ValueError, match="matcher=None requires a hub"):
        RoutedServer(None, ExpertRegistry())
    with pytest.raises(ValueError, match="does not match"):
        other = ExpertRegistry()
        other.add("only-one", None)
        Scheduler(None, other, hub=hub)
    with pytest.raises(ValueError, match="HubMember"):
        # same length, foreign backends: must be rejected, not served
        # through the hub's slots under the wrong names
        foreign = ExpertRegistry()
        for i in range(len(hub)):
            foreign.add(f"f{i}", None)
        Scheduler(None, foreign, hub=hub)
    with pytest.raises(ValueError, match="pre-routed"):
        srv = RoutedServer(None, reg, hub=hub)
        srv.submit([Request(uid=0, features=np.zeros(784, np.float32),
                            prompt=np.arange(4), max_new_tokens=1)])
    with pytest.raises(ValueError, match="out of range"):
        srv = RoutedServer(None, hub.build_registry(), hub=hub)
        srv.submit([Request(uid=1, features=np.zeros(784, np.float32),
                            prompt=np.arange(4), max_new_tokens=1,
                            expert=7)])
    with pytest.raises(ValueError, match="already in the catalog"):
        hub.add_expert("ex0", params4[0])
    with pytest.raises(ValueError, match="no params and no checkpoint"):
        ExpertHub(model, n_slots=1, max_len=32).add_expert("ghost")
    with pytest.raises(ValueError, match="n_slots"):
        ExpertHub(model, n_slots=0, max_len=32)


# -- worker lifecycle / thread hygiene --------------------------------------


@pytest.fixture(autouse=True, scope="module")
def no_dangling_nondaemon_threads():
    """Concurrency-gate satellite: nothing in this module may leak a
    non-daemon thread (a leaked staging worker would hang interpreter
    shutdown). Baselined against the threads alive before the module."""
    import threading
    before = {t.ident for t in threading.enumerate()}
    yield
    leaked = [t for t in threading.enumerate()
              if t.ident not in before and t.is_alive() and not t.daemon]
    assert leaked == [], f"non-daemon threads leaked: {leaked}"


def test_hub_close_joins_worker_and_is_idempotent(tmp_path, model, params4):
    import threading
    root = str(tmp_path / "store")
    for i, p in enumerate(params4):
        save_expert(root, f"ex{i}", p)
    hub = ExpertHub(model, n_slots=2, max_len=32, store=root,
                    prefetch=True)
    for i in range(4):
        hub.add_expert(f"ex{i}")
    hub.want(2)
    hub.service(block=True)
    assert hub.expert_in(hub.slot_of(2)) == 2
    worker = hub._stage_thread
    assert worker is not None and worker.is_alive()
    assert worker.name == "hub-stage"

    hub.close()
    assert not worker.is_alive(), "close() returned with the worker alive"
    hub.close()                                        # idempotent
    assert hub._stage_thread is None

    # a closed hub still serves residents but refuses to stage
    assert hub.acquire(2) == hub.slot_of(2)
    hub.want(3)
    with pytest.raises(RuntimeError, match="closed"):
        hub.service(block=True)


def test_hub_context_manager_closes(tmp_path, model, params4):
    root = str(tmp_path / "store")
    for i, p in enumerate(params4):
        save_expert(root, f"ex{i}", p)
    with ExpertHub(model, n_slots=2, max_len=32, store=root,
                   prefetch=True) as hub:
        for i in range(4):
            hub.add_expert(f"ex{i}")
        hub.want(0)
        hub.service(block=True)
        worker = hub._stage_thread
        assert worker is not None and worker.is_alive()
    assert hub._closed and not worker.is_alive()


def test_popularity_counter_reads_under_hub_lock(model, params4):
    """Seeded regression for the unguarded popularity read (races
    R001): once bind_popularity shares the router Counter, the
    router's hit increments take the hub lock, so an eviction ranking
    running concurrently can never see torn counts. Locking is
    structural (the router is handed the hub lock), so assert the
    wiring rather than racing the threads — the sanitizer's
    demo_lost_update covers the dynamic half."""
    hub = _mk_hub(model, params4, n_slots=2)
    try:
        import collections
        from repro.serve.router import Router

        class _Stub(Router):
            def __init__(self):
                self.expert_hits = collections.Counter()
                self.hits_lock = None

        router = _Stub()
        hub.bind_popularity(router.expert_hits, router=router)
        assert router.hits_lock is hub._lock
        assert hub.popularity is router.expert_hits
        # note_hit goes through the same lock-guarded counter
        hub.note_hit(1, 3)
        assert router.expert_hits[1] == 3
    finally:
        hub.close()
