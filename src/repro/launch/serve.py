"""Serving launcher: ExpertMatcher-routed fleet (Fig. 2 of the paper).

Trains the AE bank on the 6 synthetic benchmark datasets, registers one
expert engine per dataset (reduced zoo architectures on CPU), and serves
batches of mixed-modality requests.

With ``--hub-slots K`` (K > 0) the experts are served through an
``ExpertHub`` holding only K device slots: each expert is checkpointed
to ``--store`` (or a temp dir), staged on demand and evicted by
popularity-weighted LRU — the launcher prints the hub's lifecycle
ledger after serving.

  PYTHONPATH=src python -m repro.launch.serve --requests 32
  PYTHONPATH=src python -m repro.launch.serve --hub-slots 2
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from ..configs import ALL_ARCHS, get_config
from ..core import ExpertRegistry, build_matcher, train_bank
from ..data import load_benchmark
from ..models import build_model
from ..obs import Tracer
from ..serve import ExpertEngine, ExpertHub, Request, RoutedServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--n-per-dataset", type=int, default=2000)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--executor", choices=("serial", "overlapped"),
                    default="overlapped",
                    help="dispatch executor: 'overlapped' enqueues every "
                         "shard's prefill/decode before blocking (async "
                         "dispatch); 'serial' is the blocking reference")
    ap.add_argument("--kv", choices=("ring", "paged"), default="ring",
                    help="KV cache layout: 'paged' pools fixed-size "
                         "pages per shard and shares prompt-prefix "
                         "pages between requests (dense-family experts "
                         "only; others keep the ring layout)")
    ap.add_argument("--hub-slots", type=int, default=0,
                    help="serve through an ExpertHub with this many "
                         "device slots (0 = every expert resident, the "
                         "per-engine path); experts are checkpointed "
                         "cold and staged on demand")
    ap.add_argument("--store", default=None,
                    help="expert checkpoint store dir for --hub-slots "
                         "(default: a temp dir)")
    ap.add_argument("--trace", metavar="OUT", default=None,
                    help="record request-lifecycle spans while serving "
                         "and write a Chrome trace_event JSON to OUT "
                         "(open in chrome://tracing or Perfetto), plus "
                         "a greppable JSONL sibling at OUT + 'l'")
    args = ap.parse_args()

    t0 = time.time()
    bench = load_benchmark(n_per_dataset=args.n_per_dataset)
    names = list(bench)
    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=args.epochs, batch_size=64)
    cents = [(bench[n]["server"][0], bench[n]["server"][1]) for n in names]
    matcher = build_matcher(aes, names, cents)
    print(f"[{time.time()-t0:.1f}s] matcher ready ({len(names)} experts)")

    hub = None
    if args.hub_slots > 0:
        # the hub slot bank requires one homogeneous architecture
        # (equal ExpertSpec = slot compatibility); checkpoint each
        # expert cold so staging exercises the full lifecycle
        cfg = get_config("llama3_2_1b").reduced(name="llama-hub")
        model = build_model(cfg)
        kv = args.kv if model.supports_paged_kv else "ring"
        store = args.store or tempfile.mkdtemp(prefix="expert-store-")
        hub = ExpertHub(model, n_slots=args.hub_slots, max_len=64,
                        kv_layout=kv, store=store)
        for i, n in enumerate(names):
            hub.add_expert(n, model.init(jax.random.PRNGKey(i)),
                           cold=True)
        registry = hub.build_registry()
        print(f"[{time.time()-t0:.1f}s] hub: {len(registry)} experts "
              f"checkpointed to {store}, {args.hub_slots} device slots")
    else:
        registry = ExpertRegistry()
        for i, n in enumerate(names):
            arch = ALL_ARCHS[i % len(ALL_ARCHS)]
            cfg = get_config(arch).reduced(name=f"{arch}@{n}")
            if cfg.family in ("encdec", "vlm"):  # token-only demo
                cfg = get_config("llama3_2_1b").reduced(name=f"llama@{n}")
            model = build_model(cfg)
            kv = args.kv if model.supports_paged_kv else "ring"
            registry.add(n, ExpertEngine(model, model.init(
                jax.random.PRNGKey(i)), max_len=64, kv_layout=kv),
                arch=cfg.name)
    tracer = Tracer() if args.trace else None
    server = RoutedServer(matcher, registry, executor=args.executor,
                          hub=hub, tracer=tracer)

    rng = np.random.default_rng(0)
    reqs, truth = [], []
    for uid in range(args.requests):
        n = names[rng.integers(len(names))]
        x, _ = bench[n]["client_a"]
        reqs.append(Request(uid=uid, features=x[rng.integers(len(x))],
                            prompt=rng.integers(0, 100, size=8),
                            max_new_tokens=args.max_new))
        truth.append(n)
    t1 = time.time()
    resps = server.serve(reqs)
    dt = time.time() - t1
    acc = np.mean([r.expert == t for r, t in zip(resps, truth)])
    print(f"served {len(resps)} reqs in {dt:.2f}s "
          f"({len(resps)/dt:.1f} req/s); routing accuracy {acc:.1%}")
    st = server.stats
    blocks = sum(es.host_blocks
                 for es in {**st["engines"], **st["banks"]}.values())
    print(f"executor={args.executor}: {blocks} host-blocking syncs "
          f"across all engines")
    if hub is not None:
        print(f"hub: {hub.stats!r}")
        print(f"resident now: "
              f"{[hub.catalog[e].name for e in hub.resident_experts]} "
              f"({server.scheduler.stats.resident_stalls} "
              "resident-miss stalls)")
    if tracer is not None:
        n_events = tracer.export_chrome(args.trace)
        tracer.export_jsonl(args.trace + "l")
        print(f"trace: {n_events} events -> {args.trace} "
              f"(+ {args.trace}l)")


if __name__ == "__main__":
    main()
