"""Post-SPMD HLO analysis: collective traffic, loop-aware multipliers,
and contract-oriented module introspection.

``collective_bytes(hlo_text)`` parses the compiled (per-device) HLO module,
sums the result-shape bytes of every collective op, and multiplies ops that
live inside ``while`` bodies by the loop trip count (scan-over-layers,
KV-chunk scans). Trip counts are recovered from the loop-condition
computation's comparison constant — best-effort but exact for lax.scan.

``input_output_aliases`` / ``custom_call_targets`` / ``op_kinds`` read
the facts the serving contract gate (``repro.analysis.hlo_contracts``)
asserts on: whether buffer donation actually took (XLA drops unusable
donations silently, leaving only a warning), whether a module calls back
into the host, and which opcodes appear in a lowered dispatch.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,4096]' -> bytes. Tuples handled by summing components."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """Computation headers are ``[ENTRY] %name (args) -> type {`` lines;
    bodies run until a bare ``}``. Layout/metadata braces are same-line."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ") -> " in stripped:
                head = stripped.replace("ENTRY", "").strip()
                name = head.split("(")[0].strip().lstrip("%")
                cur = name or "entry"
                comps[cur] = []
        elif stripped == "}":
            cur = None
        elif stripped:
            comps[cur].append(stripped)
    return comps


def _while_trip_counts(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """Map body-computation name -> trip count (best effort)."""
    trips: Dict[str, int] = {}
    for _, lines in comps.items():
        for ln in lines:
            if " while(" not in ln and not re.search(r"=\s*\S+\s+while\(", ln):
                continue
            mb = re.search(r"body=%?([\w\.\-_]+)", ln)
            mc = re.search(r"condition=%?([\w\.\-_]+)", ln)
            if not mb or not mc:
                continue
            body, cond = mb.group(1), mc.group(1)
            count = None
            for cl in comps.get(cond, []):
                for cm in re.finditer(r"constant\((\d+)\)", cl):
                    v = int(cm.group(1))
                    count = max(count or 0, v)
            trips[body] = count if count else 1
    return trips


def _nesting_multiplier(comp: str, parent_of: Dict[str, Tuple[str, int]],
                        depth_guard: int = 16) -> int:
    mult = 1
    seen = 0
    while comp in parent_of and seen < depth_guard:
        comp, trips = parent_of[comp]
        mult *= trips
        seen += 1
    return mult


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "iota", "after-all", "partition-id",
    "replica-id",
}

_OP_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-_]+)\s*=\s*(\(?[a-z0-9]+\[[^=]*?)\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w\.\-_]+)\s*:\s*(\(?[a-z0-9]+\[[0-9,\{\}\s]*\]\)?)")


def _index_shapes(hlo: str) -> Dict[str, str]:
    """Global %name -> result-type string (covers params via headers)."""
    shapes: Dict[str, str] = {}
    for line in hlo.splitlines():
        s = line.strip()
        m = _OP_RE.match(s)
        if m:
            shapes[m.group(1)] = m.group(2)
        elif s.endswith("{") and ") -> " in s:
            argpart = s[s.find("(") + 1:s.rfind(") -> ")]
            for pm in _PARAM_RE.finditer(argpart):
                shapes.setdefault(pm.group(1), pm.group(2))
    return shapes


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _dot_flops(line: str, shapes: Dict[str, str]) -> int:
    """2 * prod(result dims) * prod(contracted dim sizes of lhs)."""
    m = _OP_RE.match(line)
    if not m:
        return 0
    result_elems = 1
    for d in _dims_of(m.group(2)):
        result_elems *= d
    ops = re.findall(r"\(([^)]*)\)", line)
    operands = re.findall(r"%([\w\.\-_]+)", ops[0]) if ops else []
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    k = 1
    if mc and operands:
        lhs_dims = _dims_of(shapes.get(operands[0], ""))
        for idx in mc.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2 * result_elems * k


def module_cost(hlo: str) -> Dict[str, float]:
    """Loop-expanded per-device {flops, bytes, collectives...} from HLO text.

    XLA's HloCostAnalysis counts while bodies once; here every computation's
    cost is multiplied by the product of enclosing loop trip counts
    (recovered from loop-condition constants), which makes scan-over-layers
    and gradient-accumulation loops report their true cost.
    """
    comps = _split_computations(hlo)
    shapes = _index_shapes(hlo)
    trips = _while_trip_counts(comps)
    parent_of: Dict[str, Tuple[str, int]] = {}
    for cname, lines in comps.items():
        for ln in lines:
            mb = re.search(r"body=%?([\w\.\-_]+)", ln)
            if mb and mb.group(1) in trips:
                parent_of[mb.group(1)] = (cname, trips[mb.group(1)])
            mcond = re.search(r"condition=%?([\w\.\-_]+)", ln)
            if mcond and mcond.group(1) not in parent_of:
                parent_of[mcond.group(1)] = (cname, 1)
            mcall = re.search(r"(?:calls|to_apply)=%?([\w\.\-_]+)", ln)
            if mcall and mcall.group(1) not in parent_of:
                parent_of[mcall.group(1)] = (cname, 1)

    flops = 0.0
    nbytes = 0.0
    coll = defaultdict(float)
    for cname, lines in comps.items():
        mult = _nesting_multiplier(cname, parent_of)
        # fusion-internal computations: skip byte accounting (the fusion op
        # at the callsite accounts the traffic); still count dot flops.
        is_fused = cname.startswith("fused_") or ".fused" in cname
        for ln in lines:
            m = _OP_RE.match(ln)
            if not m:
                continue
            opname = m.group(3)
            if " dot(" in ln or opname == "dot":
                flops += mult * _dot_flops(ln, shapes)
            if is_fused or opname in _SKIP_BYTES_OPS:
                continue
            b = _shape_bytes(m.group(2))
            ops = re.findall(r"\(([^)]*)\)", ln)
            for ref in (re.findall(r"%([\w\.\-_]+)", ops[0]) if ops else []):
                b += _shape_bytes(shapes.get(ref, ""))
            nbytes += mult * b
            for op in COLLECTIVES:
                if opname.startswith(op):
                    if opname.endswith("-done"):
                        break
                    coll[op] += mult * _shape_bytes(m.group(2))
                    break
    out = {"flops": flops, "bytes": nbytes}
    out.update({f"coll_{k}": v for k, v in coll.items()})
    out["coll_total"] = sum(coll.values())
    return out


_ALIAS_BLOCK_RE = re.compile(r"input_output_alias=\{(.*?)\}\s*,\s*\w+=",
                             re.DOTALL)
_ALIAS_ENTRY_RE = re.compile(
    r"\{([0-9,\s]*)\}:\s*\((\d+),\s*\{[0-9,\s]*\}(?:,\s*([\w-]+))?\)")


def input_output_aliases(hlo: str) -> Dict[Tuple[int, ...], int]:
    """Donation map of a compiled module: output tuple index ->
    flat parameter number, parsed from the ``input_output_alias``
    attribute on the ``HloModule`` header line. Empty when the module
    donates nothing — including when every requested donation was
    silently dropped as unusable, which is exactly the regression the
    contract gate exists to catch."""
    m = _ALIAS_BLOCK_RE.search(hlo)
    if not m:
        return {}
    out: Dict[Tuple[int, ...], int] = {}
    for e in _ALIAS_ENTRY_RE.finditer(m.group(1)):
        key = tuple(int(x) for x in e.group(1).split(",") if x.strip())
        out[key] = int(e.group(2))
    return out


_CUSTOM_CALL_RE = re.compile(r'custom_call_target="([^"]+)"')


def custom_call_targets(hlo: str) -> List[str]:
    """Every custom-call target in the module (host callbacks lower to
    ``xla_python_cpu_callback`` / ``xla_ffi_python_cpu_callback``)."""
    return _CUSTOM_CALL_RE.findall(hlo)


def op_kinds(hlo: str) -> Dict[str, int]:
    """Opcode histogram over every computation in the module."""
    out: Dict[str, int] = defaultdict(int)
    for comp_lines in _split_computations(hlo).values():
        for ln in comp_lines:
            m = _OP_RE.match(ln)
            if m:
                out[m.group(3)] += 1
    return dict(out)


def collective_bytes(hlo: str) -> Dict[str, int]:
    """Returns {op_type: total_bytes (loop-expanded)} + {"total": ...}."""
    comps = _split_computations(hlo)
    trips = _while_trip_counts(comps)
    # parent map: computation -> (enclosing computation, trip count)
    parent_of: Dict[str, Tuple[str, int]] = {}
    for cname, lines in comps.items():
        for ln in lines:
            mb = re.search(r"body=%?([\w\.\-_]+)", ln)
            if mb and mb.group(1) in trips:
                parent_of[mb.group(1)] = (cname, trips[mb.group(1)])
            # calls/fusions propagate multipliers too
            mcall = re.search(r"(?:calls|to_apply)=%?([\w\.\-_]+)", ln)
            if mcall and mcall.group(1) not in parent_of:
                parent_of[mcall.group(1)] = (cname, 1)

    out: Dict[str, int] = defaultdict(int)
    for cname, lines in comps.items():
        mult = _nesting_multiplier(cname, parent_of)
        for ln in lines:
            for op in COLLECTIVES:
                # result-shape precedes "= <shape> op-name(" pattern
                m = re.search(rf"=\s*([^=]+?)\s+{op}(-start|-done)?\(", ln)
                if m:
                    if m.group(2) == "-done":
                        continue  # counted at -start
                    nbytes = _shape_bytes(m.group(1))
                    out[op] += nbytes * mult
                    break
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)
