"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
pure data-parallel (DCN), ``data``/``model`` stay intra-pod (ICI).

Defined as functions (never module-level constants) so importing this
module never touches jax device state — only the dry-run sets
``xla_force_host_platform_device_count``.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16 << 30,
}


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh():
    """1x1 mesh for CPU smoke runs (everything replicated)."""
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=_auto(2))


def mesh_devices_required(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
