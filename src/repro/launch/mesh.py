"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
pure data-parallel (DCN), ``data``/``model`` stay intra-pod (ICI).

Defined as functions (never module-level constants) so importing this
module never touches jax device state — only the dry-run sets
``xla_force_host_platform_device_count``.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16 << 30,
}


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across JAX versions.

    ``jax.sharding.AxisType`` only exists from JAX 0.5; the pinned 0.4.37
    predates it (all axes are implicitly Auto there, so omitting
    ``axis_types`` is semantically identical).
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh for CPU smoke runs (everything replicated)."""
    return compat_make_mesh((1, 1), ("data", "model"))


def make_expert_mesh():
    """1-D mesh over an ``expert`` axis spanning all visible devices.

    Used by ``serve.placement``: banked expert engines shard their
    stacked params/caches along this axis so co-located experts run on
    their own devices under one dispatch. On a laptop/CI box drive it
    with a forced host device count (set *before* jax initialises its
    backend, e.g. ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    with ``JAX_PLATFORMS=cpu``); on a TPU slice the real chips show up
    here instead.
    """
    return compat_make_mesh((len(jax.devices()),), ("expert",))


def mesh_devices_required(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
