"""Training launcher.

On this CPU container it trains a REDUCED variant end-to-end (real
optimizer steps); on a TPU slice the same entry point jits the full config
against the production mesh (the dry-run proves those combinations lower
and compile — see dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 100 [--full] [--seq 128 --batch 8]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..data import synthetic_token_stream
from ..models import build_model
from ..train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (TPU slice required)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.n_layers} "
          f"d_model={cfg.d_model}")

    tr = Trainer(model, lr=args.lr, total_steps=args.steps)
    stream = synthetic_token_stream(cfg.vocab_size, args.seq, args.batch)
    t0 = time.time()
    tr.fit(stream, steps=args.steps, log_every=args.log_every,
           callback=lambda i, m: print(
               f"step {i:5d}  loss {float(m['loss']):.4f}  "
               f"lr {float(m['lr']):.2e}  {time.time()-t0:.1f}s"))
    print(f"final loss: {tr.history[-1][1]:.4f}")


if __name__ == "__main__":
    main()
