import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

This proves the distribution config is coherent without TPU hardware:
``jax.jit(step, in_shardings=..., out_shardings=...).lower(shapes).compile()``
must succeed on the production mesh; ``memory_analysis()`` proves the
per-device footprint fits a v5e; ``cost_analysis()`` + HLO collective
parsing feed the §Roofline table.

The two module-level lines above MUST stay first: jax locks the device
count at first backend init, and only the dry-run wants 512 host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape train_4k [--multi-pod] [--out out.json] [--swa-window 4096]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out-dir results/
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ALL_ARCHS, get_config
from ..models import SHAPES, build_model
from ..models.common import ShapeConfig, tree_size
from ..sharding import mesh_context
from ..sharding.rules import batch_spec, cache_specs, param_specs
from ..train.loop import make_train_step, train_state_shapes
from .hlo_analysis import collective_bytes, module_cost
from .mesh import HW, make_production_mesh

from jax.sharding import NamedSharding, PartitionSpec as P


def _named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree)


def build_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
                 swa_window: int = 0, fsdp: Optional[bool] = None,
                 overrides: Optional[Dict[str, Any]] = None):
    """Returns (fn, example_shapes, in_shardings, out_shardings, meta)."""
    cfg = get_config(arch)
    if swa_window:
        cfg = cfg.replace(sliding_window=swa_window)
    if overrides:
        cfg = cfg.replace(**overrides)
    model = build_model(cfg)
    sc = SHAPES[shape_name]
    ok, why = model.supports(sc)
    if not ok:
        return None, why

    mesh = make_production_mesh(multi_pod=multi_pod)
    pshapes = model.param_shapes()
    n_params = tree_size(pshapes)
    # FSDP(ZeRO-3) only pays off when every weight is touched once per
    # *large* batch of tokens (training); at decode the per-token weight
    # all-gathers dominate latency (measured: 14 GB/token on qwen2-72b),
    # so serving steps use pure tensor-parallel params — UNLESS the params
    # don't fit TP-only (mixtral's 140B: 17.5 GiB/chip on a 16-way axis),
    # in which case weight gathers per token are the price of fitting.
    model_axis = 16
    tp_only_bytes = n_params * 2 / model_axis
    if fsdp is not None:
        use_fsdp = fsdp
    elif sc.mode in ("train", "prefill"):
        use_fsdp = n_params > 8e9
    else:  # decode
        use_fsdp = tp_only_bytes > 10e9
    pspecs = param_specs(pshapes, mesh, fsdp=use_fsdp)

    if sc.mode == "train":
        # clamp grad-accumulation so every microbatch still spans all
        # (pod x data) batch shards — a micro smaller than the batch mesh
        # forces GSPMD to replicate activations across pods (measured:
        # 8 GB/layer all-gathers on qwen2-72b multi-pod with mb=16)
        batch_devs = int(np.prod([v for k, v in mesh.shape.items()
                                  if k in ("pod", "data")]))
        mb_max = max(1, sc.global_batch // batch_devs)
        if cfg.train_microbatches > mb_max:
            cfg = cfg.replace(train_microbatches=mb_max)
            model = build_model(cfg)
        state_shapes = train_state_shapes(model)
        sspecs = {
            "params": pspecs,
            # ZeRO-1: optimizer moments additionally sharded over data
            "opt": {"m": param_specs(pshapes, mesh, fsdp=True),
                    "v": param_specs(pshapes, mesh, fsdp=True),
                    "step": P()},
            "step": P(),
        }
        bshapes = model.input_shapes(sc)
        bspecs = batch_spec(bshapes, mesh)
        step = make_train_step(model)
        fn = step
        args = (state_shapes, bshapes)
        in_sh = (_named(sspecs, mesh), _named(bspecs, mesh))
        out_sh = (_named(sspecs, mesh), None)
    elif sc.mode == "prefill":
        bshapes = model.input_shapes(sc)
        bspecs = batch_spec(bshapes, mesh)

        def fn(params, batch):
            return model.prefill(params, batch)

        args = (pshapes, bshapes)
        in_sh = (_named(pspecs, mesh), _named(bspecs, mesh))
        out_sh = None
    else:  # decode
        capacity = model.cache_capacity(sc.seq_len)
        cshapes = model.cache_shapes(sc.global_batch, capacity)
        cspecs = cache_specs(cshapes, mesh, sc.global_batch)
        bshapes = model.input_shapes(sc)
        bspecs = batch_spec(bshapes, mesh)

        def fn(params, cache, batch):
            return model.decode(params, cache, batch)

        args = (pshapes, cshapes, bshapes)
        in_sh = (_named(pspecs, mesh), _named(cspecs, mesh),
                 _named(bspecs, mesh))
        out_sh = (None, _named(cspecs, mesh))

    meta = {"arch": arch, "shape": shape_name, "mode": sc.mode,
            "multi_pod": multi_pod, "n_params": int(n_params),
            "fsdp": bool(use_fsdp), "mesh": dict(mesh.shape),
            "swa_window": swa_window}
    return (fn, args, in_sh, out_sh, mesh, model), meta


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            swa_window: int = 0, fsdp: Optional[bool] = None,
            overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    t0 = time.time()
    built, meta = (None, None)
    try:
        res = build_dryrun(arch, shape_name, multi_pod=multi_pod,
                           swa_window=swa_window, fsdp=fsdp,
                           overrides=overrides)
        built, meta = res
        if built is None:
            return {"arch": arch, "shape": shape_name,
                    "multi_pod": multi_pod, "status": "skipped",
                    "reason": meta}
        fn, args, in_sh, out_sh, mesh, model = built
        donate = (0,) if meta["mode"] == "train" else \
            ((1,) if meta["mode"] == "decode" else ())
        with mesh_context(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        mc = module_cost(hlo)  # loop-expanded per-device flops/bytes/coll
        n_dev = int(np.prod(list(mesh.shape.values())))
        result = {
            **meta,
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "n_devices": n_dev,
            # loop-expanded, per-device (the compiled module is the
            # per-device partitioned program)
            "flops_per_device": float(mc["flops"]),
            "hlo_bytes_per_device": float(mc["bytes"]),
            "analytic_bytes_per_device": float(
                analytic_bytes(model, SHAPES[shape_name], n_dev)),
            "xla_cost_flops_loop_once": float(cost.get("flops", -1)),
            "collectives": {k.replace("coll_", ""): float(v)
                            for k, v in mc.items()
                            if k.startswith("coll")},
            "memory": {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "peak_bytes": int(getattr(mem, "temp_size_in_bytes", 0))
                + int(getattr(mem, "argument_size_in_bytes", 0))
                - int(getattr(mem, "alias_size_in_bytes", 0)),
                # XLA:CPU upcasts bf16 dot operands to f32 (no native bf16
                # matmul), doubling weight/cache transients that a TPU
                # keeps in bf16; halving temp approximates the TPU figure.
                "peak_bytes_tpu_adj": int(getattr(mem, "argument_size_in_bytes", 0))
                - int(getattr(mem, "alias_size_in_bytes", 0))
                + int(getattr(mem, "temp_size_in_bytes", 0)) // 2,
            },
        }
        result["roofline"] = roofline_terms(result)
        return result
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
                "compile_s": round(time.time() - t0, 1)}


def analytic_bytes(model, sc, n_dev: int) -> float:
    """Per-device HBM-traffic floor (documented in EXPERIMENTS.md):
    CPU-lowered HLO fragments fusions, so op-level byte counts overestimate
    TPU traffic; this floor counts the unavoidable passes over params,
    optimizer state, activations and caches given the step type."""
    from ..models.common import tree_size, dt as _dt
    import numpy as _np
    pshapes = model.param_shapes()
    pbytes = sum(int(_np.prod(x.shape)) * x.dtype.itemsize
                 for x in jax.tree_util.tree_leaves(pshapes))
    cfg = model.cfg
    B, S = sc.global_batch, sc.seq_len
    act_tok_bytes = cfg.d_model * 2  # bf16 residual stream
    L = cfg.n_layers
    if sc.mode == "train":
        # params: read fwd + read bwd + grad write (bf16) ; opt: m,v r/w f32
        param_traffic = 3 * pbytes + 4 * tree_size(pshapes) * 4
        acts = 12 * B * S * act_tok_bytes * L  # ~12 materializations/layer
        logits = 4 * B * S * cfg.vocab_size * 2
        total = param_traffic + acts + logits
    elif sc.mode == "prefill":
        acts = 8 * B * S * act_tok_bytes * L
        cache = 2 * tree_size(jax.eval_shape(
            lambda: model.init_cache(B, model.cache_capacity(S)))) * 2
        total = pbytes + acts + cache
    else:
        cache_tree = jax.eval_shape(
            lambda: model.init_cache(B, model.cache_capacity(S)))
        cache_bytes = sum(int(_np.prod(x.shape)) * x.dtype.itemsize
                          for x in jax.tree_util.tree_leaves(cache_tree))
        total = pbytes + 2 * cache_bytes + 8 * B * act_tok_bytes * L
    return total / n_dev


def roofline_terms(res: Dict[str, Any]) -> Dict[str, float]:
    """Three roofline terms in seconds (per-device convention: the compiled
    module is already the per-device partitioned program). The memory term
    uses the analytic floor; the HLO op-level bytes are recorded alongside
    as an upper bound (CPU fusion granularity inflates them)."""
    flops = max(res.get("flops_per_device", 0.0), 0.0)
    byts = max(res.get("analytic_bytes_per_device", 0.0), 0.0)
    byts_hi = max(res.get("hlo_bytes_per_device", 0.0), 0.0)
    coll = res.get("collectives", {}).get("total", 0.0)
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = byts / HW["hbm_bw"]
    t_coll = coll / HW["ici_bw"]
    dom = max((("compute", t_compute), ("memory", t_memory),
               ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_memory_upper_s": byts_hi / HW["hbm_bw"],
            "t_collective_s": t_coll, "bottleneck": dom}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--swa-window", type=int, default=0)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--fsdp", type=int, default=-1,
                    help="-1 auto, 0 off, 1 on")
    args = ap.parse_args()
    fsdp = None if args.fsdp < 0 else bool(args.fsdp)

    combos = []
    if args.all:
        for a in ALL_ARCHS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    results = []
    for arch, shape in combos:
        res = run_one(arch, shape, multi_pod=args.multi_pod,
                      swa_window=args.swa_window, fsdp=fsdp)
        results.append(res)
        line = {k: v for k, v in res.items() if k not in ("trace",)}
        print(json.dumps(line))
        if args.out_dir:
            import pathlib
            pathlib.Path(args.out_dir).mkdir(parents=True, exist_ok=True)
            tag = f"{arch}_{shape}_{'mp' if args.multi_pod else 'sp'}"
            with open(f"{args.out_dir}/{tag}.json", "w") as f:
                json.dump(res, f, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
