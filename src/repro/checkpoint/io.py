"""Minimal sharded checkpointing: pytree -> npz shards + json index.

Leaves are flattened by tree path; shards capped at ``shard_bytes`` so large
models split across files. No orbax dependency (offline container).

On top of the single-pytree primitives sits the **expert store** — the
cold tier of the serving hub's lifecycle (``serve/hub.py``): one
directory per expert under a store root, each holding its params
checkpoint plus a ``meta.json``. ``save_expert`` / ``load_expert`` /
``list_experts`` are the whole store API; the hub stages experts from
here into host memory and commits them into device bank slots on
demand, so the expert catalog can grow far beyond device memory.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# npz cannot store ml_dtypes (bfloat16 etc.); store as a bit-identical
# unsigned view and restore from the recorded dtype string.
_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
         "float8_e5m2": np.uint8}


def _paths(tree) -> Dict[str, np.ndarray]:
    flat = {}

    def rec(node, prefix):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], f"{prefix}/{k}" if prefix else str(k))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, f"{prefix}/{i}")
        else:
            flat[prefix] = np.asarray(node)

    rec(tree, "")
    return flat


def save_pytree(tree: PyTree, directory: str,
                shard_bytes: int = 512 << 20) -> None:
    os.makedirs(directory, exist_ok=True)
    flat = _paths(tree)
    index, shard, size, sid = {}, {}, 0, 0

    def flush():
        nonlocal shard, size, sid
        if shard:
            np.savez(os.path.join(directory, f"shard{sid}.npz"), **shard)
            sid += 1
            shard, size = {}, 0

    for key, arr in flat.items():
        if size + arr.nbytes > shard_bytes and shard:
            flush()
        safe = key.replace("/", "__")
        stored = arr
        if str(arr.dtype) in _VIEW:
            stored = arr.view(_VIEW[str(arr.dtype)])
        shard[safe] = stored
        index[key] = {"shard": sid, "key": safe,
                      "shape": list(arr.shape), "dtype": str(arr.dtype)}
        size += arr.nbytes
    flush()
    with open(os.path.join(directory, "index.json"), "w") as f:
        json.dump(index, f)


def load_pytree(directory: str, like: PyTree = None) -> PyTree:
    """Load; if ``like`` given, restore that exact pytree structure."""
    with open(os.path.join(directory, "index.json")) as f:
        index = json.load(f)
    shards = {}
    flat = {}
    for key, meta in index.items():
        sid = meta["shard"]
        if sid not in shards:
            shards[sid] = np.load(
                os.path.join(directory, f"shard{sid}.npz"))
        arr = shards[sid][meta["key"]]
        if meta["dtype"] in _VIEW:
            arr = arr.view(jnp.dtype(meta["dtype"]))
        flat[key] = arr
    if like is None:
        return _unflatten(flat)
    ref = _paths(like)
    assert set(ref) == set(flat), "checkpoint/pytree structure mismatch"
    return _unflatten({k: flat[k] for k in ref})


# ---------------------------------------------------------------------------
# Expert store: <root>/<name>/{index.json, shard*.npz, meta.json}
# ---------------------------------------------------------------------------


_NAME_OK = re.compile(r"[A-Za-z0-9][A-Za-z0-9._\-@+]*\Z")


def _expert_dir(root: str, name: str) -> str:
    # expert names come from user-facing catalogs and become directory
    # names; munging bad names would let two distinct experts collide
    # onto one directory (silently overwriting each other's weights),
    # so reject them instead
    if not _NAME_OK.match(name):
        raise ValueError(
            f"expert name {name!r} is not a safe store directory name "
            "(want [A-Za-z0-9][A-Za-z0-9._-@+]*)")
    return os.path.join(root, name)


def save_expert(root: str, name: str, params: PyTree,
                meta: Optional[Dict[str, Any]] = None,
                shard_bytes: int = 512 << 20) -> str:
    """Write one expert's params (+ json-able ``meta``) under the store
    root; returns the expert's directory (the hub catalog's cold
    pointer)."""
    d = _expert_dir(root, name)
    save_pytree(params, d, shard_bytes=shard_bytes)
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump({"name": name, **(meta or {})}, f)
    return d


def load_expert(root: str, name: str, like: PyTree = None) -> PyTree:
    """Stage one expert's params from the cold store into host memory."""
    return load_pytree(_expert_dir(root, name), like=like)


def load_expert_meta(root: str, name: str) -> Dict[str, Any]:
    with open(os.path.join(_expert_dir(root, name), "meta.json")) as f:
        return json.load(f)


def list_experts(root: str) -> List[str]:
    """Expert names present in the store (sorted, for determinism)."""
    if not os.path.isdir(root):
        return []
    out = []
    for entry in sorted(os.listdir(root)):
        if os.path.isfile(os.path.join(root, entry, "meta.json")):
            with open(os.path.join(root, entry, "meta.json")) as f:
                out.append(json.load(f)["name"])
    return out


def expert_nbytes(root: str, name: str) -> int:
    """On-disk checkpoint size — the hub's stage-cost signal."""
    d = _expert_dir(root, name)
    return sum(os.path.getsize(os.path.join(d, f))
               for f in os.listdir(d) if f.endswith(".npz"))


def _unflatten(flat: Dict[str, np.ndarray]) -> PyTree:
    root: Dict = {}
    for key, arr in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr

    def fix(node):
        if isinstance(node, dict):
            keys = list(node)
            if keys and all(k.isdigit() for k in keys):
                return [fix(node[str(i)]) for i in range(len(keys))]
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)
