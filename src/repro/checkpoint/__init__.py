from .io import (expert_nbytes, list_experts, load_expert,
                 load_expert_meta, load_pytree, save_expert, save_pytree)

__all__ = ["save_pytree", "load_pytree", "save_expert", "load_expert",
           "load_expert_meta", "list_experts", "expert_nbytes"]
