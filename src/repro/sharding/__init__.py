from .context import (axis_size, current_mesh, leading_sharding, mesh_context,
                      shard_act)
from .rules import param_specs, batch_spec, divisible

__all__ = [
    "axis_size",
    "current_mesh",
    "leading_sharding",
    "mesh_context",
    "shard_act",
    "param_specs",
    "batch_spec",
    "divisible",
]
