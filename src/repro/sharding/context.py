"""Mesh context + activation sharding constraints.

Models call ``shard_act(x, ("data", None, "model"))`` at key points; when no
mesh is active (CPU smoke tests) this is a no-op, under a mesh it becomes a
``with_sharding_constraint`` so GSPMD pins the layout instead of guessing.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        if mesh is not None:
            with mesh:  # legacy mesh context (enables pjit-style lowering)
                yield mesh
        else:
            yield None
    finally:
        _STATE.mesh = prev


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or name not in mesh.shape:
        return 1
    return mesh.shape[name]


def _clean_spec(mesh: Mesh, spec: Sequence, shape) -> P:
    """Drop axes that don't exist in the mesh or don't divide the dim."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.shape)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if not axes or total == 1 or dim % total:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def shard_act(x, spec: Sequence):
    """Best-effort activation sharding constraint (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(spec) != x.ndim:
        return x
    p = _clean_spec(mesh, spec, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, p))


def leading_sharding(tree, axis: str, mesh: Optional[Mesh] = None):
    """Pytree of NamedShardings that split every leaf's *leading* dim over
    ``axis`` (replicating leaves the axis size does not divide).

    This is the layout contract of banked expert serving: expert-stacked
    params / caches / token buffers all carry the expert index as dim 0,
    so one spec pytree places the whole bank. Returns ``None`` when there
    is no usable mesh, so callers can fall back to unsharded jit.
    """
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None or axis not in mesh.shape or mesh.shape[axis] == 1:
        return None
    n = mesh.shape[axis]

    def leaf(x):
        shape = getattr(x, "shape", ())
        if len(shape) >= 1 and shape[0] % n == 0:
            return NamedSharding(mesh, jax.sharding.PartitionSpec(axis))
        return NamedSharding(mesh, jax.sharding.PartitionSpec())

    return jax.tree_util.tree_map(leaf, tree)
