"""Path/name-based parameter PartitionSpec rules.

Given a pytree of param shapes (from ``jax.eval_shape``) and a mesh, produce
a matching pytree of ``PartitionSpec``. Rules are keyed on the leaf name and
expressed over the *trailing* dims (layer-stacked params get leading ``None``
padding automatically). Every sharded dim is checked for divisibility by the
mesh-axis size; the first valid candidate wins, else the leaf is replicated.

``fsdp=True`` additionally shards the largest replicated dim of every big
matrix over the ``data`` axis (ZeRO-3 / FSDP style — GSPMD inserts the
per-layer all-gathers inside the scan-over-layers loop).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf name -> ordered candidates over trailing dims
_RULES: Dict[str, Sequence[Tuple]] = {
    # embeddings
    "embed": [("model", None), (None, "model")],
    "unembed": [(None, "model"), ("model", None)],
    "stub_proj": [(None, "model")],
    # attention
    "wq": [(None, "model")],
    "wk": [(None, "model")],
    "wv": [(None, "model")],
    "bq": [("model",)],
    "bk": [("model",)],
    "bv": [("model",)],
    "wo": [("model", None)],
    # dense mlp: trailing (D, F) / (F, D)
    "w_gate": [(None, "model")],
    "w_up": [(None, "model")],
    "w_down": [("model", None)],
    # moe experts: trailing (E, D, F) / (E, F, D) — expert-parallel over the
    # model axis when E divides it, else tensor-parallel within experts
    "moe/w_gate": [("model", None, None), (None, None, "model")],
    "moe/w_up": [("model", None, None), (None, None, "model")],
    "moe/w_down": [("model", None, None), (None, "model", None)],
    "router": [()],
    # mamba2
    "w_in_x": [(None, "model")],
    "w_in_z": [(None, "model")],
    "w_B": [()],
    "w_C": [()],
    "w_dt": [(None, "model")],
    "conv_x": [(None, "model")],
    "A_log": [("model",)],
    "D_skip": [("model",)],
    "dt_bias": [("model",)],
    "ssm_norm": [("model",)],
    "w_out": [("model", None)],
    # rwkv6
    "w_r": [(None, "model")],
    "w_kk": [(None, "model")],
    "w_vv": [(None, "model")],
    "w_g": [(None, "model")],
    "w_o2": [("model", None)],
    "decay_w0": [("model", None)],
    "first_u": [("model", None)],
    "w_ch_k": [(None, "model")],
    "w_ch_v": [("model", None)],
    "w_ch_r": [()],
}

_REPLICATED_SUFFIXES = (
    "ln", "scale", "bias", "norm", "mu", "lora", "maa", "pos_embed",
)


def divisible(dim: int, axes, mesh_shape: Dict[str, int]) -> bool:
    axes = axes if isinstance(axes, tuple) else (axes,)
    total = 1
    for a in axes:
        total *= mesh_shape.get(a, 1)
    return total <= dim and dim % total == 0


def _candidate_ok(shape, cand, mesh_shape) -> bool:
    if len(cand) > len(shape):
        return False
    trail = shape[len(shape) - len(cand):]
    for dim, ax in zip(trail, cand):
        if ax is not None and not divisible(dim, ax, mesh_shape):
            return False
    return True


def _apply_fsdp(shape, spec: Tuple, mesh_shape, min_size: int) -> Tuple:
    """Shard the largest un-sharded dim over 'data' for big params."""
    if int(np.prod(shape)) < min_size or "data" not in mesh_shape:
        return spec
    spec = list(spec)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if spec[i] is None and divisible(shape[i], "data", mesh_shape):
            spec[i] = "data"
            return tuple(spec)
    return tuple(spec)


def spec_for_leaf(name: str, shape, mesh_shape: Dict[str, int], *,
                  fsdp: bool = False, fsdp_min_size: int = 1 << 20) -> P:
    parts = name.split("/")
    leaf = parts[-1]
    qualified = "/".join(parts[-2:]) if len(parts) >= 2 else leaf
    spec: Optional[Tuple] = None
    if any(leaf.endswith(sfx) or sfx in leaf for sfx in _REPLICATED_SUFFIXES):
        spec = (None,) * len(shape)
    else:
        cands = _RULES.get(qualified) or _RULES.get(leaf)
        for cand in (cands or ()):
            if _candidate_ok(shape, cand, mesh_shape):
                spec = (None,) * (len(shape) - len(cand)) + tuple(cand)
                break
    if spec is None:
        spec = (None,) * len(shape)
    if fsdp:
        spec = _apply_fsdp(shape, spec, mesh_shape, fsdp_min_size)
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_specs(shape_tree: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    """Pytree of PartitionSpec matching ``shape_tree`` (of ShapeDtypeStruct)."""
    mesh_shape = dict(mesh.shape)

    def leaf(path, x):
        return spec_for_leaf(_path_str(path), x.shape, mesh_shape, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(leaf, shape_tree)


def batch_spec(shape_tree: Any, mesh: Mesh) -> Any:
    """Shard the leading (batch) dim over (pod, data); replicate the rest.
    Scalars and dims not divisible stay replicated."""
    mesh_shape = dict(mesh.shape)
    baxes = tuple(a for a in ("pod", "data") if a in mesh_shape)

    def leaf(x):
        if not x.shape:
            return P()
        if baxes and divisible(x.shape[0], baxes, mesh_shape):
            return P(baxes if len(baxes) > 1 else baxes[0],
                     *([None] * (len(x.shape) - 1)))
        # long-context single-sequence caches: shard the seq dim over data
        if len(x.shape) >= 2 and "data" in mesh_shape and \
                divisible(x.shape[1], "data", mesh_shape):
            return P(None, "data", *([None] * (len(x.shape) - 2)))
        return P(*([None] * len(x.shape)))

    return jax.tree_util.tree_map(leaf, shape_tree)


def named(tree_specs, mesh: Mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree_specs)


# ---------------------------------------------------------------------------
# Decode-cache specs (name + shape heuristics per cache family)
# ---------------------------------------------------------------------------

_CACHE_KV = ("k", "v", "xk", "xv", "attn_k", "attn_v")
_CACHE_HEADED = ("ssm", "S")  # (L, B, H, ...)


def cache_specs(shape_tree, mesh: Mesh, batch_size: int):
    """PartitionSpecs for decode caches.

    KV caches (L, B, C, KV, dh): batch over (pod, data); KV heads over
    model when divisible. For batch=1 long-context decode the *sequence*
    dim is sharded over data instead (sequence-parallel cache).
    SSM/WKV states (L, B, H, ...): batch over data, heads over model.
    """
    mesh_shape = dict(mesh.shape)
    baxes = tuple(a for a in ("pod", "data") if a in mesh_shape)
    batch_ok = baxes and divisible(batch_size, baxes, mesh_shape)

    def leaf(path, x):
        name = _path_str(path).split("/")[-1]
        nd = len(x.shape)
        if nd == 0 or name in ("pos", "attn_pos", "t"):
            return P(*([None] * nd))
        spec = [None] * nd
        if name in _CACHE_KV and nd == 5:  # (L, B, C, KV, dh)
            if batch_ok:
                spec[1] = baxes if len(baxes) > 1 else baxes[0]
            elif divisible(x.shape[2], "data", mesh_shape):
                spec[2] = "data"
            if divisible(x.shape[3], "model", mesh_shape):
                spec[3] = "model"
            elif spec[2] is None and divisible(x.shape[2], "model",
                                               mesh_shape):
                # GQA kv-heads don't divide the model axis (e.g. kv=8 on a
                # 16-way axis): shard the cache *sequence* dim instead —
                # decode attention becomes a flash-style partial softmax
                # and only (B, H)-sized score stats cross the axis, vs.
                # replicating the whole cache per device
                spec[2] = "model"
            elif spec[2] == "data" and divisible(
                    x.shape[2] // mesh_shape.get("data", 1), "model",
                    mesh_shape):
                spec[2] = ("data", "model")
        elif nd >= 3:  # states: (L, B, H, ...), conv: (L, B, W-1, C)
            if batch_ok:
                spec[1] = baxes if len(baxes) > 1 else baxes[0]
            # shard the largest remaining dim over model if divisible
            rest = sorted(range(2, nd), key=lambda i: -x.shape[i])
            for i in rest:
                if divisible(x.shape[i], "model", mesh_shape):
                    spec[i] = "model"
                    break
        elif nd == 2 and batch_ok:  # (B, ...) token buffers
            if divisible(x.shape[0], baxes, mesh_shape):
                spec[0] = baxes if len(baxes) > 1 else baxes[0]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, shape_tree)
