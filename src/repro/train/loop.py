"""Distributed LM training substrate.

``make_train_step(model, ...)`` builds the jittable step used both by the
real trainer (CPU smoke / examples) and by the multi-pod dry-run:

    state, metrics = train_step(state, batch)

with state = {params, opt, step}; gradient microbatching (accumulation via
``lax.scan`` over microbatch splits) and global-norm clipping included.
Sharding is applied at the jit boundary (in_shardings from
repro.sharding.rules); inside, shard_act constraints pin activations.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import BaseModel
from ..optim import adamw_init, adamw_update, cosine_warmup

PyTree = Any


def make_train_step(model: BaseModel, *, lr_fn=None, weight_decay: float = 0.0,
                    clip_norm: Optional[float] = 1.0,
                    microbatches: Optional[int] = None):
    lr_fn = lr_fn or cosine_warmup(3e-4, warmup_steps=100, total_steps=10_000)
    mb = microbatches or model.cfg.train_microbatches or 1

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        bdim = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if mb > 1 and bdim % mb == 0:
            split = jax.tree_util.tree_map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                batch)

            def micro(carry, mbatch):
                gsum, lsum = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mbatch)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, grads)
                return (gsum, lsum + loss), None

            gzero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (gzero, jnp.float32(0)),
                                           split)
            grads = jax.tree_util.tree_map(lambda g: g / mb, gsum)
            loss = lsum / mb
        else:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        lr = lr_fn(opt["step"])
        new_params, new_opt = adamw_update(
            grads, opt, params, lr, weight_decay=weight_decay,
            clip_norm=clip_norm)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "lr": lr}

    return train_step


def init_train_state(model: BaseModel, rng) -> Dict:
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_shapes(model: BaseModel) -> Dict:
    return jax.eval_shape(lambda: init_train_state(model,
                                                   jax.random.PRNGKey(0)))


class Trainer:
    """Single-host convenience trainer (examples / integration tests)."""

    def __init__(self, model: BaseModel, *, lr: float = 3e-4,
                 total_steps: int = 1000, seed: int = 0, **step_kw):
        self.model = model
        lr_fn = cosine_warmup(lr, warmup_steps=min(100, total_steps // 10),
                              total_steps=total_steps)
        self.state = init_train_state(model, jax.random.PRNGKey(seed))
        self._step = jax.jit(make_train_step(model, lr_fn=lr_fn, **step_kw))
        self.history = []

    def fit(self, stream: Iterator[Dict[str, np.ndarray]], steps: int,
            log_every: int = 50, callback: Optional[Callable] = None):
        for i in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
            self.state, metrics = self._step(self.state, batch)
            if i % log_every == 0 or i == steps - 1:
                loss = float(metrics["loss"])
                self.history.append((i, loss))
                if callback:
                    callback(i, metrics)
        return self.history
