from .loop import (Trainer, init_train_state, make_train_step,
                   train_state_shapes)

__all__ = ["Trainer", "init_train_state", "make_train_step",
           "train_state_shapes"]
