"""Paper's experimental protocol: 50/25/25% server / Client A / Client B
non-overlapping splits (Table 1), plus the LM-side token pipeline used by
the training substrate."""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np

from . import preprocess, synthetic


def server_client_split(x: np.ndarray, y: np.ndarray, seed: int = 0):
    """Returns dict(server=(x, y), client_a=..., client_b=...)."""
    n = len(x)
    perm = np.random.default_rng(seed).permutation(n)
    n_server = n // 2
    n_a = n // 4
    si = perm[:n_server]
    ai = perm[n_server:n_server + n_a]
    bi = perm[n_server + n_a:n_server + 2 * n_a]
    return {
        "server": (x[si], y[si]),
        "client_a": (x[ai], y[ai]),
        "client_b": (x[bi], y[bi]),
    }


def load_benchmark(names=None, n_per_dataset=None, seed: int = 0):
    """Generate + preprocess + split the full 6-dataset benchmark.

    Returns {name: {split: (x784, y)}} with x784 (N, 784) float32.
    ``n_per_dataset`` caps sample counts for fast tests.
    """
    names = names or list(synthetic.SPECS)
    out = {}
    for name in names:
        x, y = synthetic.generate(name, n_per_dataset, seed)
        x784 = preprocess.to_784(x)
        out[name] = server_client_split(x784, y, seed)
    return out


# ---------------------------------------------------------------------------
# LM token pipeline (training substrate)
# ---------------------------------------------------------------------------


def synthetic_token_stream(vocab_size: int, seq_len: int, batch: int,
                           seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of (tokens, labels) batches with Zipfian marginals
    and local n-gram structure (so losses actually decrease)."""
    rng = np.random.default_rng(seed)
    zipf = 1.0 / np.arange(1, vocab_size + 1) ** 1.05
    zipf = zipf / zipf.sum()
    trans_shift = rng.integers(1, vocab_size, size=64)
    while True:
        base = rng.choice(vocab_size, size=(batch, seq_len + 1), p=zipf)
        # inject deterministic bigram structure on half the positions
        mask = rng.random((batch, seq_len)) < 0.5
        nxt = (base[:, :-1] + trans_shift[base[:, :-1] % 64]) % vocab_size
        base[:, 1:][mask] = nxt[mask]
        yield {"tokens": base[:, :-1].astype(np.int32),
               "labels": base[:, 1:].astype(np.int32)}
