"""Paper Sec. 4 preprocessing: images resized to 28x28 and flattened to 784;
1-D modalities (HAR, Reuters) adaptive-avg-pooled to 784."""
from __future__ import annotations

import numpy as np


def resize_image(x: np.ndarray, out_hw=(28, 28)) -> np.ndarray:
    """Bilinear-ish resize via area averaging. x: (N, H, W)."""
    N, H, W = x.shape
    oh, ow = out_hw
    if (H, W) == (oh, ow):
        return x
    ys = np.linspace(0, H - 1, oh)
    xs = np.linspace(0, W - 1, ow)
    yi = np.clip(ys.astype(int), 0, H - 2)
    xi = np.clip(xs.astype(int), 0, W - 2)
    fy = (ys - yi)[None, :, None]
    fx = (xs - xi)[None, None, :]
    a = x[:, yi][:, :, xi]
    b = x[:, yi + 1][:, :, xi]
    c = x[:, yi][:, :, xi + 1]
    d = x[:, yi + 1][:, :, xi + 1]
    return ((1 - fy) * (1 - fx) * a + fy * (1 - fx) * b
            + (1 - fy) * fx * c + fy * fx * d)


def adaptive_avg_pool_1d(x: np.ndarray, out_dim: int = 784) -> np.ndarray:
    """Torch-style AdaptiveAvgPool1d. x: (N, D) -> (N, out_dim)."""
    N, D = x.shape
    if D == out_dim:
        return x
    if D < out_dim:  # upsample by linear interpolation
        pos = np.linspace(0, D - 1, out_dim)
        lo = np.clip(pos.astype(int), 0, D - 2)
        f = pos - lo
        return (1 - f) * x[:, lo] + f * x[:, lo + 1]
    starts = (np.arange(out_dim) * D) // out_dim
    ends = ((np.arange(out_dim) + 1) * D + out_dim - 1) // out_dim
    out = np.empty((N, out_dim), x.dtype)
    for j in range(out_dim):
        out[:, j] = x[:, starts[j]:ends[j]].mean(axis=1)
    return out


def to_784(x: np.ndarray) -> np.ndarray:
    """Any raw modality -> (N, 784) float32 (the matcher's input space)."""
    if x.ndim == 3:  # image (N, H, W)
        return resize_image(x).reshape(len(x), -1).astype(np.float32)
    if x.ndim == 2:
        return adaptive_avg_pool_1d(x).astype(np.float32)
    raise ValueError(f"unsupported raw shape {x.shape}")
