"""Synthetic generative analogues of the paper's 6 benchmark datasets.

The container is offline, so STL-10 / MNIST / HAR / Reuters / NLOS / DR
cannot be downloaded. Each generator reproduces the *statistics the paper's
claims depend on* (Table 1): sample counts, class counts, LC/SC class skew,
input dimensionality and modality structure — with per-dataset distinct
generative processes so reconstruction error separates them, and
within-dataset class structure so fine-grained matching is non-trivial.

All generators return (x (N, raw_dim...), y (N,)) in numpy; preprocessing
(resize->784 / adaptive-avg-pool->784) lives in ``preprocess.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    kind: str          # image | sensor | text
    n_classes: int
    n_samples: int
    raw_dim: Tuple[int, ...]
    lc_sc: Tuple[float, float]  # largest/smallest class percentage


SPECS: Dict[str, DatasetSpec] = {
    "stl10": DatasetSpec("stl10", "image", 10, 13_000, (32, 32), (10.0, 10.0)),
    "mnist": DatasetSpec("mnist", "image", 10, 10_000, (28, 28), (11.35, 8.92)),
    "har": DatasetSpec("har", "sensor", 6, 10_299, (561,), (19.0, 14.0)),
    "reuters": DatasetSpec("reuters", "text", 4, 10_000, (2000,), (43.12, 8.14)),
    "nlos": DatasetSpec("nlos", "image", 3, 45_096, (28, 28), (33.33, 33.33)),
    "db": DatasetSpec("db", "image", 3, 3_540, (28, 28), (33.33, 33.33)),
}


def _class_sizes(spec: DatasetSpec, n: int) -> np.ndarray:
    """Interpolate class sizes between SC and LC percentages."""
    lc, sc = spec.lc_sc
    fracs = np.linspace(sc, lc, spec.n_classes)
    fracs = fracs / fracs.sum()
    sizes = np.floor(fracs * n).astype(int)
    sizes[-1] += n - sizes.sum()
    return sizes


def _smooth2d(img: np.ndarray, it: int = 2) -> np.ndarray:
    for _ in range(it):
        img = (img + np.roll(img, 1, -1) + np.roll(img, -1, -1)
               + np.roll(img, 1, -2) + np.roll(img, -1, -2)) / 5.0
    return img


def _norm01(x: np.ndarray) -> np.ndarray:
    lo = x.min(axis=tuple(range(1, x.ndim)), keepdims=True)
    hi = x.max(axis=tuple(range(1, x.ndim)), keepdims=True)
    return (x - lo) / np.maximum(hi - lo, 1e-6)


def gen_mnist(spec: DatasetSpec, n: int, seed: int):
    """Digit-like strokes: per-class smooth prototype + elastic jitter."""
    rng = np.random.default_rng(seed)
    H, W = spec.raw_dim
    protos = _smooth2d(rng.normal(size=(spec.n_classes, H, W)), 3)
    protos = (protos > np.quantile(protos, 0.8, axis=(1, 2),
                                   keepdims=True)).astype(np.float32)
    protos = _smooth2d(protos, 1)
    xs, ys = [], []
    for c, sz in enumerate(_class_sizes(spec, n)):
        shift = rng.integers(-2, 3, size=(sz, 2))
        base = np.stack([np.roll(np.roll(protos[c], sx, 0), sy, 1)
                         for sx, sy in shift])
        noise = rng.normal(0, 0.15, size=base.shape)
        xs.append(np.clip(base + noise, 0, 1))
        ys.append(np.full(sz, c))
    return (np.concatenate(xs).astype(np.float32),
            np.concatenate(ys).astype(np.int32))


def gen_stl10(spec: DatasetSpec, n: int, seed: int):
    """Object-like textures: per-class frequency signature + phase noise."""
    rng = np.random.default_rng(seed)
    H, W = spec.raw_dim
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32)
    xs, ys = [], []
    for c, sz in enumerate(_class_sizes(spec, n)):
        fx, fy = 0.3 + 0.25 * c, 0.2 + 0.15 * ((c * 3) % spec.n_classes)
        ph = rng.uniform(0, 2 * np.pi, size=(sz, 2, 1, 1))
        img = (np.sin(fx * xx + ph[:, 0]) * np.cos(fy * yy + ph[:, 1])
               + rng.normal(0, 0.4, size=(sz, H, W)))
        xs.append(_norm01(img))
        ys.append(np.full(sz, c))
    return (np.concatenate(xs).astype(np.float32),
            np.concatenate(ys).astype(np.int32))


def gen_har(spec: DatasetSpec, n: int, seed: int):
    """Accelerometer-feature-like: per-class band-limited sinusoid mixes."""
    rng = np.random.default_rng(seed)
    (D,) = spec.raw_dim
    t = np.linspace(0, 6 * np.pi, D, dtype=np.float32)
    xs, ys = [], []
    for c, sz in enumerate(_class_sizes(spec, n)):
        f = 1.0 + 0.7 * c
        amp = rng.uniform(0.5, 1.5, size=(sz, 1))
        phase = rng.uniform(0, 2 * np.pi, size=(sz, 1))
        sig = (amp * np.sin(f * t + phase)
               + 0.3 * np.sin(2.3 * f * t + 2 * phase)
               + rng.normal(0, 0.2, size=(sz, D)))
        xs.append(_norm01(sig))
        ys.append(np.full(sz, c))
    return (np.concatenate(xs).astype(np.float32),
            np.concatenate(ys).astype(np.int32))


def gen_reuters(spec: DatasetSpec, n: int, seed: int):
    """Zipfian bag-of-words: per-class topic distribution over 2000 terms."""
    rng = np.random.default_rng(seed)
    (V,) = spec.raw_dim
    zipf = 1.0 / np.arange(1, V + 1) ** 1.1
    xs, ys = [], []
    for c, sz in enumerate(_class_sizes(spec, n)):
        topic = np.roll(zipf, 137 * c) * rng.gamma(2.0, 1.0, size=V)
        topic = topic / topic.sum()
        counts = rng.multinomial(200, topic, size=sz).astype(np.float32)
        xs.append(np.log1p(counts))
        ys.append(np.full(sz, c))
    x = np.concatenate(xs).astype(np.float32)
    return _norm01(x), np.concatenate(ys).astype(np.int32)


def gen_nlos(spec: DatasetSpec, n: int, seed: int):
    """Non-line-of-sight-like: diffuse shadow projections of 3 scene types.
    Classes are *coarsely similar* (Fig. 3 caption) — same global blur,
    different occluder geometry."""
    rng = np.random.default_rng(seed)
    H, W = spec.raw_dim
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32) / H
    xs, ys = [], []
    for c, sz in enumerate(_class_sizes(spec, n)):
        cx = rng.uniform(0.3, 0.7, size=(sz, 1, 1))
        cy = rng.uniform(0.3, 0.7, size=(sz, 1, 1))
        if c == 0:  # vertical bar occluder
            occ = np.exp(-((xx - cx) ** 2) / 0.01)
        elif c == 1:  # disk occluder
            occ = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2)) / 0.02)
        else:  # corner wedge
            occ = ((xx > cx) & (yy > cy)).astype(np.float32)
        img = _smooth2d(1.0 - 0.8 * occ + rng.normal(0, 0.05,
                                                     size=(sz, H, W)), 3)
        xs.append(_norm01(img))
        ys.append(np.full(sz, c))
    return (np.concatenate(xs).astype(np.float32),
            np.concatenate(ys).astype(np.int32))


def gen_db(spec: DatasetSpec, n: int, seed: int):
    """Fundus-like: circular retina field + grade-dependent lesion density.
    Hardest fine-grained case (paper FA accuracy 41-44%)."""
    rng = np.random.default_rng(seed)
    H, W = spec.raw_dim
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32)
    cx, cy = W / 2, H / 2
    rad = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2)
    field = (rad < 0.45 * W).astype(np.float32)
    xs, ys = [], []
    for c, sz in enumerate(_class_sizes(spec, n)):
        n_lesions = 2 + 4 * c  # severity grade
        img = np.repeat(field[None] * 0.6, sz, axis=0)
        for _ in range(n_lesions):
            lx = rng.uniform(0.3 * W, 0.7 * W, size=(sz, 1, 1))
            ly = rng.uniform(0.3 * H, 0.7 * H, size=(sz, 1, 1))
            img += 0.35 * np.exp(-(((xx - lx) ** 2 + (yy - ly) ** 2)) / 3.0)
        img += rng.normal(0, 0.05, size=img.shape)
        xs.append(_norm01(_smooth2d(img, 1)))
        ys.append(np.full(sz, c))
    return (np.concatenate(xs).astype(np.float32),
            np.concatenate(ys).astype(np.int32))


_GENERATORS: Dict[str, Callable] = {
    "mnist": gen_mnist, "stl10": gen_stl10, "har": gen_har,
    "reuters": gen_reuters, "nlos": gen_nlos, "db": gen_db,
}


def generate(name: str, n: int | None = None, seed: int = 0):
    """Generate dataset ``name``; n=None uses the paper's sample count."""
    spec = SPECS[name]
    n = n if n is not None else spec.n_samples
    x, y = _GENERATORS[name](spec, n, seed + hash(name) % 10_000)
    perm = np.random.default_rng(seed).permutation(len(x))
    return x[perm], y[perm]
