from .preprocess import adaptive_avg_pool_1d, resize_image, to_784
from .splits import load_benchmark, server_client_split, synthetic_token_stream
from .synthetic import SPECS, generate

__all__ = ["adaptive_avg_pool_1d", "resize_image", "to_784",
           "load_benchmark", "server_client_split", "synthetic_token_stream",
           "SPECS", "generate"]
