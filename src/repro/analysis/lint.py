"""AST lint pass: repo-specific serving-stack hazards (rules L001-L006).

Pure stdlib (``ast``) — importable and runnable without jax, so the CI
job can fail fast before any lowering work starts.

Rules
-----
L001  host sync on a traced value inside jit-traced code: ``int()``/
      ``float()``/``bool()``, ``.item()``/``.tolist()``,
      ``np.asarray``/``np.array`` or ``jax.device_get`` applied to a
      value derived from a traced function's array arguments. Each of
      these either fails under jit or silently blocks the dispatch
      pipeline once per trace.
L002  Python control flow (``if``/``while``/``assert``) testing a
      traced value — a ConcretizationTypeError at trace time, or a
      per-call host block under ``jax.disable_jit``.
L003  use of the private jit ``_cache_size`` API anywhere but the one
      guarded helper in ``serve/core.py`` (``_wrapper_compiles``); the
      API is version-probed there (``COMPILE_COUNTER_EXACT``) and raw
      call sites would crash on jax versions that dropped it.
L004  a ``time.time()``/``perf_counter()`` timed region that dispatches
      device work but never blocks on it (``jax.block_until_ready``,
      ``device_get``, ``np.asarray`` ...): async dispatch means such a
      timer measures *enqueue*, not completion.
L005  unpaired resource lifecycle in the serving clients: an acquire
      (``PagePool.alloc``/``retain``, hub ``pin``, prefix-cache
      ``adopt_prefix``) with no matching release anywhere in the same
      function while later statements can raise — the exception path
      leaks a reference. (The allocator's own modules — ``kvcache.py``
      — maintain these invariants internally and are covered by the
      property tests in ``tests/test_paged_kv.py``, so the pairing
      rule applies to the *client* modules only.)
L006  a prefill/suffix dispatch (``_prefill_fn``/``_suffix_fn``) whose
      shape argument (length bucket, or chunk index) is not derived
      from the bucket ladders — ``bucket_for``/``pad_shape`` results,
      ``chunk_len``/``max_len``, or ``len_buckets``/``batch_buckets``
      elements. A raw length (``toks.shape[1]``, ``len(prompt)``)
      keys a fresh executable per distinct value: the silent
      recompile-per-length regression the bucketed-jit contract (and
      the H004 executable-count bound) exists to prevent. Derivation
      is tracked by *name* across the whole file (the analysis is
      intra-file, not intra-procedural: ``Sb`` blessed by one
      ``bucket_for`` assignment stays blessed when passed as a
      parameter named ``Sb``), which matches the repo idiom of
      threading bucket values under stable names.

Taint model (L001/L002): inside a traced function, positional
parameters are traced arrays; keyword-only parameters are static
configuration (the repo-wide kernel idiom: ``def _kernel(refs..., *,
window, n_blocks)``), and closure variables are host values. Taint
propagates through expressions and assignments; ``.shape``/``.dtype``/
``.ndim`` and ``len()`` escape it. Traced functions are those
decorated with ``jax.jit``-family wrappers or passed (possibly through
``functools.partial``) to ``jit``/``vmap``/``pmap``/``pallas_call``/
``lax`` control-flow combinators in the same file. The analysis is
intra-procedural: a helper called *from* a traced function is only
checked if it is itself traced somewhere.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from . import REPO_ROOT, Violation

# names whose call-argument functions get traced
_TRACING_CALLS = {"jit", "vmap", "pmap", "pallas_call", "scan", "cond",
                  "while_loop", "fori_loop", "switch", "checkpoint",
                  "grad", "value_and_grad", "custom_vjp", "remat"}
# attribute reads that yield host metadata, not a traced value
_UNTAINT_ATTRS = {"shape", "dtype", "ndim", "size", "sharding", "aval",
                  "at"}
_UNTAINT_CALLS = {"len", "isinstance", "type", "getattr", "hasattr",
                  "range", "enumerate", "zip"}
# calls that always yield traced values even with no traced args
_ALWAYS_TRACED_CALLS = {"program_id", "num_programs"}

_HOST_CAST_CALLS = {"int", "float", "bool", "complex"}
_HOST_SYNC_METHODS = {"item", "tolist"}
_NP_ROOTS = {"np", "numpy", "onp"}

# L004: method-name hints for "this call dispatches device work" when
# the callee is repo code rather than a jnp/jax primitive
_DEVICE_HINTS = {"step", "tick", "admit", "admit_wave", "harvest",
                 "prefill", "decode", "generate", "warmup", "drain",
                 "run_step", "service", "dispatch", "install",
                 "pallas_call", "apply"}
_SYNC_CALLS = {"block_until_ready", "device_get", "effects_barrier"}
# jax-rooted calls that only *build* wrappers / traces — no dispatch
_NON_DISPATCH = {"jit", "vmap", "pmap", "grad", "value_and_grad",
                 "partial", "ShapeDtypeStruct", "eval_shape",
                 "named_scope", "lower", "compile"}
_TIME_FNS = {"time", "perf_counter", "monotonic", "process_time"}

# L005 pairing table and client scope
_ACQUIRE_RELEASE = {"alloc": {"release"},
                    "retain": {"release"},
                    "pin": {"unpin"},
                    "adopt_prefix": {"release"}}
_LIFECYCLE_FILES = ("src/repro/serve/core.py",
                    "src/repro/serve/scheduler.py",
                    "src/repro/serve/hub.py",
                    "src/repro/serve/engine.py",
                    "src/repro/serve/router.py")
_SAFE_CALLS = {"append", "pop", "extend", "add", "update", "get",
               "items", "keys", "values", "setdefault", "sort",
               "join", "copy", "len", "int", "str", "list", "dict",
               "tuple", "set", "zip", "range", "enumerate", "sorted",
               "min", "max", "sum", "abs", "isinstance", "format"}

_CACHE_SIZE_HOME = "src/repro/serve/core.py"


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.jit' for Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_name(call: ast.Call) -> Optional[str]:
    return _dotted(call.func)


def _last_attr(name: Optional[str]) -> str:
    return name.rsplit(".", 1)[-1] if name else ""


class _Scope:
    """Maps local names to function nodes (defs and lambda bindings)."""

    def __init__(self) -> None:
        self.by_name: Dict[str, ast.AST] = {}

    def collect(self, body: Iterable[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.by_name[stmt.name] = stmt
            elif isinstance(stmt, ast.Assign) and isinstance(
                    stmt.value, ast.Lambda):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        self.by_name[t.id] = stmt.value


class _Parents(ast.NodeVisitor):
    def __init__(self, tree: ast.AST) -> None:
        self.parent: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node

    def qualname(self, node: ast.AST) -> str:
        names: List[str] = []
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                names.append(cur.name)
            elif isinstance(cur, ast.Lambda):
                names.append("<lambda>")
            cur = self.parent.get(cur)
        return ".".join(reversed(names)) or "<module>"

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parent.get(node)
        while cur is not None and not isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            cur = self.parent.get(cur)
        return cur

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        cur = self.parent.get(node)
        while cur is not None:
            yield cur
            cur = self.parent.get(cur)


# ---------------------------------------------------------------------------
# traced-function discovery
# ---------------------------------------------------------------------------


def _is_tracing_name(name: Optional[str]) -> bool:
    return _last_attr(name) in _TRACING_CALLS


def _resolve_fn_arg(arg: ast.AST, scope: _Scope) -> Optional[ast.AST]:
    """The function node an argument of jit/vmap/... refers to."""
    if isinstance(arg, ast.Lambda):
        return arg
    if isinstance(arg, ast.Name):
        return scope.by_name.get(arg.id)
    if isinstance(arg, ast.Call) and _last_attr(
            _call_name(arg)) == "partial" and arg.args:
        return _resolve_fn_arg(arg.args[0], scope)
    return None


def find_traced_functions(tree: ast.AST) -> Set[ast.AST]:
    """Function/lambda nodes whose bodies run under a jax trace."""
    scope = _Scope()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.FunctionDef,
                             ast.AsyncFunctionDef, ast.ClassDef)):
            scope.collect(node.body)
    traced: Set[ast.AST] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                name = _dotted(dec) if not isinstance(dec, ast.Call) \
                    else _call_name(dec)
                if _is_tracing_name(name):
                    traced.add(node)
                elif isinstance(dec, ast.Call) and _last_attr(
                        _call_name(dec)) == "partial" and dec.args \
                        and _is_tracing_name(_dotted(dec.args[0])):
                    traced.add(node)
        elif isinstance(node, ast.Call) and _is_tracing_name(
                _call_name(node)):
            for arg in list(node.args) + [kw.value for kw in
                                          node.keywords]:
                fn = _resolve_fn_arg(arg, scope)
                if fn is not None:
                    traced.add(fn)
    return traced


# ---------------------------------------------------------------------------
# taint analysis inside one traced function (L001/L002)
# ---------------------------------------------------------------------------


class _Taint:
    def __init__(self, fn: ast.AST) -> None:
        self.tainted: Set[str] = set()
        args = fn.args
        for a in list(args.posonlyargs) + list(args.args):
            if a.arg not in ("self", "cls"):
                self.tainted.add(a.arg)
        if args.vararg:
            self.tainted.add(args.vararg.arg)
        # keyword-only params are static config by repo convention;
        # closure variables are host values: neither seeds taint

    def expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _UNTAINT_ATTRS:
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value)
        if isinstance(node, ast.Call):
            name = _call_name(node)
            last = _last_attr(name)
            if last in _ALWAYS_TRACED_CALLS:
                return True
            if last in _UNTAINT_CALLS:
                return False
            # a method on a traced value yields a traced value
            if isinstance(node.func, ast.Attribute) and self.expr(
                    node.func.value):
                return True
            return any(self.expr(a) for a in node.args) or any(
                self.expr(kw.value) for kw in node.keywords)
        if isinstance(node, (ast.BinOp,)):
            return self.expr(node.left) or self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return self.expr(node.left) or any(
                self.expr(c) for c in node.comparators)
        if isinstance(node, ast.IfExp):
            return (self.expr(node.body) or self.expr(node.orelse)
                    or self.expr(node.test))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        return False

    def assign(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            val = self.expr(stmt.value)
            for t in stmt.targets:
                self._mark(t, val)
        elif isinstance(stmt, ast.AugAssign):
            if self.expr(stmt.value) or self.expr(stmt.target):
                self._mark(stmt.target, True)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._mark(stmt.target, self.expr(stmt.value))
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._mark(stmt.target, self.expr(stmt.iter))

    def _mark(self, target: ast.AST, val: bool) -> None:
        if not val:
            return
        if isinstance(target, ast.Name):
            self.tainted.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._mark(e, True)


def _fn_statements(fn: ast.AST) -> List[ast.stmt]:
    if isinstance(fn, ast.Lambda):
        return []
    return list(fn.body)


def _check_traced_fn(fn: ast.AST, parents: _Parents, path: str
                     ) -> List[Violation]:
    out: List[Violation] = []
    taint = _Taint(fn)
    qual = parents.qualname(fn)
    body = _fn_statements(fn)
    # two forward passes so loop-carried assignments settle
    for _ in range(2):
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign, ast.For)):
                    taint.assign(node)
    nodes = ast.walk(fn.body) if isinstance(fn, ast.Lambda) else \
        iter([n for s in body for n in ast.walk(s)])
    for node in nodes:
        # don't descend into nested defs — they get their own pass if
        # they are themselves traced
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            continue
        if isinstance(node, ast.Call):
            name = _call_name(node)
            last = _last_attr(name)
            tainted_arg = any(taint.expr(a) for a in node.args)
            if isinstance(node.func, ast.Name) and \
                    node.func.id in _HOST_CAST_CALLS and tainted_arg:
                out.append(Violation(
                    "L001", path, node.lineno, qual,
                    f"{node.func.id}() on a traced value forces a "
                    "host sync (ConcretizationTypeError under jit)"))
            elif last in _HOST_SYNC_METHODS and isinstance(
                    node.func, ast.Attribute) and taint.expr(
                        node.func.value):
                out.append(Violation(
                    "L001", path, node.lineno, qual,
                    f".{last}() on a traced value forces a host sync"))
            elif name and "." in name and name.split(".")[0] in \
                    _NP_ROOTS and last in ("asarray", "array") \
                    and tainted_arg:
                out.append(Violation(
                    "L001", path, node.lineno, qual,
                    f"{name}() materialises a traced value on host"))
            elif last == "device_get" and tainted_arg:
                out.append(Violation(
                    "L001", path, node.lineno, qual,
                    "jax.device_get on a traced value inside a traced "
                    "function"))
        elif isinstance(node, (ast.If, ast.While)) and taint.expr(
                node.test):
            out.append(Violation(
                "L002", path, node.lineno, qual,
                "Python branch on a traced value (use jnp.where / "
                "lax.cond / pl.when)"))
        elif isinstance(node, ast.Assert) and taint.expr(node.test):
            out.append(Violation(
                "L002", path, node.lineno, qual,
                "assert on a traced value (use checkify or a static "
                "shape check)"))
    return out


# ---------------------------------------------------------------------------
# L004 — unsynced device timing
# ---------------------------------------------------------------------------


def _is_time_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = _call_name(node) or ""
    return (name.startswith("time.") and _last_attr(name) in _TIME_FNS) \
        or name in ("perf_counter", "monotonic")


def _walk_skip_fns(stmts: Sequence[ast.stmt]) -> List[ast.AST]:
    """All nodes under ``stmts``, not descending into nested ``def``
    bodies (a nested def's body doesn't execute in this region).
    Lambdas ARE descended into: the repo idiom passes them inline to
    eagerly-applied combinators (``tree_map(lambda x:
    x.block_until_ready(), r)``), so their bodies do run here."""
    out: List[ast.AST] = []

    def visit(n: ast.AST) -> None:
        out.append(n)
        for c in ast.iter_child_nodes(n):
            if not isinstance(c, (ast.FunctionDef,
                                  ast.AsyncFunctionDef)):
                visit(c)

    for s in stmts:
        visit(s)
    return out


def _check_timing(fn_body: Sequence[ast.stmt], qual: str, path: str
                  ) -> List[Violation]:
    out: List[Violation] = []
    starts: Dict[str, int] = {}           # var -> lineno of t0 = time.*()
    spans: List[Tuple[str, int, int]] = []  # (var, start_line, end_line)
    nodes = _walk_skip_fns(fn_body)
    for node in nodes:
        if isinstance(node, ast.Assign) and _is_time_call(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    starts[t.id] = node.lineno
        elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub):
            if _is_time_call(node.left) and isinstance(
                    node.right, ast.Name) and node.right.id in starts:
                spans.append((node.right.id, starts[node.right.id],
                              node.lineno))
    for var, lo, hi in spans:
        device: Optional[ast.Call] = None
        synced = False
        for node in nodes:
            line = getattr(node, "lineno", None)
            if line is None or not (lo < line <= hi):
                continue
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node) or ""
            last = _last_attr(name)
            root = name.split(".")[0] if name else ""
            if last in _SYNC_CALLS or (root in _NP_ROOTS and last in
                                       ("asarray", "array")):
                synced = True
            elif (root in ("jnp", "jax") and last not in _NON_DISPATCH) \
                    or last.lstrip("_") in _DEVICE_HINTS:
                device = device or node
        if device is not None and not synced:
            out.append(Violation(
                "L004", path, device.lineno, qual,
                f"timed region ({var}: lines {lo}..{hi}) dispatches "
                f"device work ({_call_name(device)}) with no "
                "block_until_ready/device_get — measures enqueue, not "
                "completion"))
    return out


# ---------------------------------------------------------------------------
# L005 — lifecycle pairing
# ---------------------------------------------------------------------------


def _stmts_after(node: ast.AST, parents: _Parents,
                 fn: ast.AST) -> List[ast.stmt]:
    """Statements that can still execute after ``node`` succeeded,
    walking out through enclosing blocks up to ``fn``. Handlers of an
    enclosing ``try`` are included only when a later try-body statement
    can raise after the acquire; ``finally`` and ``else`` always run."""
    # the statement containing `node`
    cur: Optional[ast.AST] = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = parents.parent.get(cur)
    out: List[ast.stmt] = []
    while cur is not None and cur is not fn:
        block = parents.parent.get(cur)
        if block is None or block is fn and not isinstance(
                fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            break
        hit = False
        for field in ("body", "orelse", "finalbody"):
            seq = getattr(block, field, None)
            if isinstance(seq, list) and cur in seq:
                hit = True
                idx = seq.index(cur)
                out.extend(seq[idx + 1:])
                if isinstance(block, ast.Try) and field == "body":
                    if idx + 1 < len(seq):     # later try-body stmt can
                        for h in block.handlers:  # raise -> handler runs
                            out.extend(h.body)
                    out.extend(block.orelse)
                    out.extend(block.finalbody)
        if not hit and isinstance(block, ast.ExceptHandler) and \
                cur in block.body:
            out.extend(block.body[block.body.index(cur) + 1:])
        if block is fn:
            break
        cur = block if isinstance(
            block, (ast.stmt, ast.excepthandler)) else None
        if cur is None:
            break
    return out


def _check_lifecycles(fn: ast.AST, parents: _Parents, path: str
                      ) -> List[Violation]:
    out: List[Violation] = []
    body = _fn_statements(fn)
    if not body:
        return out
    qual = parents.qualname(fn)
    all_calls = [n for s in body for n in ast.walk(s)
                 if isinstance(n, ast.Call)]
    released = {_last_attr(_call_name(c)) for c in all_calls}
    for call in all_calls:
        attr = _last_attr(_call_name(call))
        if attr not in _ACQUIRE_RELEASE:
            continue
        if not isinstance(call.func, ast.Attribute):
            continue                      # bare name: not a method call
        partners = _ACQUIRE_RELEASE[attr]
        if partners & released:
            continue                      # paired somewhere in the fn
        risky = None
        for stmt in _stmts_after(call, parents, fn):
            for n in ast.walk(stmt):
                if isinstance(n, ast.Call):
                    last = _last_attr(_call_name(n))
                    if last not in _SAFE_CALLS and last not in partners:
                        risky = n
                        break
            if risky is not None:
                break
        if risky is not None:
            out.append(Violation(
                "L005", path, call.lineno, qual,
                f"{attr}() with no matching "
                f"{'/'.join(sorted(partners))} in this function, and a "
                f"later call ({_call_name(risky) or '?'}:{risky.lineno})"
                " can raise — the exception path leaks the reference"))
    return out


# ---------------------------------------------------------------------------
# L006 — prefill dispatch shapes must come from the bucket ladders
# ---------------------------------------------------------------------------

_BUCKET_FNS = {"_prefill_fn", "_suffix_fn", "_verify_fn"}
_BUCKET_SOURCES = {"bucket_for", "pad_shape", "make_buckets"}
_BUCKET_ATTRS = {"chunk_len", "max_len", "len_buckets", "batch_buckets",
                 "page", "speculate_k"}
_BUCKET_CALLS = {"range", "min", "max", "len", "sum", "sorted", "tuple",
                 "list"}


def _collect_blessed(tree: ast.AST) -> Set[str]:
    """Names bound (anywhere in the file) to bucket-ladder-derived
    values. Two propagation passes so chained assignments settle."""
    blessed: Set[str] = set()

    def ok(node: ast.AST) -> bool:
        if isinstance(node, ast.Constant):
            return isinstance(node.value, int)
        if isinstance(node, ast.Name):
            return node.id in blessed
        if isinstance(node, ast.Attribute):
            return node.attr in _BUCKET_ATTRS
        if isinstance(node, ast.Subscript):
            return ok(node.value)
        if isinstance(node, ast.BinOp):
            return ok(node.left) and ok(node.right)
        if isinstance(node, ast.UnaryOp):
            return ok(node.operand)
        if isinstance(node, ast.Call):
            last = _last_attr(_call_name(node))
            if last in _BUCKET_SOURCES:
                return True
            if last in _BUCKET_CALLS:
                return all(ok(a) for a in node.args)
            return False
        if isinstance(node, (ast.Tuple, ast.List)):
            return all(ok(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return ok(node.body) and ok(node.orelse)
        if isinstance(node, ast.BoolOp):
            return all(ok(v) for v in node.values)
        return False

    def mark(target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            blessed.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                mark(e)

    for _ in range(2):
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and ok(node.value):
                for t in node.targets:
                    mark(t)
            elif isinstance(node, ast.AnnAssign) and \
                    node.value is not None and ok(node.value):
                mark(node.target)
            elif isinstance(node, (ast.For, ast.AsyncFor)) and \
                    ok(node.iter):
                mark(node.target)
            elif isinstance(node, ast.comprehension) and ok(node.iter):
                mark(node.target)
    return blessed


def _check_bucket_shapes(tree: ast.AST, parents: _Parents,
                         path: str) -> List[Violation]:
    """L006: the shape-keying argument of every ``_prefill_fn(Bb, Sb)``
    / ``_suffix_fn(Bb, k)`` / ``_verify_fn(Bb, k)`` call site must be
    bucket-derived (``speculate_k`` counts: it is fixed per engine and
    part of the executable ladder, so it keys exactly one extra
    executable family). Only the
    second argument is checked — the batch argument is routinely read
    back off a descriptor array's static shape, which is already
    bucket-sized by construction."""
    out: List[Violation] = []
    blessed = _collect_blessed(tree)

    def ok(node: ast.AST) -> bool:
        if isinstance(node, ast.Constant):
            return isinstance(node.value, int)
        if isinstance(node, ast.Name):
            return node.id in blessed
        if isinstance(node, ast.Attribute):
            return node.attr in _BUCKET_ATTRS
        if isinstance(node, ast.Subscript):
            return ok(node.value)
        if isinstance(node, ast.BinOp):
            return ok(node.left) and ok(node.right)
        if isinstance(node, ast.UnaryOp):
            return ok(node.operand)
        if isinstance(node, ast.Call):
            last = _last_attr(_call_name(node))
            return last in _BUCKET_SOURCES or (
                last in _BUCKET_CALLS and all(ok(a) for a in node.args))
        return False

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _last_attr(_call_name(node)) in _BUCKET_FNS
                and len(node.args) >= 2):
            continue
        arg = node.args[1]
        if not ok(arg):
            fn = _last_attr(_call_name(node))
            out.append(Violation(
                "L006", path, node.lineno, parents.qualname(node),
                f"{fn}() shape argument "
                f"{ast.unparse(arg) if hasattr(ast, 'unparse') else '?'}"
                " is not derived from the bucket ladders (bucket_for/"
                "pad_shape/chunk_len/len_buckets) — every distinct "
                "value keys a fresh XLA executable, breaking the "
                "bounded-compile contract"))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def default_paths(root: str = REPO_ROOT) -> List[str]:
    out: List[str] = []
    for base in ("src/repro", "benchmarks"):
        for dirpath, _dirs, files in os.walk(os.path.join(root, base)):
            for f in sorted(files):
                if f.endswith(".py"):
                    out.append(os.path.join(dirpath, f))
    return out


def lint_source(src: str, path: str) -> List[Violation]:
    """Lint one file's source. ``path`` is the repo-relative name used
    in reports and baseline keys."""
    tree = ast.parse(src, filename=path)
    parents = _Parents(tree)
    out: List[Violation] = []

    # L003 — private _cache_size outside its guarded home
    if path != _CACHE_SIZE_HOME:
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) and \
                    node.attr == "_cache_size":
                out.append(Violation(
                    "L003", path, node.lineno, parents.qualname(node),
                    "private jit._cache_size() outside the guarded "
                    "helper serve/core.py:_wrapper_compiles (use "
                    "serve.core._wrapper_compiles)"))

    # L001/L002 — traced-code hazards
    for fn in find_traced_functions(tree):
        out.extend(_check_traced_fn(fn, parents, path))

    # L004 — unsynced timing, per function and at module level
    fns = [n for n in ast.walk(tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in fns:
        out.extend(_check_timing(fn.body, parents.qualname(fn), path))
    out.extend(_check_timing(
        [s for s in tree.body
         if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef))], "<module>", path))

    # L005 — lifecycle pairing in the client modules
    if any(path.endswith(p) or path == p for p in _LIFECYCLE_FILES):
        for fn in fns:
            out.extend(_check_lifecycles(fn, parents, path))

    # L006 — prefill dispatch shapes come from the bucket ladders
    out.extend(_check_bucket_shapes(tree, parents, path))
    return out


def run(paths: Optional[Sequence[str]] = None,
        root: str = REPO_ROOT) -> List[Violation]:
    out: List[Violation] = []
    for p in (paths or default_paths(root)):
        rel = os.path.relpath(p, root) if os.path.isabs(p) else p
        with open(os.path.join(root, rel), encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), rel.replace(os.sep, "/")))
    return out
