"""HLO contract gate (rules H001-H004): lower every serving dispatch
on a forced 8-device CPU mesh and assert the compiled modules keep the
stack's load-bearing promises.

The serving invariants — in-place KV updates, a host-callback-free
decode tick, GSPMD-sharded bank params, a bounded executable ladder —
are all *silent* to Python: XLA drops an unusable donation with only a
warning, a stray ``jax.debug`` or shape-dependent reshape lowers
happily, and a sharding regression just makes everything slower. This
pass reads the compiled HLO instead of trusting the call sites:

  H001  buffer donation took: every donated argument (the prefill/
        decode KV pool planes, the COW copy pool, the hub install's
        slot stack) appears in the module's ``input_output_alias`` map
        — no alias entry means XLA is double-buffering the engine's
        largest array every dispatch.
  H002  the decode tick is device-pure: no ``custom-call`` host
        callbacks (``xla_python_cpu_callback`` et al.), no infeed/
        outfeed, no ``dynamic-reshape``/``dynamic-pad`` (shape-dynamic
        ops that force a host round-trip or defeat bucketing).
  H003  sharding annotations on the bank params match the placement
        spec: every param leaf of a mesh-built engine's dispatch is
        ``PartitionSpec('expert', ...)`` on the leading axis.
  H004  executable count equals the declared bucket bound after a full
        warmup — ``EngineCore.executable_bounds()``, the one source of
        the ladder arithmetic: monolithic prefills for buckets up to
        ``chunk_len``, one suffix executable per (batch bucket, chunk
        index) pair, ``len(batch_buckets)`` decode steps, one hub
        install, and — on speculating engines — one verify executable
        per batch bucket (``k`` is fixed per engine). The paged hub
        here is built *chunked* so the gate exercises the chunk-ladder
        bound the serving bench asserts; a dedicated spec engine
        drives a wrap-risk admission grid so BOTH the verify family
        and its gate-blocked decode fallback are proven exactly full —
        the zero-steady-state-recompile contract, checked exactly and
        in seconds rather than minutes.

Requires >= 8 devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``
set before jax initialises — the ``python -m repro.analysis`` CLI
re-execs itself into such an environment automatically; pytest callers
use a subprocess, see ``tests/test_analysis.py``).
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

from . import Violation

_CALLBACK_MARKERS = ("callback", "infeed", "outfeed", "send", "recv")
_DYNAMIC_OPS = ("dynamic-reshape", "dynamic-pad")

_HUB_PATH = "src/repro/serve/hub.py"
_CORE_PATH = "src/repro/serve/core.py"


def _require_devices(n: int = 8) -> None:
    import jax
    have = len(jax.devices())
    if have < n:
        raise EnvironmentError(
            f"hlo contract pass needs {n} devices, found {have}; run "
            "via `python -m repro.analysis hlo` (which re-execs with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8) or "
            "set the flag before jax initialises")


def _flat_arg_offsets(args: Sequence[Any]) -> List[Tuple[int, int]]:
    """(first flat param index, leaf count) per positional argument."""
    import jax
    out: List[Tuple[int, int]] = []
    off = 0
    for a in args:
        n = len(jax.tree_util.tree_leaves(a))
        out.append((off, n))
        off += n
    return out


def _avals(tree: Any) -> Any:
    import jax

    def aval(x):
        return jax.ShapeDtypeStruct(tuple(x.shape), x.dtype,
                                    sharding=getattr(x, "sharding", None))
    return jax.tree_util.tree_map(aval, tree)


def check_donation(jitted, args: Sequence[Any], donate: Sequence[int],
                   label: str, path: str = _CORE_PATH,
                   hlo: Optional[str] = None) -> List[Violation]:
    """H001: every leaf of each donated argument must be aliased to an
    output in the compiled module. ``args`` may be concrete or avals."""
    from ..launch.hlo_analysis import input_output_aliases
    out: List[Violation] = []
    if hlo is None:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            hlo = jitted.lower(*args).compile().as_text()
    aliased = set(input_output_aliases(hlo).values())
    offsets = _flat_arg_offsets(args)
    for argnum in donate:
        off, n = offsets[argnum]
        missing = [i for i in range(off, off + n) if i not in aliased]
        if missing:
            out.append(Violation(
                "H001", path, 0, label,
                f"donated argument {argnum} ({n} leaves) not aliased "
                f"in the compiled module (flat params {missing} have "
                "no input_output_alias entry) — XLA dropped the "
                "donation and silently double-buffers the array"))
    return out


def check_clean_decode(hlo: str, label: str,
                       path: str = _CORE_PATH) -> List[Violation]:
    """H002: no host callbacks / infeed / dynamic-shape ops."""
    from ..launch.hlo_analysis import custom_call_targets, op_kinds
    out: List[Violation] = []
    for tgt in custom_call_targets(hlo):
        low = tgt.lower()
        if any(m in low for m in _CALLBACK_MARKERS):
            out.append(Violation(
                "H002", path, 0, label,
                f"decode-tick module calls back into the host "
                f"(custom-call target {tgt!r}) — one host block per "
                "decode step"))
    kinds = op_kinds(hlo)
    for op, n in kinds.items():
        if op in _DYNAMIC_OPS or op.startswith(("infeed", "outfeed")):
            out.append(Violation(
                "H002", path, 0, label,
                f"decode-tick module contains {n}x {op} — shape-"
                "dynamic/host-coupled ops defeat the bucketed "
                "executable contract"))
    return out


def check_bank_sharding(compiled, label: str,
                        bank_args: Sequence[int] = (0,),
                        path: str = _CORE_PATH) -> List[Violation]:
    """H003: every leaf of each bank-stacked argument (stacked params,
    KV pool planes) must be sharded ``PartitionSpec('expert', ...)``.
    ``compiled.input_shardings[0]`` preserves per-argument pytree
    structure, so each listed argument's subtree is flattened here."""
    import jax
    out: List[Violation] = []
    args_shardings = compiled.input_shardings[0]
    for argnum in bank_args:
        leaves = jax.tree_util.tree_leaves(args_shardings[argnum])
        for i, s in enumerate(leaves):
            spec = getattr(s, "spec", None)
            lead = spec[0] if spec is not None and len(spec) else None
            if lead != "expert":
                out.append(Violation(
                    "H003", path, 0, label,
                    f"bank arg {argnum} leaf {i} sharded {spec} — "
                    "placement spec requires PartitionSpec('expert', "
                    "...) on the stacked axis"))
    return out


# ---------------------------------------------------------------------------
# the serving dispatches
# ---------------------------------------------------------------------------


def _tiny_hub(kv_layout: str, with_experts: bool = True,
              chunk_len: "int | None" = None):
    """An 8-slot hub on the full 8-device expert mesh, smallest
    geometry the layout allows. Slots start on zero template params —
    enough to lower every executable; real experts are only needed
    when warmup must drive the install scatter."""
    import jax
    from ..configs import get_config
    from ..launch.mesh import make_expert_mesh
    from ..models import build_model
    from ..serve import ExpertHub

    cfg = get_config("smollm-135m").reduced(name=f"hlo-{kv_layout}")
    model = build_model(cfg)
    mesh = make_expert_mesh()
    hub = ExpertHub(model, n_slots=8, max_len=32,
                    len_buckets=(8, 16), batch_buckets=(1, 2),
                    mesh=mesh, kv_layout=kv_layout,
                    chunk_len=chunk_len)
    if with_experts:
        for i in range(8):
            hub.add_expert(f"ex{i}", model.init(jax.random.PRNGKey(i)))
    return hub


def _lower_paged(core) -> List[Tuple[str, Any, tuple, tuple, str, tuple]]:
    """(label, jitted, args(avals), donate_argnums, kind, bank_args)
    for every ladder point of a paged engine; ``bank_args`` are the
    expert-stacked positional arguments H003 checks."""
    import jax.numpy as jnp
    import jax
    E, C = core.n_experts, core.max_len
    nlp, npp_page = core.n_logical, core.page
    p_av = _avals(core.params)
    pool_av = _avals(core.kv_pool)
    cl = core.chunk_len
    out = []
    for Sb in core.len_buckets:
        if cl is not None and Sb > cl:
            continue    # chunked engines never build monolithic
            #             prefills past chunk_len (executable_bounds)
        for Bb in core.batch_buckets:
            toks = jax.ShapeDtypeStruct((E, Bb, Sb), jnp.int32)
            stbl = jax.ShapeDtypeStruct((E, Bb, Sb // npp_page),
                                        jnp.int32)
            out.append((f"paged_prefill[B{Bb},S{Sb}]",
                        core._prefill_fn(Bb, Sb),
                        (p_av, {"tokens": toks}, pool_av, stbl),
                        (2,), "prefill", (0, 2)))
    if cl is not None:
        # the suffix ladder: chunk index k >= 1, chunk_len tokens at
        # static offset k * chunk_len, prefix pages gathered read-only
        ppc = cl // npp_page
        for k in range(1, max(core.len_buckets) // cl):
            for Bb in core.batch_buckets:
                toks = jax.ShapeDtypeStruct((E, Bb, cl), jnp.int32)
                ptbl = jax.ShapeDtypeStruct((E, Bb, k * ppc), jnp.int32)
                stbl = jax.ShapeDtypeStruct((E, Bb, ppc), jnp.int32)
                out.append((f"paged_suffix[B{Bb},k{k}]",
                            core._suffix_fn(Bb, k),
                            (p_av, {"tokens": toks}, pool_av, ptbl,
                             stbl),
                            (2,), "prefill", (0, 2)))
    for Bb in core.batch_buckets:
        tbl = jax.ShapeDtypeStruct((E, Bb, nlp), jnp.int32)
        pos = jax.ShapeDtypeStruct((E, C), jnp.int32)
        t = jax.ShapeDtypeStruct((E,), jnp.int32)
        tok = jax.ShapeDtypeStruct((E, Bb, 1), jnp.int32)
        out.append((f"paged_decode[B{Bb}]", core._decode_fn(Bb),
                    (p_av, pool_av, tbl, pos, t, {"token": tok}),
                    (1,), "decode", (0, 1)))
    m = 2
    es = jax.ShapeDtypeStruct((m,), jnp.int32)
    out.append((f"cow_copy[m{m}]", core._copy_pages_fn(m),
                (pool_av, es, es, es), (0,), "copy", (0,)))
    return out


def run() -> List[Violation]:
    """Lower/compile every serving dispatch and apply H001-H004."""
    import warnings as _w
    import jax
    import jax.numpy as jnp
    from ..serve.core import COMPILE_COUNTER_EXACT

    _require_devices(8)
    out: List[Violation] = []

    # chunk_len = one page: the hub's 16-bucket prompts split into a
    # chunk-0 prefill plus one suffix chunk, so the warmup ladder
    # drives every executable family the chunked engine owns
    hub = _tiny_hub("paged", chunk_len=8)
    core = hub.bank.core

    # H004 first: warmup drives the whole ladder through the *calling*
    # path the compile counters watch; the AOT lower/compile passes
    # below must not run before the counts are read, or they could
    # perturb the very caches being counted.
    hub.warmup(max_batch=core.batch_buckets[-1], commit=True)
    bounds = core.executable_bounds()
    got_p = core.stats.prefill_compiles
    got_s = core.stats.suffix_compiles
    got_d = core.stats.decode_compiles
    got_i = hub.install_compiles
    cmp_name = "==" if COMPILE_COUNTER_EXACT else ">="

    def bad(got, want):
        return (got != want) if COMPILE_COUNTER_EXACT else (got < want)

    if bad(got_p, bounds["prefill"]):
        out.append(Violation(
            "H004", _CORE_PATH, 0, "prefill_ladder",
            f"prefill executables after full warmup: {got_p}, declared "
            f"bound {cmp_name} {bounds['prefill']} "
            f"(executable_bounds: buckets <= chunk_len x batch_buckets)"))
    if bad(got_s, bounds["suffix"]):
        out.append(Violation(
            "H004", _CORE_PATH, 0, "suffix_ladder",
            f"suffix executables after full warmup: {got_s}, declared "
            f"bound {cmp_name} {bounds['suffix']} "
            f"(executable_bounds: chunk indices x batch_buckets)"))
    if bad(got_d, bounds["decode"]):
        out.append(Violation(
            "H004", _CORE_PATH, 0, "decode_ladder",
            f"decode executables after full warmup: {got_d}, declared "
            f"bound {cmp_name} {bounds['decode']} (batch_buckets)"))
    if COMPILE_COUNTER_EXACT and got_i != 1:
        out.append(Violation(
            "H004", _HUB_PATH, 0, "hub_install",
            f"hub install executables: {got_i}, expected exactly 1 "
            "(slot installs are keyed on bank shape, not expert)"))

    # H001/H002/H003 over the paged ladder
    for label, jitted, args, donate, kind, bank_args in \
            _lower_paged(core):
        with _w.catch_warnings():
            _w.simplefilter("ignore")
            compiled = jitted.lower(*args).compile()
        hlo = compiled.as_text()
        out.extend(check_donation(jitted, args, donate, label, hlo=hlo))
        if kind == "decode":
            out.extend(check_clean_decode(hlo, label))
        out.extend(check_bank_sharding(compiled, label, bank_args))

    # hub slot-install scatter (exists after warmup(commit=True))
    if hub._install is None:
        out.append(Violation(
            "H001", _HUB_PATH, 0, "hub_install",
            "warmup(commit=True) made no commit — cannot lower the "
            "slot install scatter"))
    else:
        iargs = (_avals(core.params), _avals(hub.catalog[0].params),
                 jax.ShapeDtypeStruct((), jnp.int32))
        out.extend(check_donation(hub._install, iargs, (0,),
                                  "hub_install", path=_HUB_PATH))

    # ring layout: the non-paged decode donates its dense cache the
    # same way — template-param hub, lowering only, no warmup needed
    ring = _tiny_hub("ring", with_experts=False)
    rcore = ring.bank.core
    p_av = _avals(rcore.params)
    Bb = rcore.batch_buckets[0]
    Sb = rcore.len_buckets[0]
    toks = jax.ShapeDtypeStruct((rcore.n_experts, Bb, Sb), jnp.int32)
    _, cache_av = jax.eval_shape(rcore._prefill_fn(Bb, Sb),
                                 _avals(rcore.params), {"tokens": toks})
    tok = jax.ShapeDtypeStruct((rcore.n_experts, Bb, 1), jnp.int32)
    args = (p_av, cache_av, {"token": tok})
    jitted = rcore._decode_fn(Bb)
    with _w.catch_warnings():
        _w.simplefilter("ignore")
        compiled = jitted.lower(*args).compile()
    hlo = compiled.as_text()
    out.extend(check_donation(jitted, args, (1,),
                              f"ring_decode[B{Bb}]", hlo=hlo))
    out.extend(check_clean_decode(hlo, f"ring_decode[B{Bb}]"))
    out.extend(check_bank_sharding(compiled, f"ring_decode[B{Bb}]",
                                   (0, 1)))

    # speculative ladder: a dedicated E=1 spec engine (ring, k=2)
    # driven through the *calling* path via generate(). max_len == 16
    # makes the admission grid split cleanly on the no-wrap gate
    # (Sb + steps + k <= max_len): Sb=8 waves speculate — only the
    # verify family compiles — while Sb=16 waves are gate-blocked and
    # fall back to the plain decode family, so after the grid BOTH
    # ladders must sit exactly at their declared bounds.
    import numpy as np
    from ..configs import get_config
    from ..models import build_model
    from ..serve import ExpertEngine

    scfg = get_config("smollm-135m").reduced(name="hlo-spec")
    smodel = build_model(scfg)
    seng = ExpertEngine(smodel, smodel.init(jax.random.PRNGKey(0)),
                        max_len=16, min_len_bucket=8,
                        batch_buckets=(1, 2), speculate_k=2,
                        draft="table")
    score = seng.core
    for Sb_g, max_new in ((8, 4), (16, 2)):
        for Bb_g in score.batch_buckets:
            seng.generate(np.full((Bb_g, Sb_g), 3, np.int32), max_new)
    sbounds = score.executable_bounds()
    got_v = score.stats.verify_compiles
    got_fd = score.stats.decode_compiles
    if bad(got_v, sbounds["verify"]):
        out.append(Violation(
            "H004", _CORE_PATH, 0, "verify_ladder",
            f"verify executables after the speculative grid: {got_v}, "
            f"declared bound {cmp_name} {sbounds['verify']} "
            "(executable_bounds: batch_buckets x one engine-fixed k)"))
    if bad(got_fd, sbounds["decode"]):
        out.append(Violation(
            "H004", _CORE_PATH, 0, "spec_fallback_decode_ladder",
            f"decode executables after gate-blocked (wrap-risk) waves: "
            f"{got_fd}, declared bound {cmp_name} {sbounds['decode']} "
            "— speculation must not mint extra decode variants"))
    if score.stats.spec_fallback_waves == 0:
        out.append(Violation(
            "H004", _CORE_PATH, 0, "spec_fallback_gate",
            "no admission in the wrap-risk grid was gate-blocked — "
            "the no-wrap gate is not exercising the fallback decode "
            "family, so its bound above proved nothing"))

    # H001/H002 over the ring verify executable itself (E=1 engine
    # built without a mesh, so H003 does not apply)
    vk = score.speculate_k
    vBb = score.batch_buckets[0]
    vSb = score.len_buckets[0]
    vE, vC = score.n_experts, score.max_len
    sp_av = _avals(score.params)
    vtoks = jax.ShapeDtypeStruct((vE, vBb, vSb), jnp.int32)
    _, wave_cache_av = jax.eval_shape(score._prefill_fn(vBb, vSb),
                                      sp_av, {"tokens": vtoks})
    vargs = (sp_av,
             {"k": wave_cache_av["k"], "v": wave_cache_av["v"]},
             jax.ShapeDtypeStruct((vE, vBb, vC), jnp.int32),  # row_pos
             jax.ShapeDtypeStruct((vE, vBb), jnp.int32),      # row_t
             jax.ShapeDtypeStruct((vE, vBb), jnp.int32),      # tok
             jax.ShapeDtypeStruct((vE, vBb), jnp.int32),      # cap
             _avals(score.draft_state))
    vlabel = f"ring_verify[B{vBb},k{vk}]"
    vjit = score._verify_fn(vBb, vk)
    with _w.catch_warnings():
        _w.simplefilter("ignore")
        vhlo = vjit.lower(*vargs).compile().as_text()
    out.extend(check_donation(vjit, vargs, (1,), vlabel, hlo=vhlo))
    out.extend(check_clean_decode(vhlo, vlabel))
    return out
