"""CLI for the contract checkers.

    python -m repro.analysis --all --fail-on-violation
    python -m repro.analysis lint pallas races
    python -m repro.analysis sanitizer
    python -m repro.analysis --emit-baseline races

The ``hlo`` pass needs >= 8 devices, which on a CPU-only runner means
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set *before*
jax initialises. The CLI handles that itself: the parent process runs
``lint``/``pallas``/``races`` in-process (they need no device mesh),
runs the ``sanitizer`` schedule fuzzer in-process too (its stub-model
hubs are CPU-friendly), and re-execs ``hlo`` as a child with the
forced-device environment, collecting the child's findings over a
JSON pipe. The ``obs`` pass (rules O001–O003, the tracing/metrics
contract) is pure AST like ``lint`` and runs in-process. Exit status with ``--fail-on-violation``: 0 when every
error-severity finding is covered by ``baseline.toml``, 1 otherwise
(the report prints a ready to paste baseline stanza per unbaselined
error; ``--emit-baseline`` prints *only* those stanzas, for piping
straight into the file).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
from typing import List

from . import (Violation, apply_baseline, format_report, load_baseline,
               REPO_ROOT)

_PASSES = ("lint", "obs", "hlo", "pallas", "races", "sanitizer")
_CHILD_FLAG = "--emit-json"


def _run_lint() -> List[Violation]:
    from . import lint
    return lint.run()


def _run_obs() -> List[Violation]:
    from . import obs_lint
    return obs_lint.run()


def _run_pallas() -> List[Violation]:
    from . import pallas_check
    return pallas_check.run()


def _run_races() -> List[Violation]:
    from . import races
    return races.run()


def _run_sanitizer() -> List[Violation]:
    from . import sanitizer
    return sanitizer.run()


def _run_hlo_inprocess() -> List[Violation]:
    from . import hlo_contracts
    return hlo_contracts.run()


def _run_hlo_subprocess() -> List[Violation]:
    """Re-exec the hlo pass with the 8-device CPU environment forced
    before jax can initialise in the child."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(REPO_ROOT, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "hlo", _CHILD_FLAG],
        env=env, cwd=REPO_ROOT, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"hlo contract child failed (exit {proc.returncode}):\n"
            f"{proc.stdout}\n{proc.stderr}")
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith("JSON:"):
            payload = line[len("JSON:"):]
    if payload is None:
        raise RuntimeError(
            f"hlo contract child produced no JSON line:\n{proc.stdout}")
    return [Violation(**d) for d in json.loads(payload)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checkers for the serving stack")
    ap.add_argument("passes", nargs="*", choices=(*_PASSES, []),
                    help=f"passes to run (default: all of {_PASSES})")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (same as naming none)")
    ap.add_argument("--fail-on-violation", action="store_true",
                    help="exit 1 if any unbaselined error remains")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore baseline.toml (show every finding)")
    ap.add_argument("--emit-baseline", action="store_true",
                    help="print only ready-to-paste baseline stanzas "
                         "for the unbaselined errors, nothing else")
    ap.add_argument(_CHILD_FLAG, dest="emit_json", action="store_true",
                    help=argparse.SUPPRESS)   # internal child protocol
    args = ap.parse_args(argv)

    passes = list(args.passes) or list(_PASSES)
    if args.all:
        passes = list(_PASSES)

    violations: List[Violation] = []
    for p in passes:
        if p == "lint":
            violations += _run_lint()
        elif p == "obs":
            violations += _run_obs()
        elif p == "pallas":
            violations += _run_pallas()
        elif p == "races":
            violations += _run_races()
        elif p == "sanitizer":
            violations += _run_sanitizer()
        elif p == "hlo":
            if args.emit_json:
                violations += _run_hlo_inprocess()
            else:
                violations += _run_hlo_subprocess()

    if args.emit_json:
        print("JSON:" + json.dumps(
            [dataclasses.asdict(v) for v in violations]))
        return 0

    entries = [] if args.no_baseline else load_baseline()
    active, suppressed = apply_baseline(violations, entries)
    if args.emit_baseline:
        for v in active:
            if v.severity == "error":
                print(v.stanza())
                print()
        return 0
    print(f"repro.analysis: {' '.join(passes)} — "
          f"{len(active)} active finding(s), "
          f"{len(suppressed)} baselined")
    print(format_report(active, suppressed))
    errors = [v for v in active if v.severity == "error"]
    if args.fail_on_violation and errors:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
