"""Static lockset / race analysis over the serving stack's threading
contract (rules R001-R004).

The expert hub made the serving stack genuinely concurrent: a staging
worker thread loads checkpoints while the scheduler thread decodes, and
they share the catalog entry state machines, the wanted/staging books,
the popularity ``Counter`` and the ``HubStats`` counters. This pass
verifies the code against the contract the code itself declares — the
``THREAD_CONTRACT`` literal in ``serve/hub.py`` — instead of trusting
comments:

  * Parse the analysis unit (``DEFAULT_UNIT``: hub, scheduler, kvcache)
    into an AST function table and extract ``THREAD_CONTRACT`` via
    ``ast.literal_eval`` (a missing or non-literal contract is itself
    R001: unchecked concurrency).
  * Build a name-based call graph (method-name call edges plus
    property-access edges) and BFS the per-thread **reach set** from
    each thread's declared entry points.
  * For every function, record attribute accesses with a *receiver
    kind* — ``self``, catalog-entry (receivers derived from
    ``self.catalog[...]``, including loop/comprehension targets over
    the catalog), ``stats`` (receivers ending ``.stats``) — the lexical
    lock state at the access (``with self._lock:`` nesting, or the
    ``*_locked``-suffix convention: such helpers assume the lock and
    the checker verifies every call site), plus calls, lock
    acquisitions and ordered field writes.

Rules:

  R001  unguarded shared state — a lock-guarded field / catalog-entry
        field / stats counter accessed without the designated lock in a
        thread-reachable function; a ``*_locked`` helper called without
        the lock held; a single-writer field reachable from a thread
        that does not own it; a mutable attribute both threads touch
        that the contract does not cover at all; a contract entry point
        that no longer exists (drift).
  R002  lock-order hazards — re-acquiring a held (non-reentrant) lock,
        directly or transitively through calls, or acquiring two locks
        in inconsistent (A,B)/(B,A) order across the unit.
  R003  blocking work under a lock — checkpoint I/O,
        ``block_until_ready``, joins, sleeps held under the designated
        lock stall every thread that needs it. Condition waits on the
        designated lock are exempt (they release it).
  R004  unsafe publication — a state write publishing ``staged`` /
        ``resident`` ordered before its payload fields (params, slot)
        are written, so another thread could observe a
        half-constructed entry.

The dynamic half of the gate — the deterministic schedule fuzzer that
exercises real interleavings of the same contract — is
``repro.analysis.sanitizer`` (S001-S002).
"""
from __future__ import annotations

import ast
import os
from typing import Any, Dict, List, Optional, Set, Tuple

from . import REPO_ROOT, Violation

# the three files whose threads actually interleave: the hub (both
# threads), the scheduler driving it, and the kv bookkeeping the
# scheduler owns single-writer. router.py participates only through
# Router.hits_lock, which bind_popularity points at the hub lock.
DEFAULT_UNIT = (
    "src/repro/serve/hub.py",
    "src/repro/serve/scheduler.py",
    "src/repro/serve/kvcache.py",
)

CONTRACT_NAME = "THREAD_CONTRACT"


class _Access:
    __slots__ = ("attr", "kind", "line", "write", "locked")

    def __init__(self, attr, kind, line, write, locked):
        self.attr, self.kind, self.line = attr, kind, line
        self.write, self.locked = write, locked


class _Call:
    __slots__ = ("name", "line", "locked", "recv_name", "recv_const",
                 "held")

    def __init__(self, name, line, locked, recv_name, recv_const, held):
        self.name, self.line, self.locked = name, line, locked
        self.recv_name, self.recv_const = recv_name, recv_const
        self.held = held


class _Acquire:
    __slots__ = ("lock", "line", "held")

    def __init__(self, lock, line, held):
        self.lock, self.line, self.held = lock, line, held


class _Func:
    def __init__(self, qual: str, short: str, path: str, line: int,
                 assumed_locked: bool):
        self.qual = qual
        self.short = short
        self.path = path
        self.line = line
        self.assumed_locked = assumed_locked
        self.accesses: List[_Access] = []
        self.calls: List[_Call] = []
        self.acquires: List[_Acquire] = []
        # receiver key -> ordered [(attr, value_kind, line)]; value_kind
        # is the constant value for Constant assigns, else "<expr>"
        self.entry_writes: Dict[str, List[Tuple[str, Any, int]]] = {}
        self.refs: Set[str] = set()      # names for call-graph edges
        self.threads: Set[str] = set()   # filled by reachability


def _alias_scan(fn: ast.AST) -> Dict[str, str]:
    """Local receiver typing: names bound from ``self.catalog[...]``
    (or iteration over the catalog) are catalog entries; names bound
    from ``*.stats`` are stats objects."""
    aliases: Dict[str, str] = {}

    def from_value(node) -> Optional[str]:
        if isinstance(node, ast.Subscript) and \
                isinstance(node.value, ast.Attribute) and \
                node.value.attr == "catalog":
            return "entry"
        if isinstance(node, ast.Attribute) and node.attr == "stats":
            return "stats"
        return None

    def entry_iter_target(target, it) -> None:
        # ``for e, c in enumerate(self.catalog)`` / ``for c in
        # self.catalog`` (and the comprehension equivalents)
        wrapped = (isinstance(it, ast.Call)
                   and isinstance(it.func, ast.Name)
                   and it.func.id == "enumerate")
        inner = it.args[0] if wrapped and it.args else it
        if not (isinstance(inner, ast.Attribute)
                and inner.attr == "catalog"):
            return
        if wrapped and isinstance(target, ast.Tuple) and \
                len(target.elts) == 2 and \
                isinstance(target.elts[1], ast.Name):
            aliases[target.elts[1].id] = "entry"
        elif not wrapped and isinstance(target, ast.Name):
            aliases[target.id] = "entry"

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            kind = from_value(node.value)
            if kind:
                aliases[node.targets[0].id] = kind
        elif isinstance(node, ast.For):
            entry_iter_target(node.target, node.iter)
        elif isinstance(node, ast.comprehension):
            entry_iter_target(node.target, node.iter)
    return aliases


class _FuncVisitor(ast.NodeVisitor):
    def __init__(self, info: _Func, aliases: Dict[str, str],
                 lock_aliases: Set[str], canon: str):
        self.info = info
        self.aliases = aliases
        self.lock_aliases = lock_aliases
        self.canon = canon
        self.locks: List[str] = []

    # -- lock state ------------------------------------------------------
    def _is_locked(self) -> bool:
        return self.info.assumed_locked or bool(self.locks)

    def _held(self) -> Tuple[str, ...]:
        held = tuple(self.locks)
        if self.info.assumed_locked:
            held = (self.canon,) + held
        return held

    def _lock_name(self, expr) -> Optional[str]:
        name = None
        if isinstance(expr, ast.Attribute):
            name = expr.attr
        elif isinstance(expr, ast.Name):
            name = expr.id
        if name is None:
            return None
        if name in self.lock_aliases:
            return self.canon
        if "lock" in name.lower():
            return name
        return None

    def visit_With(self, node: ast.With) -> None:
        acquired = []
        for item in node.items:
            lock = self._lock_name(item.context_expr)
            if lock is not None:
                self.info.acquires.append(
                    _Acquire(lock, node.lineno, self._held()))
                acquired.append(lock)
            self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        self.locks.extend(acquired)
        for stmt in node.body:
            self.visit(stmt)
        if acquired:
            del self.locks[-len(acquired):]

    # -- receivers -------------------------------------------------------
    def _recv_kind(self, node) -> str:
        if isinstance(node, ast.Name):
            if node.id == "self":
                return "self"
            return self.aliases.get(node.id, "other")
        if isinstance(node, ast.Attribute):
            return "stats" if node.attr == "stats" else "other"
        if isinstance(node, ast.Subscript):
            if isinstance(node.value, ast.Attribute) and \
                    node.value.attr == "catalog":
                return "entry"
        return "other"

    def visit_Attribute(self, node: ast.Attribute) -> None:
        self.info.refs.add(node.attr)
        self.info.accesses.append(_Access(
            node.attr, self._recv_kind(node.value), node.lineno,
            isinstance(node.ctx, (ast.Store, ast.Del)),
            self._is_locked()))
        self.visit(node.value)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        name, recv_name, recv_const = None, None, False
        if isinstance(fn, ast.Attribute):
            name = fn.attr
            recv_const = isinstance(fn.value, ast.Constant)
            if isinstance(fn.value, ast.Attribute):
                recv_name = fn.value.attr
            elif isinstance(fn.value, ast.Name):
                recv_name = fn.value.id
        elif isinstance(fn, ast.Name):
            name = fn.id
        if name is not None:
            self.info.refs.add(name)
            self.info.calls.append(_Call(
                name, node.lineno, self._is_locked(), recv_name,
                recv_const, self._held()))
        self.generic_visit(node)

    # -- ordered writes (R004) -------------------------------------------
    def _record_write(self, target, value) -> None:
        if not isinstance(target, ast.Attribute):
            return
        if self._recv_kind(target.value) != "entry":
            return
        key = ast.unparse(target.value)
        val: Any = "<expr>"
        if isinstance(value, ast.Constant):
            val = value.value
        self.info.entry_writes.setdefault(key, []).append(
            (target.attr, val, target.lineno))

    def visit_Assign(self, node: ast.Assign) -> None:
        for target in node.targets:
            if isinstance(target, ast.Tuple) and \
                    isinstance(node.value, ast.Tuple) and \
                    len(target.elts) == len(node.value.elts):
                for t, v in zip(target.elts, node.value.elts):
                    self._record_write(t, v)
            else:
                self._record_write(target, node.value)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_write(node.target, node)
        self.generic_visit(node)


def _collect(path: str, tree: ast.Module
             ) -> List[Tuple[str, str, ast.AST]]:
    """(qualname, short name, def node) for every module-level function
    and method. Nested defs/lambdas stay part of their parent — they
    execute in its thread context."""
    out: List[Tuple[str, str, ast.AST]] = []
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.append((node.name, node.name, node))
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                    out.append((f"{node.name}.{sub.name}", sub.name,
                                sub))
    return [(qual, short, node) for qual, short, node in out]


def _build_funcs(sources: Dict[str, str], lock_aliases: Set[str],
                 canon: str) -> Tuple[List[_Func], List[Violation]]:
    funcs: List[_Func] = []
    errors: List[Violation] = []
    for path, src in sources.items():
        try:
            tree = ast.parse(src)
        except SyntaxError as exc:
            errors.append(Violation(
                "R001", path, exc.lineno or 1, "<module>",
                f"unit file failed to parse: {exc.msg}"))
            continue
        for qual, short, node in _collect(path, tree):
            info = _Func(qual, short, path, node.lineno,
                         short.endswith("_locked"))
            vis = _FuncVisitor(info, _alias_scan(node), lock_aliases,
                               canon)
            for stmt in node.body:
                vis.visit(stmt)
            funcs.append(info)
    return funcs, errors


def _find_contract(sources: Dict[str, str]
                   ) -> Tuple[Optional[dict], Optional[str], int]:
    for path, src in sources.items():
        try:
            tree = ast.parse(src)
        except SyntaxError:
            continue
        for node in tree.body:
            if isinstance(node, ast.Assign) and \
                    any(isinstance(t, ast.Name)
                        and t.id == CONTRACT_NAME
                        for t in node.targets):
                try:
                    return (ast.literal_eval(node.value), path,
                            node.lineno)
                except (ValueError, SyntaxError):
                    return (None, path, node.lineno)
    return None, None, 0


def _reach(funcs: List[_Func], contract: dict) -> List[Violation]:
    """Per-thread BFS over name-based call/property edges; marks each
    function with the threads that can reach it."""
    vs: List[Violation] = []
    by_short: Dict[str, List[_Func]] = {}
    by_qual: Dict[str, _Func] = {}
    for f in funcs:
        by_short.setdefault(f.short, []).append(f)
        by_qual[f.qual] = f
    first = funcs[0] if funcs else None
    for thread, entries in contract.get("threads", {}).items():
        work: List[_Func] = []
        for qual in entries:
            f = by_qual.get(qual)
            if f is None:
                vs.append(Violation(
                    "R001",
                    first.path if first else "<unit>", 1, "<contract>",
                    f"THREAD_CONTRACT thread {thread!r} names entry "
                    f"point {qual!r} which no longer exists — contract "
                    "drift"))
                continue
            work.append(f)
        seen: Set[str] = set()
        while work:
            f = work.pop()
            if f.qual in seen:
                continue
            seen.add(f.qual)
            f.threads.add(thread)
            for name in f.refs:
                for g in by_short.get(name, ()):
                    if g.qual not in seen:
                        work.append(g)
    return vs


def analyze_unit(sources: Dict[str, str]) -> List[Violation]:
    """Run R001-R004 over ``{repo-relative path: source}``."""
    vs: List[Violation] = []
    contract, cpath, cline = _find_contract(sources)
    first = next(iter(sources), "<unit>")
    if cpath is None:
        return [Violation(
            "R001", first, 1, "<module>",
            f"no {CONTRACT_NAME} literal found in the unit — the "
            "threading contract must be declared where the threads "
            "live (serve/hub.py)")]
    if contract is None:
        return [Violation(
            "R001", cpath, cline, "<module>",
            f"{CONTRACT_NAME} must be a pure literal "
            "(ast.literal_eval-able) so the checker can read it")]

    canon = contract.get("lock", "_lock")
    lock_aliases = set(contract.get("lock_aliases", [canon])) | {canon}
    guarded = contract.get("lock_guarded", {})
    fields = set(guarded.get("fields", []))
    entry_fields = set(guarded.get("entry_fields", []))
    stats_fields = set(guarded.get("stats_fields", []))
    handoffs = set(contract.get("queue_handoffs", []))
    single = contract.get("single_writer", {})
    owner_of = {fld: t for t, fl in single.items() for fld in fl}
    blocking = set(contract.get("blocking_calls", []))
    publish = contract.get("publish_order", {})

    funcs, errs = _build_funcs(sources, lock_aliases, canon)
    vs.extend(errs)
    vs.extend(_reach(funcs, contract))
    by_short: Dict[str, List[_Func]] = {}
    for f in funcs:
        by_short.setdefault(f.short, []).append(f)

    covered = (fields | entry_fields | stats_fields | handoffs
               | lock_aliases | set(owner_of))
    # attr -> {thread: [reads?, writes?]} for the contract-coverage rule
    shared_seen: Dict[str, Dict[str, List[bool]]] = {}

    for f in funcs:
        reachable = bool(f.threads)
        if reachable and f.short != "__init__":
            for acc in f.accesses:
                if acc.attr in handoffs or acc.attr in lock_aliases:
                    continue
                is_guarded = (
                    (acc.kind in ("self", "other")
                     and acc.attr in fields)
                    or (acc.kind == "entry"
                        and acc.attr in entry_fields)
                    or (acc.kind == "stats"
                        and acc.attr in stats_fields))
                if is_guarded and not acc.locked:
                    # R001: unguarded shared state
                    vs.append(Violation(
                        "R001", f.path, acc.line, f.qual,
                        f"access to lock-guarded {acc.attr!r} without "
                        f"holding {canon!r} (thread(s): "
                        f"{','.join(sorted(f.threads))}) — wrap in "
                        f"`with self.{canon}:` or move into a "
                        "*_locked helper"))
                owner = owner_of.get(acc.attr)
                if owner is not None and \
                        acc.kind in ("self", "other") and \
                        any(t != owner for t in f.threads):
                    others = sorted(t for t in f.threads if t != owner)
                    vs.append(Violation(
                        "R001", f.path, acc.line, f.qual,
                        f"single-writer field {acc.attr!r} (owner "
                        f"thread {owner!r}) is reachable from thread(s)"
                        f" {','.join(others)} — route through a locked "
                        "accessor or a queue handoff"))
                if acc.attr not in covered:
                    rec = shared_seen.setdefault(acc.attr, {})
                    for t in f.threads:
                        slot = rec.setdefault(t, [False, False])
                        slot[0] = slot[0] or not acc.write
                        slot[1] = slot[1] or acc.write
            for call in f.calls:
                if call.name.endswith("_locked") and \
                        call.name in by_short and not call.locked:
                    vs.append(Violation(
                        "R001", f.path, call.line, f.qual,
                        f"{call.name}() assumes {canon!r} is held "
                        "(the *_locked convention) but the call site "
                        "holds no lock"))

        # R003 applies to every function — blocking under a lock is a
        # latency/deadlock bug regardless of which thread runs it
        for call in f.calls:
            if call.name in blocking and call.locked:
                if call.recv_const or call.recv_name in lock_aliases:
                    continue  # str.join / cv.wait release or don't hold
                vs.append(Violation(
                    "R003", f.path, call.line, f.qual,
                    f"blocking call {call.name}() while holding "
                    f"{canon!r} — stage outside the lock and publish "
                    "the result under it"))

    # -- R002: same-lock re-acquire + inconsistent acquisition order ----
    # transitive acquire sets propagate over CALL edges only — an
    # attribute reference like ``target=self._stage_loop`` hands the
    # function to another thread, whose acquisitions don't nest inside
    # the referencing frame's locks
    trans: Dict[str, Set[str]] = {
        f.qual: {a.lock for a in f.acquires} for f in funcs}
    changed = True
    while changed:
        changed = False
        for f in funcs:
            cur = trans[f.qual]
            for call in f.calls:
                for g in by_short.get(call.name, ()):
                    extra = trans[g.qual] - cur
                    if extra:
                        cur |= extra
                        changed = True
    order: Dict[Tuple[str, str], Tuple[str, int, str]] = {}
    for f in funcs:
        for acq in f.acquires:
            for h in acq.held:
                if h == acq.lock:
                    vs.append(Violation(
                        "R002", f.path, acq.line, f.qual,
                        f"re-acquiring {acq.lock!r} while already "
                        "holding it — threading.Lock is not reentrant; "
                        "use a *_locked helper instead"))
                else:
                    order.setdefault((h, acq.lock),
                                     (f.path, acq.line, f.qual))
        for call in f.calls:
            if not call.held:
                continue
            for g in by_short.get(call.name, ()):
                for m in trans[g.qual]:
                    for h in call.held:
                        if h == m:
                            vs.append(Violation(
                                "R002", f.path, call.line, f.qual,
                                f"calls {call.name}() which acquires "
                                f"{m!r} while {m!r} is already held — "
                                "transitive self-deadlock"))
                        else:
                            order.setdefault(
                                (h, m), (f.path, call.line, f.qual))
    for (a, b), (path, line, qual) in order.items():
        if (b, a) in order and a < b:
            opath, oline, oqual = order[(b, a)]
            vs.append(Violation(
                "R002", path, line, qual,
                f"inconsistent lock order: {a!r} then {b!r} here, but "
                f"{b!r} then {a!r} in {oqual} ({opath}:{oline}) — "
                "pick one global order"))

    # -- R004: publication order of partially constructed entries --------
    state_rules = publish.get("state", {})
    for f in funcs:
        for recv, writes in f.entry_writes.items():
            for i, (attr, val, line) in enumerate(writes):
                if attr != "state" or val not in state_rules:
                    continue
                payload = state_rules[val]
                for p in payload:
                    later = [ln for (a2, _, ln) in writes[i + 1:]
                             if a2 == p]
                    if later:
                        vs.append(Violation(
                            "R004", f.path, line, f.qual,
                            f"{recv}.state = {val!r} published before "
                            f"its payload write {recv}.{p} (line "
                            f"{later[0]}) — another thread can observe "
                            "a half-constructed entry; write the "
                            "payload first"))
                    before = [v2 for (a2, v2, _) in writes[:i]
                              if a2 == p]
                    if before and before[-1] is None:
                        vs.append(Violation(
                            "R004", f.path, line, f.qual,
                            f"{recv}.state = {val!r} published after "
                            f"{recv}.{p} was cleared to None — the "
                            f"{val!r} state promises a live {p}"))

    # -- R001 (coverage): shared mutable attrs the contract misses ------
    for attr, rec in sorted(shared_seen.items()):
        if len(rec) < 2 or not any(w for _, w in rec.values()):
            continue
        threads = ",".join(sorted(rec))
        f = next((f for f in funcs
                  for a in f.accesses if a.attr == attr), None)
        line = next((a.line for a in f.accesses if a.attr == attr), 1) \
            if f else 1
        vs.append(Violation(
            "R001", f.path if f else "<unit>", line,
            f.qual if f else "<unit>",
            f"attribute {attr!r} is accessed by threads {threads} "
            "(with at least one write) but appears in no "
            "THREAD_CONTRACT category — declare it lock_guarded, "
            "single_writer, or a queue handoff"))

    vs.sort(key=lambda v: (v.path, v.line, v.rule))
    return vs


def run(root: str = REPO_ROOT,
        unit: Tuple[str, ...] = DEFAULT_UNIT) -> List[Violation]:
    sources: Dict[str, str] = {}
    for rel in unit:
        full = os.path.join(root, rel)
        with open(full, "r", encoding="utf-8") as fh:
            sources[rel] = fh.read()
    return analyze_unit(sources)
