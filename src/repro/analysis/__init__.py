"""Contract checkers for the serving stack.

Five cooperating passes, each runnable standalone
(``python -m repro.analysis <pass>``) and as tier-1 pytest tests:

  * ``lint``  — AST-based repo-specific linter (no jax import): host
    syncs inside jit-traced code, tracer branches, private
    ``_cache_size`` use, unsynced device timing, unpaired resource
    lifecycles. Rules L001..L005.
  * ``hlo``   — lowers the serving dispatches (prefill/decode ladder,
    banked vmapped step, hub slot install) on a forced 8-device CPU
    mesh and asserts contracts on the compiled HLO: donation took,
    no host callbacks or dynamic reshapes in the decode tick, bank
    shardings match the placement spec, executable count equals the
    declared bucket bound. Rules H001..H004.
  * ``pallas`` — validates every kernel's BlockSpec geometry (block
    divisibility, index-map bounds over the grid, TPU memory-space
    and VMEM-budget legality) without a TPU. Rules P001..P004.
  * ``races`` — static lockset/race analysis of the expert-lifecycle
    threading contract (``THREAD_CONTRACT`` in ``serve/hub.py``):
    per-thread reachability over the call graph, cross-thread shared
    state guarded by the designated lock / queue handoffs /
    single-writer annotations, consistent lock order, no blocking
    work under the lock, safe publication order. Rules R001..R004.
  * ``sanitizer`` — *dynamic* schedule fuzzer for the same contract:
    runs the hub's two threads under a seeded deterministic
    cooperative scheduler, replays interleavings byte-identically,
    and asserts the conservation invariants after each one (plus a
    planted lost-update that must keep reproducing). Rules
    S001..S002.

Intentional exceptions live in ``analysis/baseline.toml`` — one
``[[baseline]]`` stanza per suppressed finding, each with a written
justification. An unbaselined error fails ``--fail-on-violation``
(and the CI ``analysis`` job); the failure message prints the exact
stanza to paste if the finding is intentional.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baseline.toml")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding. ``func`` (enclosing def/kernel qualname) rather
    than the line number is the baseline key, so baselines survive
    unrelated edits to the file."""
    rule: str                    # "L001" .. "P004"
    path: str                    # repo-relative file
    line: int
    func: str                    # enclosing qualname or "<module>"
    msg: str
    severity: str = "error"      # "error" | "warning"

    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.func)

    def format(self) -> str:
        sev = "" if self.severity == "error" else " (warning)"
        return (f"{self.rule}{sev} {self.path}:{self.line} "
                f"[{self.func}] {self.msg}")

    def stanza(self, reason: str = "<why this is intentional>") -> str:
        return ("[[baseline]]\n"
                f'rule = "{self.rule}"\n'
                f'file = "{self.path}"\n'
                f'func = "{self.func}"\n'
                f'reason = "{reason}"')


# ---------------------------------------------------------------------------
# baseline.toml — parsed with a tiny TOML-subset reader (the pinned
# runtime is Python 3.10: no tomllib, and adding a dependency for four
# string keys is not worth it). Supported grammar: comments, blank
# lines, ``[[baseline]]`` array-of-tables headers, and
# ``key = "string"`` pairs.
# ---------------------------------------------------------------------------

_KV = re.compile(r'^([A-Za-z_][\w-]*)\s*=\s*"((?:[^"\\]|\\.)*)"\s*(?:#.*)?$')


def load_baseline(path: Optional[str] = None) -> List[Dict[str, str]]:
    path = path or BASELINE_PATH
    if not os.path.exists(path):
        return []
    entries: List[Dict[str, str]] = []
    cur: Optional[Dict[str, str]] = None
    with open(path, encoding="utf-8") as fh:
        for n, raw in enumerate(fh, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            if line == "[[baseline]]":
                cur = {}
                entries.append(cur)
                continue
            m = _KV.match(line)
            if m and cur is not None:
                cur[m.group(1)] = m.group(2).replace('\\"', '"')
                continue
            raise ValueError(
                f"{path}:{n}: unsupported baseline syntax {line!r} "
                "(expected [[baseline]] or key = \"value\")")
    for e in entries:
        missing = {"rule", "file", "func", "reason"} - set(e)
        if missing:
            raise ValueError(
                f"{path}: baseline entry {e} missing {sorted(missing)} "
                "(every suppression needs a written justification)")
    return entries


def apply_baseline(violations: Sequence[Violation],
                   entries: Iterable[Dict[str, str]]
                   ) -> Tuple[List[Violation], List[Violation]]:
    """Split findings into (active, suppressed)."""
    keys = {(e["rule"], e["file"], e["func"]) for e in entries}
    active = [v for v in violations if v.key() not in keys]
    suppressed = [v for v in violations if v.key() in keys]
    return active, suppressed


def format_report(violations: Sequence[Violation],
                  suppressed: Sequence[Violation] = (),
                  *, show_stanzas: bool = True) -> str:
    lines: List[str] = []
    errors = [v for v in violations if v.severity == "error"]
    warns = [v for v in violations if v.severity != "error"]
    for v in errors + warns:
        lines.append(v.format())
    if suppressed:
        lines.append(f"({len(suppressed)} finding(s) suppressed by "
                     "baseline.toml)")
    if errors and show_stanzas:
        lines.append("")
        lines.append("To suppress an intentional finding, add to "
                     "src/repro/analysis/baseline.toml:")
        for v in errors:
            lines.append("")
            lines.append(v.stanza())
    if not violations:
        lines.append("clean")
    return "\n".join(lines)
