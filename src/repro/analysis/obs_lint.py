"""Observability contract lint (rules O001–O003).

PR 6 fixed a family of L004 timing bugs — hand-rolled ``perf_counter``
regions that measured *enqueue* instead of completion. The tracing
subsystem (``repro.obs``) could silently reintroduce every one of them,
plus a new failure class: tracer calls captured inside jit-traced
code (a host side effect that fires once at trace time, then never
again — silently wrong data AND a retrace hazard). These rules keep
the observability layer honest, statically:

O001  a tracer call (``span``/``event``/``begin_device``/...) inside a
      jit-traced function. Host-side tracing must stay host-side: a
      call baked into a trace records trace-time, not run-time.

O002  sync-safe device spans, two clauses. (a) a ``with tracer.span()``
      body that dispatches device work without a blessed sync
      (``block_until_ready``/``device_get``/``np.asarray``) times the
      enqueue, not the work — use ``begin_device``/``end_device`` at a
      sync site, or ``enqueue_span`` when enqueue latency is the
      *intended* measurement (the hub's slot install). (b) an
      ``end_device`` call in a function with no sync call: the handle
      would close before the device work finished.

O003  ``Histogram(...)`` bucket bounds must be literals (an inline
      tuple/list of numbers, or an ALL_CAPS constant) — computed
      buckets can silently degenerate (empty, unsorted, wrong unit)
      and make every recorded percentile a lie.

Pure AST — no jax import, safe to run anywhere. Shares the device /
sync vocabularies with ``lint`` so the two gates can't drift.
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Sequence, Set

from . import REPO_ROOT, Violation
from .lint import (_DEVICE_HINTS, _NON_DISPATCH, _NP_ROOTS, _SYNC_CALLS,
                   _Parents, _call_name, _dotted, _last_attr,
                   _walk_skip_fns, default_paths, find_traced_functions)

#: The Tracer API surface — any of these on a tracer-named receiver is
#: "a tracing call" for O001.
_TRACER_METHODS = {"span", "enqueue_span", "event", "begin_device",
                   "end_device", "next_id", "bind_uid", "trace_of",
                   "release_uid", "now"}


def _is_tracer_call(node: ast.AST, methods: Set[str]) -> bool:
    """``<something named *tracer*>.<method>(...)``."""
    if not (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in methods):
        return False
    recv = _dotted(node.func.value)
    return recv is not None and "tracer" in recv.lower()


def _classify(nodes: Sequence[ast.AST]) -> "tuple[Optional[ast.Call], bool]":
    """(first device-dispatch call, any sync call present) — the same
    vocabulary L004 uses, so the two rules agree on what 'dispatch'
    and 'sync' mean."""
    device: Optional[ast.Call] = None
    synced = False
    for node in nodes:
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node) or ""
        last = _last_attr(name)
        root = name.split(".")[0] if name else ""
        if last in _SYNC_CALLS or (root in _NP_ROOTS
                                   and last in ("asarray", "array")):
            synced = True
        elif (root in ("jnp", "jax") and last not in _NON_DISPATCH) \
                or last.lstrip("_") in _DEVICE_HINTS:
            device = device or node
    return device, synced


# ---------------------------------------------------------------------------
# O001 — no tracing inside traced code
# ---------------------------------------------------------------------------


def _check_traced_tracing(tree: ast.AST, parents: _Parents,
                          path: str) -> List[Violation]:
    out: List[Violation] = []
    for fn in find_traced_functions(tree):
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for node in _walk_skip_fns(body):
            if _is_tracer_call(node, _TRACER_METHODS):
                out.append(Violation(
                    "O001", path, node.lineno, parents.qualname(node),
                    f"tracer call {_dotted(node.func)}() inside a "
                    "jit-traced function — host-side tracing baked "
                    "into a trace fires at trace time only (silently "
                    "wrong spans) and is a retrace hazard"))
    return out


# ---------------------------------------------------------------------------
# O002 — device spans end at sync sites
# ---------------------------------------------------------------------------


def _check_span_sync(tree: ast.AST, parents: _Parents,
                     path: str) -> List[Violation]:
    out: List[Violation] = []
    # (a) `with tracer.span(...)` wrapping unsynced device dispatch.
    # `enqueue_span` is exempt by name: it declares enqueue semantics.
    for node in ast.walk(tree):
        if not isinstance(node, ast.With):
            continue
        for item in node.items:
            ce = item.context_expr
            if not (_is_tracer_call(ce, {"span"})):
                continue
            device, synced = _classify(_walk_skip_fns(node.body))
            if device is not None and not synced:
                out.append(Violation(
                    "O002", path, device.lineno,
                    parents.qualname(device),
                    f"span wraps device dispatch "
                    f"({_call_name(device)}) with no block_until_ready/"
                    "device_get — the span measures enqueue, not "
                    "completion; use begin_device/end_device closed at "
                    "a sync site, or enqueue_span if enqueue latency "
                    "is the intended measurement"))
    # (b) end_device outside a sync-bearing function.
    for node in ast.walk(tree):
        if not _is_tracer_call(node, {"end_device"}):
            continue
        fn = parents.enclosing_function(node)
        body = fn.body if fn is not None else []
        body = body if isinstance(body, list) else [body]
        _dev, synced = _classify(_walk_skip_fns(body))
        if not synced:
            out.append(Violation(
                "O002", path, node.lineno, parents.qualname(node),
                "end_device() in a function with no "
                "block_until_ready/device_get — the device span would "
                "close before the work completed; close handles only "
                "at the blessed sync sites (the engine's "
                "_materialize/_materialize_spec)"))
    return out


# ---------------------------------------------------------------------------
# O003 — histogram buckets are literals
# ---------------------------------------------------------------------------


def _module_literals(tree: ast.AST) -> Set[str]:
    """Module-level names bound to literal tuples/lists of numbers."""
    names: Set[str] = set()
    for stmt in getattr(tree, "body", []):
        if isinstance(stmt, ast.Assign) and _is_literal_seq(stmt.value):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names


def _is_literal_seq(node: ast.AST) -> bool:
    return isinstance(node, (ast.Tuple, ast.List)) and node.elts and all(
        isinstance(e, ast.Constant)
        and isinstance(e.value, (int, float)) for e in node.elts)


def _check_bucket_literals(tree: ast.AST, parents: _Parents,
                           path: str) -> List[Violation]:
    out: List[Violation] = []
    literal_names = _module_literals(tree)
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _last_attr(_call_name(node)) == "Histogram"):
            continue
        arg: Optional[ast.AST] = node.args[0] if node.args else None
        for kw in node.keywords:
            if kw.arg == "buckets":
                arg = kw.value
        if arg is None:          # library default — itself a literal
            continue
        if _is_literal_seq(arg):
            continue
        name = _dotted(arg)
        if name is not None:
            last = _last_attr(name)
            if last.isupper() or last in literal_names:
                continue         # ALL_CAPS constant / module literal
        out.append(Violation(
            "O003", path, node.lineno, parents.qualname(node),
            f"Histogram buckets "
            f"{ast.unparse(arg) if hasattr(ast, 'unparse') else '?'} "
            "are computed, not literal — declare bounds inline or as "
            "an ALL_CAPS constant so resolution is reviewable and "
            "can't silently degenerate"))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def lint_source(src: str, path: str) -> List[Violation]:
    """Check one file's source. ``path`` is the repo-relative name used
    in reports and baseline keys."""
    tree = ast.parse(src, filename=path)
    parents = _Parents(tree)
    out: List[Violation] = []
    out.extend(_check_traced_tracing(tree, parents, path))
    out.extend(_check_span_sync(tree, parents, path))
    out.extend(_check_bucket_literals(tree, parents, path))
    return out


def run(paths: Optional[Sequence[str]] = None,
        root: str = REPO_ROOT) -> List[Violation]:
    out: List[Violation] = []
    for p in (paths or default_paths(root)):
        rel = os.path.relpath(p, root) if os.path.isabs(p) else p
        with open(os.path.join(root, rel), encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), rel.replace(os.sep, "/")))
    return out
