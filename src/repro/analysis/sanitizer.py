"""Deterministic schedule-fuzzing sanitizer for the expert-hub
lifecycle (rules S001-S002) — the dynamic half of the concurrency gate.

``races`` proves lock discipline statically; this pass *runs* the two
threads (scheduler driver + hub staging worker) under a cooperative,
seeded scheduler and checks the conservation invariants after real
interleavings:

  * **Shimmed primitives.** ``instrument(hub, itl)`` swaps the hub's
    ``_lock`` / ``_cv`` / ``_stage_q`` and its ``_thread_factory`` seam
    for shims (``ShimLock``, ``ShimCondition``, ``ShimQueue``,
    ``_ManagedThread``) that route every block/wake decision through
    one ``Interleaver``.
  * **Single-run-token scheduling.** Exactly one managed thread runs at
    a time; at every yield point (a ``sys.settrace`` line hook scoped
    to ``serve/hub.py``, plus every shim operation) the interleaver's
    seeded RNG picks the next runnable thread from a sorted candidate
    list. Given a seed, the interleaving — and the recorded trace — is
    byte-identical on replay. Timeouts inside the shims are ignored
    (they would be wall-clock nondeterminism); real deadlocks are
    caught structurally (no runnable thread) and by a watchdog.
  * **Invariants per interleaving** (``fuzz_hub``): ``hub.check()``
    (state-machine legality + ``loads == commits`` +
    stage-attempt conservation), ``PagePool.check()``, pin counts back
    to baseline after drain, clean worker shutdown via ``close()``.
  * **Teeth.** A planted lost-update — the exact two-line
    read-modify-write the pre-gate popularity counter performed — must
    *lose* updates under ``LOST_UPDATE_SEED`` when unlocked and
    conserve when locked. A sanitizer whose planted bug stops
    reproducing has lost its teeth and fails the gate (S002).

Rules:

  S001  conservation violated under an interleaving — an invariant
        (pins, page books, state machine, stats conservation) broke, or
        an unexpected error surfaced from the fuzzed lifecycle.
  S002  determinism/teeth failure — the same seed replayed to a
        different trace, or a planted negative stopped reproducing.

``run()`` is wired into ``python -m repro.analysis --all``; the CI
sanitizer suite additionally arms ``faulthandler`` with a hard timeout
so a real deadlock dumps stacks and fails fast instead of hanging the
runner.
"""
from __future__ import annotations

import collections
import dataclasses
import faulthandler
import itertools
import random
import shutil
import sys
import tempfile
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from . import REPO_ROOT, Violation

HUB_PATH = "src/repro/serve/hub.py"

# seed under which the planted unlocked read-modify-write demonstrably
# loses increments (the negative test: documented, replayable), and a
# fuzz seed whose workload wants the never-saved expert so the
# staging-failure path is exercised end to end
LOST_UPDATE_SEED = 1
FAIL_SEED = 0
DEFAULT_SEEDS = (0, 1, 2)
SANITIZER_TIMEOUT = 300.0   # faulthandler hard stop for the whole pass


class _AbortError(BaseException):
    """Unwinds managed threads on deadlock/watchdog/shutdown. Derives
    from BaseException so the hub's ``except Exception`` staging guard
    cannot swallow a schedule abort."""


class _TState:
    __slots__ = ("name", "done", "blocked", "in_shim", "notified")

    def __init__(self, name: str):
        self.name = name
        self.done = False
        # predicate gating runnability (None = runnable); evaluated by
        # the scheduler under the monitor
        self.blocked: Optional[Callable[[], bool]] = None
        # True while executing shim internals (incl. cv predicates):
        # yield_point must not recurse into the scheduler from there
        self.in_shim = False
        self.notified = False


class Interleaver:
    """Cooperative deterministic scheduler over real threads.

    One token: only ``_current`` runs; everyone else waits on the
    monitor. Every decision — who runs after a yield, a block, a thread
    exit — is made by ``rng`` over a *sorted* candidate list, so a seed
    fully determines the interleaving. ``trace`` records every yield
    and shim event in global order; byte-equal traces == identical
    interleavings.
    """

    def __init__(self, seed: int, watchdog: float = 30.0):
        self.seed = seed
        self.rng = random.Random(seed)
        self.watchdog = watchdog
        self._mon = threading.Condition()
        self._states: Dict[str, _TState] = {}
        self._by_ident: Dict[int, _TState] = {}
        self._current: Optional[str] = None
        self._managed: List["_ManagedThread"] = []
        self.trace: List[str] = []
        self.aborted: Optional[str] = None
        self._trace_suffix = ("serve/hub.py",)

    # -- registration ----------------------------------------------------
    def _register(self, name: str) -> _TState:
        if name in self._states:
            raise ValueError(f"duplicate managed thread {name!r}")
        st = _TState(name)
        self._states[name] = st
        return st

    def _adopt(self, name: str) -> _TState:
        st = self._states[name]
        self._by_ident[threading.get_ident()] = st
        return st

    def _me(self) -> Optional[_TState]:
        return self._by_ident.get(threading.get_ident())

    # -- scheduling core (all under self._mon) ---------------------------
    def _runnable_locked(self) -> List[str]:
        out = []
        for name in sorted(self._states):
            st = self._states[name]
            if st.done:
                continue
            if st.blocked is not None and not st.blocked():
                continue
            out.append(name)
        return out

    def _abort_locked(self, reason: str, raise_: bool = True) -> None:
        if self.aborted is None:
            self.aborted = reason
        self._mon.notify_all()
        if raise_:
            raise _AbortError(reason)

    def _pick_locked(self) -> None:
        cand = self._runnable_locked()
        if not cand:
            live = sorted(n for n, s in self._states.items()
                          if not s.done)
            self._abort_locked(
                "deadlock: every live thread is blocked "
                f"({','.join(live)})")
        self._current = cand[self.rng.randrange(len(cand))]
        self._mon.notify_all()

    def _wait_turn_locked(self, st: _TState) -> None:
        deadline = time.monotonic() + self.watchdog
        while self.aborted is None and self._current != st.name:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                self._abort_locked(
                    f"watchdog: {st.name} starved for "
                    f"{self.watchdog}s (wedged thread?)")
            self._mon.wait(remaining)
        if self.aborted is not None:
            raise _AbortError(self.aborted)
        st.blocked = None

    def _block_locked(self, st: _TState, tag: str,
                      pred: Callable[[], bool]) -> None:
        """Current thread blocks on ``pred``; scheduler picks someone
        else (or us again, once the predicate turns true)."""
        self.trace.append(f"{st.name}|{tag}")
        st.blocked = pred
        self._pick_locked()
        self._wait_turn_locked(st)

    # -- public yield points ---------------------------------------------
    def yield_point(self, tag: str) -> None:
        """A possible context switch. No-op for unmanaged threads and
        inside shim internals."""
        st = self._me()
        if st is None or st.in_shim:
            return
        with self._mon:
            if self.aborted is not None:
                raise _AbortError(self.aborted)
            self.trace.append(f"{st.name}|{tag}")
            self._pick_locked()
            self._wait_turn_locked(st)

    def note(self, tag: str) -> None:
        """Append a marker to the trace without switching."""
        with self._mon:
            self.trace.append(f"#|{tag}")

    def _finish(self, name: str) -> None:
        with self._mon:
            self._states[name].done = True
            if self.aborted is None:
                cand = self._runnable_locked()
                if cand:
                    self._current = cand[self.rng.randrange(len(cand))]
            self._mon.notify_all()

    # -- tracing ---------------------------------------------------------
    def _tracer(self, frame, event, arg):
        if event == "call" and \
                frame.f_code.co_filename.endswith(self._trace_suffix):
            return self._line_tracer
        return None

    def _line_tracer(self, frame, event, arg):
        if event == "line":
            self.yield_point(
                f"{frame.f_code.co_name}:{frame.f_lineno}")
        return self._line_tracer

    # -- driving ---------------------------------------------------------
    def run(self, fn: Callable[[], Any]) -> Any:
        """Run ``fn`` as the managed ``main`` thread with line tracing
        installed; managed threads it spawns interleave with it."""
        self._register("main")
        self._adopt("main")
        self._current = "main"
        old = sys.gettrace()
        sys.settrace(self._tracer)
        try:
            return fn()
        finally:
            sys.settrace(old)
            self._finish("main")

    def shutdown(self, timeout: float = 5.0) -> None:
        """Abort any still-live managed threads and join their real
        threads — test hygiene so no fuzz thread outlives its run."""
        with self._mon:
            live = [n for n, s in self._states.items() if not s.done]
            if live and self.aborted is None:
                self.aborted = "shutdown"
            self._mon.notify_all()
        for mt in self._managed:
            mt._real.join(timeout)


# -- shimmed primitives ------------------------------------------------


class ShimLock:
    """``threading.Lock`` lookalike whose blocking routes through the
    interleaver (deterministic, deadlock-detected)."""

    def __init__(self, itl: Interleaver):
        self.itl = itl
        self.owner: Optional[str] = None

    def acquire(self, blocking: bool = True, timeout: float = -1):
        itl = self.itl
        st = itl._me()
        if st is None:
            raise RuntimeError("unmanaged thread on a ShimLock")
        with itl._mon:
            st.in_shim = True
            try:
                while self.owner is not None:
                    itl._block_locked(st, "lock.block",
                                      lambda: self.owner is None)
                self.owner = st.name
                itl.trace.append(f"{st.name}|lock.acquire")
            finally:
                st.in_shim = False
        return True

    def release(self) -> None:
        itl = self.itl
        st = itl._me()
        with itl._mon:
            if st is None or self.owner != st.name:
                raise RuntimeError(
                    f"ShimLock released by non-owner "
                    f"({st.name if st else '?'} vs {self.owner})")
            self.owner = None
            itl.trace.append(f"{st.name}|lock.release")

    def locked(self) -> bool:
        return self.owner is not None

    def __enter__(self) -> "ShimLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class ShimCondition:
    """``threading.Condition`` lookalike over a ``ShimLock``. Timeouts
    are deliberately ignored — a wait that would time out in real time
    shows up here as a structural deadlock instead (deterministic)."""

    def __init__(self, lock: ShimLock, itl: Interleaver):
        self.lock = lock
        self.itl = itl
        self._waiters: List[_TState] = []

    def wait_for(self, predicate: Callable[[], bool],
                 timeout: Optional[float] = None) -> bool:
        itl = self.itl
        st = itl._me()
        if st is None:
            raise RuntimeError("unmanaged thread on a ShimCondition")
        with itl._mon:
            if self.lock.owner != st.name:
                raise RuntimeError("wait_for without holding the lock")
            st.in_shim = True
            try:
                while True:
                    if predicate():
                        return True
                    st.notified = False
                    self._waiters.append(st)
                    self.lock.owner = None          # release
                    itl._block_locked(st, "cv.wait",
                                      lambda: st.notified)
                    while self.lock.owner is not None:  # reacquire
                        itl._block_locked(
                            st, "cv.reacquire",
                            lambda: self.lock.owner is None)
                    self.lock.owner = st.name
            finally:
                st.in_shim = False

    def notify_all(self) -> None:
        itl = self.itl
        st = itl._me()
        with itl._mon:
            for w in self._waiters:
                w.notified = True
            self._waiters.clear()
            if st is not None:
                itl.trace.append(f"{st.name}|cv.notify_all")

    notify = notify_all


class ShimQueue:
    """``queue.Queue`` lookalike (put/get) with interleaver blocking."""

    def __init__(self, itl: Interleaver):
        self.itl = itl
        self._items: "collections.deque" = collections.deque()

    def put(self, item: Any) -> None:
        itl = self.itl
        st = itl._me()
        with itl._mon:
            self._items.append(item)
            if st is not None:
                itl.trace.append(f"{st.name}|q.put")

    def get(self) -> Any:
        itl = self.itl
        st = itl._me()
        if st is None:
            raise RuntimeError("unmanaged thread on a ShimQueue")
        with itl._mon:
            st.in_shim = True
            try:
                while not self._items:
                    itl._block_locked(st, "q.get",
                                      lambda: bool(self._items))
                return self._items.popleft()
            finally:
                st.in_shim = False


class _ManagedThread:
    """``threading.Thread`` lookalike under interleaver control:
    cooperative start/join/is_alive, line tracer installed in the new
    thread, aborts unwound quietly."""

    _counter = itertools.count()

    def __init__(self, itl: Interleaver, target: Callable = None,
                 name: Optional[str] = None, daemon: Optional[bool]
                 = None, args: Tuple = (), kwargs: Optional[dict]
                 = None):
        self.itl = itl
        self._target = target
        self._args = args
        self._kwargs = kwargs or {}
        self.name = name or f"managed-{next(self._counter)}"
        self.daemon = True
        self._st: Optional[_TState] = None
        self._real = threading.Thread(target=self._run, name=self.name,
                                      daemon=True)

    def start(self) -> None:
        itl = self.itl
        with itl._mon:
            self._st = itl._register(self.name)
            itl._managed.append(self)
        self._real.start()

    def _run(self) -> None:
        itl = self.itl
        st = itl._adopt(self.name)
        sys.settrace(itl._tracer)
        try:
            with itl._mon:
                itl._wait_turn_locked(st)
            if self._target is not None:
                self._target(*self._args, **self._kwargs)
        except _AbortError:
            pass
        finally:
            sys.settrace(None)
            itl._finish(self.name)

    def is_alive(self) -> bool:
        return self._st is not None and not self._st.done

    def join(self, timeout: Optional[float] = None) -> None:
        itl = self.itl
        me = itl._me()
        if me is None:                 # unmanaged caller: real join
            self._real.join(timeout)
            return
        with itl._mon:
            if self._st is None or self._st.done:
                return
            me.in_shim = True
            try:
                itl._block_locked(me, f"join:{self.name}",
                                  lambda: self._st.done)
            finally:
                me.in_shim = False


def instrument(hub, itl: Interleaver) -> None:
    """Swap the hub's concurrency primitives for interleaver shims.
    Must run before the staging worker first spawns (it is lazy, so any
    time before the first prefetching ``service`` call works)."""
    if hub._stage_thread is not None:
        raise RuntimeError("instrument() after the staging worker "
                           "spawned — too late to shim")
    hub._lock = ShimLock(itl)
    hub._cv = ShimCondition(hub._lock, itl)
    hub._stage_q = ShimQueue(itl)
    hub._thread_factory = (
        lambda target=None, name=None, daemon=None: _ManagedThread(
            itl, target=target, name=name or "hub-stage",
            daemon=daemon))


# -- stub model: a hub that builds in milliseconds ---------------------


@dataclasses.dataclass(frozen=True)
class _StubCfg:
    name: str = "stub"
    family: str = "stub"
    n_experts: int = 0
    moe_impl: str = "none"

    def replace(self, **kw) -> "_StubCfg":
        return dataclasses.replace(self, **kw)


class _StubModel:
    """The minimal model surface ``ExpertHub``/``EngineCore`` need at
    construction: tiny params, paged-KV capable (so the fuzz hub runs
    the paged layout and ``PagePool.check`` is a real invariant). The
    fuzz workload never prefills/decodes — it drives the residency
    lifecycle, which is where the threads interleave."""

    supports_paged_kv = True

    def __init__(self):
        import jax
        import jax.numpy as jnp
        self.cfg = _StubCfg()
        self._jnp = jnp
        self._sds = jax.ShapeDtypeStruct

    def param_shapes(self):
        return {"w": self._sds((4,), self._jnp.float32)}

    def init_paged_pool(self, n_pages: int, page: int):
        # +1: physical page n_pages is the trash page
        return {"k": self._jnp.zeros((n_pages + 1, page, 2),
                                     self._jnp.float32)}


def _stub_params() -> Dict[str, np.ndarray]:
    return {"w": np.zeros((4,), np.float32)}


# -- the fuzzer --------------------------------------------------------


@dataclasses.dataclass
class FuzzResult:
    seed: int
    trace: List[str]
    failures: List[str]          # invariant violations (S001 material)
    errors: List[str]            # exceptions service() surfaced
    stats: Dict[str, float]


def fuzz_hub(seed: int, *, n_experts: int = 4, n_slots: int = 2,
             steps: int = 30, fail_expert: bool = False,
             store: Optional[str] = None,
             watchdog: float = 30.0) -> FuzzResult:
    """One seeded interleaving of the full hub lifecycle.

    Builds a stub-model hub (paged layout, prefetching staging worker)
    over a cold checkpoint store, instruments it, then drives a seeded
    workload of acquire/pin/unpin/note_hit/want/service/check from the
    managed driver thread while the staging worker interleaves. With
    ``fail_expert`` the last catalog expert is never saved to the
    store, so wanting it exercises the staging-failure path (the
    worker's cold reset + the scheduler-side re-raise) mid-fuzz.
    After the workload: drain, assert conservation, close the hub.
    """
    from ..checkpoint import io as ckpt_io
    from ..serve.hub import ExpertHub, NotResident

    own_store = store is None
    if own_store:
        store = tempfile.mkdtemp(prefix="sanitizer-hub-")
    itl = Interleaver(seed, watchdog=watchdog)
    failures: List[str] = []
    errors: List[BaseException] = []
    try:
        names = [f"e{i}" for i in range(n_experts)]
        for i, name in enumerate(names):
            if fail_expert and i == n_experts - 1:
                continue       # catalogued below but never saved:
                #                staging it fails with FileNotFoundError
            ckpt_io.save_expert(store, name, _stub_params())
        hub = ExpertHub(_StubModel(), n_slots=n_slots, max_len=16,
                        min_len_bucket=8, kv_layout="paged",
                        page_size=8, pool_pages=8, store=store,
                        prefetch=True, host_cache=1)
        if fail_expert:
            # on_disk is taken on faith for store-backed entries; the
            # missing checkpoint surfaces at stage time, as in
            # production (a corrupt or half-written cold tier)
            pass
        for name in names:
            hub.add_expert(name)
        instrument(hub, itl)

        def service(block: bool) -> None:
            try:
                hub.service(block=block)
            except AssertionError:
                raise                       # invariant: real failure
            except _AbortError:
                raise
            except Exception as exc:        # staging failures re-raised
                errors.append(exc)

        def driver() -> None:
            wl = random.Random(seed ^ 0x5EED5EED)
            pinned: List[int] = []
            try:
                try:
                    for _ in range(steps):
                        op = wl.randrange(8)
                        e = wl.randrange(n_experts)
                        itl.note(f"op{op}:e{e}")
                        if op <= 1:
                            try:
                                hub.acquire(e)
                                hub.pin(e)
                                pinned.append(e)
                            except NotResident:
                                pass
                        elif op == 2 and pinned:
                            hub.unpin(pinned.pop())
                        elif op == 3:
                            hub.note_hit(e, 1 + wl.randrange(3))
                        elif op == 4:
                            hub.want(e)
                        elif op <= 6:
                            service(block=wl.random() < 0.3)
                        else:
                            hub.check()
                    while pinned:
                        hub.unpin(pinned.pop())
                    for _ in range(8 * n_experts):
                        if not hub.has_wanted:
                            break
                        service(block=True)
                    if hub.has_wanted and not errors:
                        failures.append("drain did not converge: "
                                        "experts still wanted")
                    hub.check()
                    pins = hub.total_pins()
                    if pins != 0:
                        failures.append(
                            f"pins not back to baseline: {pins}")
                    st = hub.stats
                    if st.stage_attempts != (st.stage_count
                                             + st.stage_failures):
                        failures.append(
                            "stage conservation after drain: "
                            f"{st.stage_attempts} attempts != "
                            f"{st.stage_count} + {st.stage_failures}")
                    hub.bank.core.pool.check()
                finally:
                    hub.close()
            except AssertionError as exc:
                failures.append(f"invariant: {exc}")
            except _AbortError as exc:
                failures.append(f"schedule abort: {exc}")

        itl.run(driver)
        if itl.aborted is not None:
            msg = f"schedule abort: {itl.aborted}"
            if msg not in failures:
                failures.append(msg)
        return FuzzResult(seed=seed, trace=list(itl.trace),
                          failures=failures,
                          errors=[type(e).__name__ for e in errors],
                          stats=hub.stats.as_dict())
    finally:
        itl.shutdown()
        if own_store:
            shutil.rmtree(store, ignore_errors=True)


# -- the planted negative ----------------------------------------------


def demo_lost_update(seed: int, *, locked: bool,
                     rounds: int = 10) -> Tuple[int, int, List[str]]:
    """The planted lost-update: two managed threads each bump a shared
    counter ``rounds`` times through the exact two-step
    read-modify-write the pre-gate popularity counter performed
    (``pop[e] += 1`` with the eviction ranking reading concurrently),
    with an explicit yield in the window. Returns (got, want, trace):
    unlocked runs *lose* increments under ``LOST_UPDATE_SEED``; the
    ``locked`` variant conserves under every seed."""
    itl = Interleaver(seed)
    counter: collections.Counter = collections.Counter()
    lock = ShimLock(itl)

    def bump() -> None:
        v = counter[0]
        itl.yield_point("lost-update-window")
        counter[0] = v + 1

    def loop() -> None:
        for _ in range(rounds):
            if locked:
                with lock:
                    bump()
            else:
                bump()

    peer = _ManagedThread(itl, target=loop, name="peer")

    def driver() -> None:
        peer.start()
        loop()
        peer.join()

    try:
        itl.run(driver)
    finally:
        itl.shutdown()
    return counter[0], 2 * rounds, list(itl.trace)


# -- the pass ----------------------------------------------------------


def _diverge(a: List[str], b: List[str]) -> str:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return f"index {i}: {a[i]!r} != {b[i]!r}"
    return f"length {len(a)} != {len(b)}"


def run(root: str = REPO_ROOT,
        seeds: Tuple[int, ...] = DEFAULT_SEEDS) -> List[Violation]:
    vs: List[Violation] = []
    can_dump = threading.current_thread() is threading.main_thread()
    if can_dump:
        faulthandler.dump_traceback_later(SANITIZER_TIMEOUT,
                                          exit=False)
    try:
        # teeth first: the planted unlocked RMW must lose updates under
        # its documented seed, and the locked fix must conserve — a
        # fuzzer that can't reproduce its own planted bug proves
        # nothing about the hub
        got, want, _ = demo_lost_update(LOST_UPDATE_SEED, locked=False)
        if got >= want:
            vs.append(Violation(
                "S002", HUB_PATH, 1, "demo_lost_update",
                f"planted lost-update did NOT reproduce under seed "
                f"{LOST_UPDATE_SEED} (got {got} of {want}) — the "
                "sanitizer lost its teeth"))
        got, want, _ = demo_lost_update(LOST_UPDATE_SEED, locked=True)
        if got != want:
            vs.append(Violation(
                "S001", HUB_PATH, 1, "demo_lost_update",
                f"locked counter lost updates ({got} of {want}) — "
                "ShimLock mutual exclusion broke"))

        for seed in seeds:
            r1 = fuzz_hub(seed)
            r2 = fuzz_hub(seed)
            func = f"ExpertHub[fuzz seed={seed}]"
            if r1.trace != r2.trace:
                vs.append(Violation(
                    "S002", HUB_PATH, 1, func,
                    "replay is not byte-deterministic: "
                    + _diverge(r1.trace, r2.trace)))
            for f in r1.failures:
                vs.append(Violation("S001", HUB_PATH, 1, func, f))
            if r1.errors:
                vs.append(Violation(
                    "S001", HUB_PATH, 1, func,
                    f"unexpected lifecycle errors: {r1.errors}"))

        rf = fuzz_hub(FAIL_SEED, fail_expert=True)
        func = f"ExpertHub[fuzz seed={FAIL_SEED} fail_expert]"
        for f in rf.failures:
            vs.append(Violation("S001", HUB_PATH, 1, func, f))
        if rf.stats["stage_failures"] < 1:
            vs.append(Violation(
                "S002", HUB_PATH, 1, func,
                "staging-failure path never exercised under seed "
                f"{FAIL_SEED} — pick a seed whose workload wants the "
                "missing expert"))
    finally:
        if can_dump:
            faulthandler.cancel_dump_traceback_later()
    return vs
