"""Pallas kernel validator (rules P001-P004): BlockSpec geometry
checked on a CPU-only runner.

Every serving kernel ships with ``interpret=True`` so CI can execute it
without a TPU — but interpret mode checks *none* of the Mosaic lowering
constraints, so a BlockSpec whose index map walks off the operand, a
block that doesn't divide its array, or a scratch buffer in an illegal
memory space all pass CI green and explode on first real-TPU run
(ROADMAP: "Real Mosaic path"). This pass closes the CPU-checkable half
of that gap statically:

  P001  block-shape divisibility: every BlockSpec dim must divide its
        operand dim (the repo's kernels are written no-padding; a
        non-dividing block silently reads garbage lanes in the last
        block).
  P002  index-map bounds: the index map, evaluated over the full grid
        (or its corners when the grid is large) with the call's real
        scalar-prefetch operands, must return one block index per
        operand dim with ``idx*block + block <= dim``.
  P003  memory-space / VMEM-budget legality: scratch buffers must live
        in an addressable TPU space (VMEM/SMEM/semaphore), and the
        per-grid-step working set (all in/out blocks + scratch) must
        fit the ~16 MiB per-core VMEM the guide documents.
  P004  (warning) tile alignment: a block's last dim should be a
        multiple of the 128-lane VREG width — or span the whole
        operand axis, which Mosaic pads internally.

Capture, not execution: ``pl.pallas_call`` is monkeypatched with a
recorder that notes the grid/spec geometry and the concrete call
shapes, then returns zero outputs — so each kernel's own Python
wrapper (reshapes, moveaxis, block-size snapping) runs for real and
the checked specs are exactly what a TPU lowering would see.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import os
from typing import Any, Callable, Dict, Iterator, List, Optional, \
    Sequence, Tuple

from . import REPO_ROOT, Violation

LANE = 128
VMEM_BYTES = 16 * 1024 * 1024          # per-core, from the TPU guide
_GRID_ENUM_CAP = 4096                  # full enumeration bound

_LEGAL_SCRATCH_SPACES = {"vmem", "smem", "semaphore"}


@dataclasses.dataclass
class PallasCallRecord:
    """One captured ``pl.pallas_call`` invocation."""
    kernel_name: str
    path: str                          # repo-relative file of the kernel
    line: int
    grid: Tuple[int, ...]
    in_specs: Sequence[Any]
    out_specs: Sequence[Any]
    scratch_shapes: Sequence[Any]
    num_scalar_prefetch: int
    in_shapes: Sequence[Tuple[Tuple[int, ...], Any]]   # (shape, dtype)
    out_shapes: Sequence[Tuple[Tuple[int, ...], Any]]
    scalar_args: Sequence[Any]         # host copies of prefetch operands


def _kernel_origin(kernel: Callable) -> Tuple[str, str, int]:
    fn = kernel
    while hasattr(fn, "func"):         # unwrap functools.partial
        fn = fn.func
    name = getattr(fn, "__name__", str(fn))
    code = getattr(fn, "__code__", None)
    if code is None:
        return name, "<unknown>", 0
    path = os.path.relpath(code.co_filename, REPO_ROOT)
    return name, path.replace(os.sep, "/"), code.co_firstlineno


def _flat(specs: Any) -> List[Any]:
    if specs is None:
        return []
    if isinstance(specs, (list, tuple)):
        return list(specs)
    return [specs]


@contextlib.contextmanager
def capture_pallas_calls() -> Iterator[List[PallasCallRecord]]:
    """Swap ``pl.pallas_call`` for a recorder returning zero outputs.

    The wrapper under test runs eagerly; every pallas_call it makes is
    appended to the yielded list instead of executing."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental import pallas as pl

    records: List[PallasCallRecord] = []
    orig = pl.pallas_call

    def recorder(kernel, out_shape=None, *, grid_spec=None, grid=(),
                 in_specs=None, out_specs=None, scratch_shapes=(),
                 interpret=False, **_kw):
        name, path, line = _kernel_origin(kernel)
        if grid_spec is not None:
            g = tuple(grid_spec.grid)
            ins = _flat(grid_spec.in_specs)
            outs = _flat(grid_spec.out_specs)
            scratch = _flat(getattr(grid_spec, "scratch_shapes", ()))
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0))
        else:
            g = tuple(grid) if isinstance(grid, (tuple, list)) else \
                (grid,)
            ins, outs = _flat(in_specs), _flat(out_specs)
            scratch, nsp = _flat(scratch_shapes), 0

        def runner(*args):
            shapes = [(tuple(a.shape), a.dtype) for a in args]
            out_leaves = jax.tree_util.tree_leaves(
                out_shape, is_leaf=lambda x: hasattr(x, "shape"))
            records.append(PallasCallRecord(
                kernel_name=name, path=path, line=line, grid=g,
                in_specs=ins, out_specs=outs, scratch_shapes=scratch,
                num_scalar_prefetch=nsp,
                in_shapes=shapes[nsp:],
                out_shapes=[(tuple(o.shape), o.dtype)
                            for o in out_leaves],
                scalar_args=[np.asarray(a) for a in args[:nsp]]))
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shape,
                is_leaf=lambda x: hasattr(x, "shape"))

        return runner

    pl.pallas_call = recorder
    try:
        yield records
    finally:
        pl.pallas_call = orig


# ---------------------------------------------------------------------------
# geometry checks over one record
# ---------------------------------------------------------------------------


def _grid_points(grid: Tuple[int, ...]) -> List[Tuple[int, ...]]:
    total = 1
    for g in grid:
        total *= max(int(g), 1)
    if total <= _GRID_ENUM_CAP:
        return list(itertools.product(*(range(int(g)) for g in grid)))
    corners = itertools.product(*(sorted({0, int(g) - 1})
                                  for g in grid))
    return list(corners)


def _dtype_bytes(dtype: Any) -> int:
    import numpy as np
    return int(np.dtype(dtype).itemsize)


def check_record(rec: PallasCallRecord, case: str) -> List[Violation]:
    out: List[Violation] = []

    def v(rule: str, msg: str, severity: str = "error") -> None:
        out.append(Violation(rule, rec.path, rec.line,
                             f"{rec.kernel_name}[{case}]", msg,
                             severity=severity))

    roles = ([("in", i, s, sh) for i, (s, sh) in
              enumerate(zip(rec.in_specs, rec.in_shapes))]
             + [("out", i, s, sh) for i, (s, sh) in
                enumerate(zip(rec.out_specs, rec.out_shapes))])
    if len(rec.in_specs) != len(rec.in_shapes):
        v("P001", f"{len(rec.in_specs)} in_specs for "
          f"{len(rec.in_shapes)} non-prefetch operands")
    if len(rec.out_specs) != len(rec.out_shapes):
        v("P001", f"{len(rec.out_specs)} out_specs for "
          f"{len(rec.out_shapes)} outputs")

    vmem = 0
    for role, i, spec, (shape, dtype) in roles:
        block = tuple(spec.block_shape)
        where = f"{role}_specs[{i}] (operand {shape})"
        if len(block) != len(shape):
            v("P001", f"{where}: block rank {len(block)} != operand "
              f"rank {len(shape)}")
            continue
        nb = 1
        for d, (b, s) in enumerate(zip(block, shape)):
            if b is None:
                b = s
            if b <= 0 or s % b:
                v("P001", f"{where}: block dim {d} = {b} does not "
                  f"divide operand dim {s} (last block would read "
                  "out of bounds)")
            nb *= max(int(b), 1)
        vmem += nb * _dtype_bytes(dtype)
        # P004 — lane alignment (warning): last block dim must be a
        # multiple of the 128-lane VREG or take the whole axis
        if block and block[-1] is not None and shape:
            last = int(block[-1])
            if last % LANE and last != shape[-1]:
                v("P004", f"{where}: last block dim {last} is neither "
                  f"a multiple of {LANE} lanes nor the full axis "
                  f"({shape[-1]}) — Mosaic will pad or reject",
                  severity="warning")

    # P002 — index-map bounds over the grid
    points = _grid_points(rec.grid)
    for role, i, spec, (shape, dtype) in roles:
        imap = getattr(spec, "index_map", None)
        block = tuple(spec.block_shape)
        if imap is None or len(block) != len(shape):
            continue
        where = f"{role}_specs[{i}]"
        for pt in points:
            try:
                idx = imap(*pt, *rec.scalar_args)
            except Exception as e:   # noqa: BLE001 — report as finding
                v("P002", f"{where}: index map raised {e!r} at grid "
                  f"point {pt}")
                break
            idx = tuple(idx) if isinstance(idx, (tuple, list)) else \
                (idx,)
            if len(idx) != len(shape):
                v("P002", f"{where}: index map returned {len(idx)} "
                  f"indices for rank-{len(shape)} operand at {pt}")
                break
            bad = False
            for d, (j, b, s) in enumerate(zip(idx, block, shape)):
                b = s if b is None else b
                j = int(j)
                if j < 0 or (j + 1) * int(b) > s:
                    v("P002", f"{where}: grid point {pt} maps dim {d} "
                      f"to block {j} (elements {j * int(b)}.."
                      f"{(j + 1) * int(b)}) outside operand dim {s}")
                    bad = True
                    break
            if bad:
                break

    # P003 — scratch memory space + VMEM budget
    for i, sc in enumerate(rec.scratch_shapes):
        space = str(getattr(sc, "memory_space", "vmem") or "vmem")
        space = space.split(".")[-1].lower()
        if space not in _LEGAL_SCRATCH_SPACES:
            v("P003", f"scratch_shapes[{i}]: memory space {space!r} is "
              "not addressable from a TPU kernel (use VMEM/SMEM/"
              "semaphore)")
        shape = tuple(getattr(sc, "shape", ()))
        n = 1
        for s in shape:
            n *= int(s)
        if space == "vmem":
            vmem += n * _dtype_bytes(getattr(sc, "dtype", "float32"))
    if vmem > VMEM_BYTES:
        v("P003", f"per-grid-step working set {vmem / 2**20:.1f} MiB "
          f"exceeds the ~{VMEM_BYTES // 2**20} MiB per-core VMEM "
          "(shrink the block sizes)")
    return out


# ---------------------------------------------------------------------------
# kernel registry — representative serving shapes per kernel
# ---------------------------------------------------------------------------


def _cases() -> List[Tuple[str, Callable[[], Any]]]:
    import jax.numpy as jnp
    import numpy as np

    def ring(B, H, KV, dh, S, bs, dtype=jnp.float32):
        def build():
            from repro.kernels.decode_attention import \
                decode_attention_pallas
            z = lambda *s: jnp.zeros(s, dtype)          # noqa: E731
            decode_attention_pallas(
                z(B, H, dh), z(B, S, KV, dh), z(B, S, KV, dh),
                jnp.zeros((), jnp.int32),
                jnp.zeros((S,), jnp.int32), block_s=bs)
        return build

    def paged(B, H, KV, dh, page, nlp, dtype=jnp.float32):
        def build():
            from repro.kernels.decode_attention import \
                paged_decode_attention_pallas
            P1 = B * nlp + 1
            z = lambda *s: jnp.zeros(s, dtype)          # noqa: E731
            tbl = np.arange(B * nlp, dtype=np.int32).reshape(B, nlp)
            paged_decode_attention_pallas(
                z(B, H, dh), z(P1, page, KV, dh), z(P1, page, KV, dh),
                jnp.asarray(tbl), jnp.zeros((), jnp.int32),
                jnp.zeros((nlp * page,), jnp.int32))
        return build

    def cosine(B, M, h):
        def build():
            from repro.kernels.cosine_topk import cosine_scores_pallas
            cosine_scores_pallas(jnp.zeros((B, h)), jnp.zeros((M, h)),
                                 jnp.zeros((M,)))
        return build

    def escore(B, D, H, K):
        def build():
            from repro.kernels.expert_score import expert_score_pallas, \
                pad_to_lane
            Dp = pad_to_lane(D)
            expert_score_pallas(
                jnp.zeros((B, Dp)), jnp.zeros((K, Dp, H)),
                jnp.zeros((K, H)), jnp.zeros((K, H, Dp)),
                jnp.zeros((K, Dp)), d_real=D)
        return build

    def wkv(B, H, P):
        def build():
            from repro.kernels.wkv_step import wkv_step_pallas
            z = lambda *s: jnp.zeros(s)                 # noqa: E731
            wkv_step_pallas(z(B, H, P), z(B, H, P), z(B, H, P),
                            z(B, H, P), z(H, P), z(B, H, P, P))
        return build

    return [
        ("ring_B2_H8_KV2_dh128_S1024", ring(2, 8, 2, 128, 1024, 256)),
        ("ring_B4_H8_KV2_dh64_S512_bf16",
         ring(4, 8, 2, 64, 512, 128, jnp.bfloat16)),
        ("paged_B3_H8_KV2_dh64_p8", paged(3, 8, 2, 64, 8, 8)),
        ("paged_B2_H16_KV2_dh128_p16", paged(2, 16, 2, 128, 16, 4)),
        ("cosine_B256_M10_h128", cosine(256, 10, 128)),
        ("expert_score_B128_D784_H128_K6", escore(128, 784, 128, 6)),
        ("wkv_B2_H4_P64", wkv(2, 4, 64)),
        ("wkv_B1_H8_P128", wkv(1, 8, 128)),
    ]


def run() -> List[Violation]:
    out: List[Violation] = []
    for case, build in _cases():
        with capture_pallas_calls() as records:
            build()
        if not records:
            out.append(Violation(
                "P001", "src/repro/kernels", 0, case,
                "kernel wrapper made no pallas_call (capture broken?)"))
        for rec in records:
            out.extend(check_record(rec, case))
    return out
