from .adamw import adamw_init, adamw_update, global_norm
from .schedules import constant_lr, cosine_warmup, step_decay

__all__ = ["adamw_init", "adamw_update", "global_norm", "constant_lr",
           "cosine_warmup", "step_decay"]
