"""AdamW + global-norm clipping, pure JAX (no optax).

Optimizer state is a pytree mirroring params:
  {"m": tree, "v": tree, "step": ()}
First/second moments are kept in float32 regardless of param dtype (bf16
training keeps f32 master statistics; the update is applied in f32 and cast
back to the param dtype).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def adamw_init(params: PyTree) -> PyTree:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(
    grads: PyTree,
    state: PyTree,
    params: PyTree,
    lr: jnp.ndarray,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    clip_norm: Optional[float] = None,
) -> Tuple[PyTree, PyTree]:
    """One AdamW step. Returns (new_params, new_state)."""
    step = state["step"] + 1
    if clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
