"""Learning-rate schedules as step -> lr callables (traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.float32(lr)


def step_decay(base_lr: float, *, decay: float = 0.1, every_steps: int):
    """The paper's AE/MLP recipe: lr /= 10 every 15 epochs."""
    def fn(step):
        n = jnp.floor_divide(step, every_steps).astype(jnp.float32)
        return jnp.float32(base_lr) * jnp.float32(decay) ** n
    return fn


def cosine_warmup(base_lr: float, *, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        prog = (step - warmup_steps) / jnp.maximum(
            total_steps - warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(base_lr) * jnp.where(step < warmup_steps, warm, cos)
    return fn
