"""Expert Hub: checkpoint-backed dynamic expert lifecycle with
popularity-driven residency.

Every server so far required its whole expert population to be built
and device-resident before the first request — the catalog was capped
by device memory at process start. The paper's premise is the opposite:
a central server hosting *numerous* expert models for clients who
cannot evaluate them locally, which at production scale means a
long-tail catalog of hundreds of experts on a fixed device mesh. The
hub makes residency a managed, demand-driven resource along the path

    cold checkpoint store  →  host-staged params  →  device bank slot
      (checkpoint/io.py         (numpy pytree,          (one slot of a
       expert store)             staged by a             BankedEngine's
                                 worker thread)          stacked params)

Residency state machine (per catalog entry):

    cold ──stage──▶ staging ──▶ staged ──commit──▶ resident
                                  ▲                    │
                                  └──────evict─────────┘

  * **Catalog.** Unbounded: one ``CatalogEntry`` per known expert —
    the shared ``ExpertSpec`` (core/registry.py), host params and/or a
    cold checkpoint-store pointer, popularity/pins/last-use books.
    Every hub expert shares one spec: equal specs are exactly what
    makes experts co-residable in one slot bank (the same predicate
    ``plan_placement`` banks by).
  * **Slot bank.** A ``BankedEngine`` with ``n_slots`` experts whose
    params are stacked on the leading ``expert`` axis (optionally
    GSPMD-sharded over a mesh). Loading an expert is ONE jitted donated
    per-slot scatter into the stacked params — executables are keyed on
    bank shape, not expert identity, so swapping an expert into a slot
    never recompiles prefill/decode.
  * **Residency is refcounted.** Rows pin their expert at admission and
    unpin at response; only pin-free residents are evictable, so a slot
    is never recycled under live KV state (asserted for the paged
    layout, whose per-slot prefix cache is invalidated on eviction).
  * **Eviction is popularity-weighted LRU.** The victim is the
    evictable resident with the fewest router hits (``Router.expert_hits``
    — bind via ``bind_popularity``), ties broken least-recently-used:
    a hot expert is never displaced while a colder candidate exists.
  * **Prefetch is asynchronous.** Wanted-but-cold experts are staged by
    a worker thread while resident waves keep decoding — the
    ``DispatchExecutor`` seam runs ``Scheduler._service_hub`` before
    admission, so commits are enqueued ahead of the step's decode ticks
    and staging I/O overlaps device compute. ``service(block=True)``
    (an idle engine) waits on staging instead of spinning.
  * **Backpressure.** ``acquire`` on a non-resident expert enqueues the
    want and raises ``NotResident``; the scheduler parks the rows in
    their queues (mirroring ``PagePoolExhausted``) until the hub
    commits the expert.

``HubStats`` carries loads, evictions, stage/commit latencies and
resident-miss stalls; ``benchmarks/serving_bench.py --hub`` drives a
Zipf long-tail workload over a catalog far larger than the slot count
and asserts token-identity to a fully-resident baseline.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from concurrent import futures
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..checkpoint import io as ckpt_io
from ..core.registry import ExpertRegistry, ExpertSpec
from .placement import BankedEngine


class NotResident(RuntimeError):
    """Admission outcome: the routed expert has no device slot yet.

    Raising enqueues nothing by itself — ``ExpertHub.acquire`` records
    the want before raising, so the scheduler's contract mirrors
    ``PagePoolExhausted``: park the rows where they are and retry once
    the hub commits the expert (a later ``service`` call).
    """

    def __init__(self, expert: int, name: str):
        super().__init__(
            f"expert {expert} ({name!r}) is not device-resident; "
            "queued for staging")
        self.expert = expert
        self.name = name


class HubStats:
    """Lifecycle counters for one ``ExpertHub``.

    ``loads`` counts slot commits (first load and every re-load),
    ``evictions`` slot recycles, ``resident_misses`` every admission
    that found its expert cold (the scheduler's stall signal), and the
    latency accumulators time the two lifecycle edges: *stage* (cold
    checkpoint → host numpy, worker thread) and *commit* (host → device
    slot scatter enqueue).
    """

    def __init__(self):
        self.loads = 0
        self.evictions = 0
        self.resident_misses = 0
        self.stage_count = 0
        self.stage_ms = 0.0
        self.stage_cache_hits = 0       # wanted expert already staged
        self.commit_count = 0
        self.commit_ms = 0.0

    @property
    def stage_ms_avg(self) -> float:
        return self.stage_ms / max(self.stage_count, 1)

    @property
    def commit_ms_avg(self) -> float:
        return self.commit_ms / max(self.commit_count, 1)

    def as_dict(self) -> Dict[str, float]:
        return {"loads": self.loads, "evictions": self.evictions,
                "resident_misses": self.resident_misses,
                "stage_count": self.stage_count,
                "stage_ms_avg": self.stage_ms_avg,
                "stage_cache_hits": self.stage_cache_hits,
                "commit_count": self.commit_count,
                "commit_ms_avg": self.commit_ms_avg}

    def __repr__(self) -> str:
        return (f"HubStats(loads={self.loads}, "
                f"evictions={self.evictions}, "
                f"resident_misses={self.resident_misses}, "
                f"stage={self.stage_count}x{self.stage_ms_avg:.1f}ms"
                f"(+{self.stage_cache_hits} cached), "
                f"commit={self.commit_count}x{self.commit_ms_avg:.1f}ms)")


@dataclasses.dataclass
class CatalogEntry:
    """One known expert: where its weights live and who is using it."""
    name: str
    params: Any = None              # host-staged numpy pytree (or None)
    store: Optional[str] = None     # cold-tier store root (checkpoint/io)
    on_disk: bool = False           # a checkpoint exists in the store
    state: str = "cold"             # cold | staging | staged | resident
    slot: int = -1                  # device bank slot while resident
    pins: int = 0                   # in-flight rows holding residency
    last_used: int = 0              # hub clock at last admission


@dataclasses.dataclass
class HubMember:
    """Registry-facing handle: one catalog expert served via the hub's
    slot bank (the dynamic-residency analogue of ``BankMember``)."""
    hub: "ExpertHub"
    expert: int

    def pad_shape(self, n_rows: int, prompt_len: int) -> Tuple[int, int]:
        return self.hub.bank.pad_shape(n_rows, prompt_len)

    @property
    def batch_buckets(self) -> Tuple[int, ...]:
        return self.hub.bank.batch_buckets

    @property
    def kv_layout(self) -> str:
        return self.hub.bank.kv_layout

    @property
    def stats(self):
        return self.hub.bank.stats

    @property
    def resident(self) -> bool:
        return self.hub.slot_of(self.expert) is not None


class ExpertHub:
    """Dynamic expert residency over a fixed slot bank.

    The hub owns one ``BankedEngine`` with ``n_slots`` expert slots and
    an unbounded catalog; ``acquire``/``pin``/``unpin`` are the
    scheduler's admission contract and ``service`` is the per-step
    lifecycle driver (poll staging, commit wanted experts into slots,
    kick prefetch). All catalog mutation happens on the scheduler
    thread — the staging worker only reads checkpoints into numpy.
    """

    def __init__(self, model, *, n_slots: int, max_len: int = 256,
                 min_len_bucket: int = 8,
                 len_buckets: Optional[Sequence[int]] = None,
                 batch_buckets: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None, kv_layout: str = "ring",
                 page_size: int = 8, pool_pages: Optional[int] = None,
                 store: Optional[str] = None, prefetch: bool = True,
                 host_cache: Optional[int] = None):
        if n_slots < 1:
            raise ValueError(f"ExpertHub needs n_slots >= 1, got {n_slots}")
        self.model = model
        self.n_slots = n_slots
        self.store = store
        self.prefetch = prefetch
        # bound on retained host-staged copies of *re-stageable*
        # (cold-store-backed) non-resident experts; None = keep every
        # staged copy (fastest reloads, host memory grows toward the
        # catalog size — fine for laptop runs, set a cap for real
        # long-tail catalogs)
        self.host_cache = host_cache
        # zero template params fill the slots until real experts commit;
        # every executable is traced against this stacked shape, so
        # later commits can never change a signature
        shapes = model.param_shapes()
        tmpl = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        self.bank = BankedEngine(
            model, [tmpl] * n_slots, max_len=max_len,
            min_len_bucket=min_len_bucket, len_buckets=len_buckets,
            batch_buckets=batch_buckets, mesh=mesh, kv_layout=kv_layout,
            page_size=page_size, pool_pages=pool_pages)
        self.spec = ExpertSpec(
            arch=model.cfg.replace(name=""), max_len=self.bank.max_len,
            len_buckets=tuple(self.bank.len_buckets),
            batch_buckets=tuple(self.bank.batch_buckets),
            kv_layout=self.bank.kv_layout,
            page=(self.bank.core.page if kv_layout == "paged" else None),
            pool_pages=(self.bank.core.pool.n_pages
                        if kv_layout == "paged" else None))
        if not self.spec.bankable:
            raise ValueError(
                f"{model.cfg.family!r} capacity-dispatch MoE experts "
                "cannot share a slot bank (outputs depend on batch "
                "padding); serve them per-engine")
        self._host_like = jax.tree_util.tree_map(
            lambda s: np.zeros(s.shape, s.dtype), shapes)
        self.catalog: List[CatalogEntry] = []
        self._index: Dict[str, int] = {}
        self._slot_expert: List[Optional[int]] = [None] * n_slots
        self._wanted: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self._staging: Dict[int, Future] = {}
        self._pool: Optional[ThreadPoolExecutor] = None
        self._install = None
        self._tick = 0
        # router hit counts (rebound by bind_popularity when a Router
        # fronts the hub; pre-routed schedulers feed it directly)
        self.popularity: collections.Counter = collections.Counter()
        self.stats = HubStats()

    # -- catalog ---------------------------------------------------------
    def add_expert(self, name: str, params: Any = None, *,
                   cold: bool = False) -> int:
        """Register one expert. ``params`` (a host pytree) stages it
        immediately; ``cold=True`` writes the params to the checkpoint
        store and drops the host copy (the full lifecycle path);
        ``params=None`` points at an expert already in the store."""
        if name in self._index:
            raise ValueError(f"expert {name!r} already in the catalog")
        entry = CatalogEntry(name=name, store=self.store)
        if params is not None:
            params = jax.tree_util.tree_map(np.asarray, params)
            if cold:
                if self.store is None:
                    raise ValueError("cold=True needs a store directory")
                ckpt_io.save_expert(self.store, name, params)
                entry.on_disk = True
            else:
                entry.params = params
                entry.state = "staged"
        elif self.store is None:
            raise ValueError(
                f"expert {name!r}: no params and no checkpoint store")
        else:
            entry.on_disk = True          # pre-existing store checkpoint
        e = len(self.catalog)
        self.catalog.append(entry)
        self._index[name] = e
        return e

    def add_from_store(self, names: Optional[Sequence[str]] = None
                       ) -> List[int]:
        """Catalog every expert found in the checkpoint store."""
        if self.store is None:
            raise ValueError("hub has no checkpoint store")
        names = names if names is not None else \
            ckpt_io.list_experts(self.store)
        return [self.add_expert(n) for n in names]

    def build_registry(self) -> ExpertRegistry:
        """An ``ExpertRegistry`` over the catalog: every backend is a
        ``HubMember`` and every entry carries the hub's shared spec."""
        reg = ExpertRegistry()
        for e, c in enumerate(self.catalog):
            reg.add(c.name, HubMember(self, e), spec=self.spec)
        return reg

    def bind_popularity(self, counter: collections.Counter) -> None:
        """Share the router's per-expert hit Counter as the eviction
        policy's popularity signal (same object, zero plumbing)."""
        counter.update(self.popularity)
        self.popularity = counter

    def __len__(self) -> int:
        return len(self.catalog)

    # -- residency -------------------------------------------------------
    def slot_of(self, e: int) -> Optional[int]:
        c = self.catalog[e]
        return c.slot if c.state == "resident" else None

    def expert_in(self, slot: int) -> Optional[int]:
        return self._slot_expert[slot]

    @property
    def resident_experts(self) -> List[int]:
        return [e for e in self._slot_expert if e is not None]

    @property
    def has_wanted(self) -> bool:
        return bool(self._wanted)

    def acquire(self, e: int) -> int:
        """Slot serving expert ``e`` (touching its LRU clock), or queue
        the want and raise ``NotResident`` — the scheduler's
        park-and-retry backpressure signal."""
        c = self.catalog[e]
        if c.state == "resident":
            c.last_used = self._tick
            return c.slot
        self.want(e)
        self.stats.resident_misses += 1
        raise NotResident(e, c.name)

    def want(self, e: int) -> None:
        c = self.catalog[e]
        if c.state == "resident" or e in self._wanted:
            return
        if c.state == "staged":
            # satisfiable from the host cache: no cold-tier stage needed
            self.stats.stage_cache_hits += 1
        self._wanted[e] = None

    def pin(self, e: int, n: int = 1) -> None:
        """Admitted rows hold their expert resident until harvested."""
        c = self.catalog[e]
        if c.state != "resident":
            raise ValueError(f"pin of non-resident expert {c.name!r}")
        c.pins += n

    def unpin(self, e: int, n: int = 1) -> None:
        c = self.catalog[e]
        if c.pins < n:
            raise ValueError(f"unpin below zero for expert {c.name!r}")
        c.pins -= n

    # -- lifecycle driver ------------------------------------------------
    def service(self, *, block: bool = False) -> int:
        """One lifecycle round: poll staging results, commit wanted
        experts into slots, kick prefetch for the rest. Returns commits
        made. ``block=True`` (nothing on device to overlap with) waits
        for the oldest in-flight staging instead of busy-spinning.
        """
        self._tick += 1
        # the host-cache trim runs on EVERY exit, including the staging
        # -failure re-raise out of _poll_staging: skipping it there let
        # staged host copies outlive the host_cache cap for as long as
        # a flaky cold tier kept raising (rule L005's unpaired-exit
        # shape, found by the repro.analysis lifecycle review)
        try:
            self._poll_staging()
            committed = self._commit_ready()
            self._kick_staging()
            if block and not committed and self._wanted and self._staging:
                futures.wait([next(iter(self._staging.values()))])
                # _poll_staging owns failure handling: it resets a
                # failed entry to cold (retryable) before re-raising
                self._poll_staging()
                committed = self._commit_ready()
        finally:
            self._trim_host()
        return committed

    def _trim_host(self) -> None:
        """Enforce ``host_cache``: drop the host params of the least
        popular (then least recent) staged, unwanted, store-backed
        entries beyond the cap — they return to ``cold`` and re-stage
        from the checkpoint tier on their next want. Entries without a
        store are never dropped (their params are the only copy)."""
        if self.host_cache is None:
            return
        held = [e for e, c in enumerate(self.catalog)
                if c.state == "staged" and c.on_disk
                and e not in self._wanted]
        drop = len(held) - self.host_cache
        if drop <= 0:
            return
        held.sort(key=lambda e: (self.popularity[e],
                                 self.catalog[e].last_used))
        for e in held[:drop]:
            c = self.catalog[e]
            c.params = None
            c.state = "cold"

    def _poll_staging(self) -> None:
        for e in [e for e, f in self._staging.items() if f.done()]:
            fut = self._staging.pop(e)
            c = self.catalog[e]
            try:
                params, dt = fut.result()
            except Exception:
                # surface the failure loudly, but leave the entry
                # retryable (back to cold) and drop the want so other
                # experts' traffic keeps flowing — a sticky 'staging'
                # state would park this expert's rows forever
                c.state = "cold"
                self._wanted.pop(e, None)
                raise
            c.params = params
            c.state = "staged"
            self.stats.stage_count += 1
            self.stats.stage_ms += dt * 1e3

    def _commit_ready(self) -> int:
        n = 0
        for e in list(self._wanted):
            c = self.catalog[e]
            if c.state == "resident":     # raced: wanted twice
                self._wanted.pop(e, None)
                continue
            if c.params is None:
                continue                  # still cold/staging
            slot = self._grab_slot()
            if slot is None:
                break                     # every slot pinned: decode on
            self._commit(e, slot)
            self._wanted.pop(e, None)
            n += 1
        return n

    def _kick_staging(self) -> None:
        for e in self._wanted:
            c = self.catalog[e]
            if c.state != "cold" or e in self._staging:
                continue
            c.state = "staging"
            if self.prefetch:
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=1,
                        thread_name_prefix="hub-stage")
                self._staging[e] = self._pool.submit(self._stage, e)
            else:                         # synchronous staging
                f: Future = Future()
                try:
                    f.set_result(self._stage(e))
                except Exception:
                    c.state = "cold"      # retryable, not wedged
                    self._wanted.pop(e, None)
                    raise
                self._staging[e] = f

    def _stage(self, e: int):
        """Worker-thread half: cold checkpoint → host numpy pytree."""
        c = self.catalog[e]
        t0 = time.perf_counter()
        params = ckpt_io.load_expert(c.store, c.name,
                                     like=self._host_like)
        return params, time.perf_counter() - t0

    def _slot_in_wave(self, slot: int) -> bool:
        """Whether any active wave still carries rows for ``slot``.

        Pins alone are not enough to gate eviction: a row's pin drops
        the moment it is harvested, but its KV pages (paged layout) are
        only released when its *whole wave* retires — so an expert can
        be pin-free while a mixed-``max_new`` wave still holds its
        pages. The wave's row map is the source of truth.
        """
        return any(w.uids.get(slot) for w in self.bank.core._active)

    def _grab_slot(self) -> Optional[int]:
        for s, owner in enumerate(self._slot_expert):
            if owner is None:
                return s
        victims = [e for e in self._slot_expert
                   if e is not None and self.catalog[e].pins == 0
                   and not self._slot_in_wave(self.catalog[e].slot)]
        if not victims:
            return None
        # popularity-weighted LRU: fewest router hits first, oldest
        # last-use breaking ties — a hot expert outlives cold ones
        victim = min(victims, key=lambda e: (self.popularity[e],
                                             self.catalog[e].last_used))
        return self._evict(victim)

    def _evict(self, e: int) -> int:
        c = self.catalog[e]
        slot = c.slot
        core = self.bank.core
        if core.kv_layout == "paged":
            # the slot's cached prefixes describe the OLD expert's KV;
            # drop them, then prove no live pages survive the eviction
            core.prefix_cache.invalidate(slot)
            used = core.pool.used_count(slot)
            if used:
                raise RuntimeError(
                    f"evicting {c.name!r} from slot {slot} with {used} "
                    "live page(s) — pin accounting broke")
        c.state = "staged"                # host copy retained: reloads
        c.slot = -1                       # skip the cold tier entirely
        #                                   (bounded by host_cache)
        self._slot_expert[slot] = None
        self.stats.evictions += 1
        return slot

    def _commit(self, e: int, slot: int) -> None:
        """Host-staged params → device bank slot: one jitted donated
        per-slot scatter into the stacked params. Executables are keyed
        on the bank's (E, ...) shape only, so this never invalidates
        the prefill/decode jit caches — the no-recompile property the
        bench asserts."""
        c = self.catalog[e]
        core = self.bank.core
        t0 = time.perf_counter()
        if self._install is None:
            s = core._bank_sharding()
            def fn(bank, new, at):
                return jax.tree_util.tree_map(
                    lambda a, b: a.at[at].set(b), bank, new)
            if s is not None:
                self._install = jax.jit(fn, donate_argnums=(0,),
                                        out_shardings=s)
            else:
                self._install = jax.jit(fn, donate_argnums=(0,))
        core.params = self._install(core.params, c.params,
                                    jnp.asarray(slot, jnp.int32))
        self.stats.commit_ms += (time.perf_counter() - t0) * 1e3
        self.stats.commit_count += 1
        self.stats.loads += 1
        c.state = "resident"
        c.slot = slot
        c.last_used = self._tick
        self._slot_expert[slot] = e

    # -- warmup ----------------------------------------------------------
    def warmup(self, max_batch: Optional[int] = None,
               commit: bool = True) -> None:
        """Compile the bank's whole executable ladder up front.

        The steady-state contract the bench asserts — *zero new
        executables after warmup, no matter which experts rotate
        through the slots* — only holds if every (batch bucket, len
        bucket) shape traffic can produce exists before measurement
        starts. Admits one throwaway wave per ladder point (tuple uids:
        the scheduler's orphan path discards any stragglers) and, with
        ``commit=True``, faults the first ``n_slots`` catalog experts
        into their slots so the install scatter is compiled too.
        Warmup compute runs on whatever params the slots hold — shapes
        are expert-agnostic, which is the very property that makes slot
        swapping recompile-free.
        """
        from .core import bucket_for
        bank = self.bank
        cap = bucket_for(min(max_batch or bank.batch_buckets[-1],
                             bank.batch_buckets[-1]),
                         bank.batch_buckets)
        rng = np.random.default_rng(0)
        for Sb in bank.len_buckets:
            for Bb in bank.batch_buckets:
                if Bb > cap:
                    break
                uids = [("__warmup__", Sb, Bb, i) for i in range(Bb)]
                prompts = [rng.integers(0, 100, size=Sb)
                           for _ in range(Bb)]
                bank.admit({0: (uids, prompts, [2] * Bb)})
                while bank.n_active:
                    bank.tick()
                bank.poll()
        if commit:
            for e in range(min(self.n_slots, len(self.catalog))):
                self.want(e)
            while self.has_wanted:
                if not self.service(block=True):
                    break

    # -- bookkeeping -----------------------------------------------------
    def check(self) -> None:
        """Invariant sweep (tests): slot maps and catalog agree, pins
        only on residents, wanted entries never resident."""
        for s, e in enumerate(self._slot_expert):
            if e is not None:
                c = self.catalog[e]
                assert c.state == "resident" and c.slot == s, (s, c)
        for e, c in enumerate(self.catalog):
            if c.state == "resident":
                assert self._slot_expert[c.slot] == e, (e, c)
            else:
                assert c.slot == -1, (e, c)
                assert c.pins == 0, f"pins on non-resident {c.name!r}"
        assert all(self.catalog[e].state != "resident"
                   for e in self._wanted)

    @property
    def install_compiles(self) -> int:
        """Real executables behind the slot-install wrapper (0 or 1 —
        counted into the bench's steady-state recompile assert)."""
        from .core import _wrapper_compiles
        return 0 if self._install is None else \
            _wrapper_compiles(self._install)
