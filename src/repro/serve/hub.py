"""Expert Hub: checkpoint-backed dynamic expert lifecycle with
popularity-driven residency.

Every server so far required its whole expert population to be built
and device-resident before the first request — the catalog was capped
by device memory at process start. The paper's premise is the opposite:
a central server hosting *numerous* expert models for clients who
cannot evaluate them locally, which at production scale means a
long-tail catalog of hundreds of experts on a fixed device mesh. The
hub makes residency a managed, demand-driven resource along the path

    cold checkpoint store  →  host-staged params  →  device bank slot
      (checkpoint/io.py         (numpy pytree,          (one slot of a
       expert store)             staged by a             BankedEngine's
                                 worker thread)          stacked params)

Residency state machine (per catalog entry):

    cold ──stage──▶ staging ──▶ staged ──commit──▶ resident
                                  ▲                    │
                                  └──────evict─────────┘

  * **Catalog.** Unbounded: one ``CatalogEntry`` per known expert —
    the shared ``ExpertSpec`` (core/registry.py), host params and/or a
    cold checkpoint-store pointer, popularity/pins/last-use books.
    Every hub expert shares one spec: equal specs are exactly what
    makes experts co-residable in one slot bank (the same predicate
    ``plan_placement`` banks by).
  * **Slot bank.** A ``BankedEngine`` with ``n_slots`` experts whose
    params are stacked on the leading ``expert`` axis (optionally
    GSPMD-sharded over a mesh). Loading an expert is ONE jitted donated
    per-slot scatter into the stacked params — executables are keyed on
    bank shape, not expert identity, so swapping an expert into a slot
    never recompiles prefill/decode.
  * **Residency is refcounted.** Rows pin their expert at admission and
    unpin at response; only pin-free residents are evictable, so a slot
    is never recycled under live KV state (asserted for the paged
    layout, whose per-slot prefix cache is invalidated on eviction).
  * **Eviction is popularity-weighted LRU.** The victim is the
    evictable resident with the fewest router hits (``Router.expert_hits``
    — bind via ``bind_popularity``), ties broken least-recently-used:
    a hot expert is never displaced while a colder candidate exists.
  * **Prefetch is asynchronous.** Wanted-but-cold experts are staged by
    a worker thread while resident waves keep decoding — the
    ``DispatchExecutor`` seam runs ``Scheduler._service_hub`` before
    admission, so commits are enqueued ahead of the step's decode ticks
    and staging I/O overlaps device compute. ``service(block=True)``
    (an idle engine) waits on staging instead of spinning.
  * **Backpressure.** ``acquire`` on a non-resident expert enqueues the
    want and raises ``NotResident``; the scheduler parks the rows in
    their queues (mirroring ``PagePoolExhausted``) until the hub
    commits the expert.

Threading model (machine-checked — see ``THREAD_CONTRACT`` below and
``docs/architecture.md`` § Threading model):

  Two threads touch hub state. The **scheduler thread** drives the
  whole lifecycle (``service``/``acquire``/``pin``/``unpin``/eviction/
  commit) and owns the bank, the page pool and the prefix cache. The
  **staging worker** (one ``hub-stage`` thread, spawned lazily, joined
  by ``close()``) receives ``(expert, name, store)`` jobs over
  ``_stage_q`` — a queue handoff, never a catalog read — performs the
  blocking checkpoint I/O with no lock held, and publishes the result
  (params first, then the ``staged`` state, or the ``cold`` reset +
  recorded error on failure) under ``_lock``. Everything both threads
  touch — catalog entry fields, the wanted/staging books, the shared
  popularity ``Counter``, ``HubStats`` — is guarded by ``_lock``;
  ``_cv`` (a condition on that same lock) is the one sanctioned
  blocking point (``service(block=True)`` waits on it, releasing the
  lock). ``repro.analysis races`` (rules R001–R004) statically enforces
  this contract; ``repro.analysis sanitizer`` (S001–S002) fuzzes real
  interleavings of the two threads under a deterministic schedule.

``HubStats`` carries loads, evictions, stage/commit latencies,
stage-failure counts and resident-miss stalls;
``benchmarks/serving_bench.py --hub`` drives a Zipf long-tail workload
over a catalog far larger than the slot count and asserts
token-identity to a fully-resident baseline.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..checkpoint import io as ckpt_io
from ..core.registry import ExpertRegistry, ExpertSpec
from ..obs.trace import NULL_TRACER
from .placement import BankedEngine

# ---------------------------------------------------------------------------
# The concurrency contract, as data. ``repro.analysis races`` parses this
# literal out of the module AST and statically verifies the code against
# it; the schedule-fuzzing sanitizer exercises the same contract
# dynamically. Keep it in lockstep with docs/architecture.md
# ("Threading model") — the checker fails the CI gate when code and
# contract drift.
#
#   * ``threads``       — entry-point qualnames per thread; everything
#                         reachable from them over the call graph is
#                         attributed to that thread.
#                         (``Scheduler._service_hub``/``_admit_batches``/
#                         ``_tick_engines``/``_harvest_engines`` are
#                         roots of their own because the executor seam
#                         in serve/core.py — outside this unit — is
#                         what calls them.)
#   * ``lock_guarded``  — state both threads touch: every access must
#                         hold the designated lock (lexically, or from
#                         a ``*_locked`` helper, whose call sites are
#                         themselves verified).
#   * ``queue_handoffs``— cross-thread channels that need no lock.
#   * ``single_writer`` — owned by exactly one thread; the checker
#                         proves the other thread never reaches them.
#   * ``blocking_calls``— calls that may block the host; forbidden
#                         under the lock (R003). ``_cv.wait``/
#                         ``wait_for`` are exempt: a condition wait
#                         releases the lock it blocks on.
#   * ``publish_order`` — R004: a ``state`` write publishing the named
#                         value must come *after* writes to its payload
#                         fields (params before ``staged``, slot before
#                         ``resident``) so no thread can observe a
#                         half-constructed entry.
# ---------------------------------------------------------------------------
THREAD_CONTRACT = {
    "lock": "_lock",
    "lock_aliases": ["_lock", "_cv"],
    "threads": {
        "scheduler": [
            "Scheduler.submit", "Scheduler.step", "Scheduler.drain",
            "Scheduler.check_invariants", "Scheduler.close",
            "Scheduler._service_hub", "Scheduler._admit_batches",
            "Scheduler._tick_engines", "Scheduler._harvest_engines",
            "ExpertHub.service", "ExpertHub.warmup", "ExpertHub.acquire",
            "ExpertHub.want", "ExpertHub.pin", "ExpertHub.unpin",
            "ExpertHub.note_hit", "ExpertHub.bind_popularity",
            "ExpertHub.slot_of", "ExpertHub.expert_in",
            "ExpertHub.resident_experts", "ExpertHub.has_wanted",
            "ExpertHub.total_pins", "ExpertHub.check", "ExpertHub.close",
            "ExpertHub.__len__",
        ],
        "stager": ["ExpertHub._stage_loop"],
    },
    "lock_guarded": {
        "entry_fields": ["state", "params", "slot", "pins", "last_used",
                         "misses", "stage_ms", "commit_ms",
                         "resident_s", "resident_since"],
        "fields": ["catalog", "_wanted", "_staging", "_stage_errors",
                   "popularity", "_stage_thread", "_closed"],
        "stats_fields": ["loads", "evictions", "resident_misses",
                         "stage_attempts", "stage_count", "stage_ms",
                         "stage_cache_hits", "stage_failures",
                         "commit_count", "commit_ms"],
    },
    "queue_handoffs": ["_stage_q"],
    "single_writer": {
        "scheduler": ["_index", "_slot_expert", "_install", "_tick",
                      "host_cache",
                      "queues", "n_queued", "_meta", "_done", "_seq",
                      "_skips", "_steps", "prefix_lru",
                      "refs", "_free", "_lru", "_active"],
    },
    "blocking_calls": ["load_expert", "save_expert", "load_pytree",
                       "save_pytree", "block_until_ready", "device_get",
                       "result", "join", "sleep", "wait"],
    "publish_order": {"state": {"staged": ["params"],
                                "resident": ["slot"]}},
}


class NotResident(RuntimeError):
    """Admission outcome: the routed expert has no device slot yet.

    Raising enqueues nothing by itself — ``ExpertHub.acquire`` records
    the want before raising, so the scheduler's contract mirrors
    ``PagePoolExhausted``: park the rows where they are and retry once
    the hub commits the expert (a later ``service`` call).
    """

    def __init__(self, expert: int, name: str):
        super().__init__(
            f"expert {expert} ({name!r}) is not device-resident; "
            "queued for staging")
        self.expert = expert
        self.name = name


class HubStats:
    """Lifecycle counters for one ``ExpertHub``.

    ``loads`` counts slot commits (first load and every re-load),
    ``evictions`` slot recycles, ``resident_misses`` every admission
    that found its expert cold (the scheduler's stall signal), and the
    latency accumulators time the two lifecycle edges: *stage* (cold
    checkpoint → host numpy, worker thread) and *commit* (host → device
    slot scatter enqueue).

    Conservation (asserted by ``ExpertHub.check`` and fuzzed by the
    sanitizer): ``loads == commit_count`` always, and every stage
    attempt is accounted for —
    ``stage_attempts == stage_count + stage_failures + in-flight``.
    All counters are mutated under the hub lock only.
    """

    def __init__(self):
        self.loads = 0
        self.evictions = 0
        self.resident_misses = 0
        self.stage_attempts = 0         # staging jobs handed to a worker
        self.stage_count = 0            # ... that published params
        self.stage_failures = 0         # ... that failed (entry reset)
        self.stage_ms = 0.0
        self.stage_cache_hits = 0       # wanted expert already staged
        self.commit_count = 0
        self.commit_ms = 0.0

    @property
    def stage_ms_avg(self) -> float:
        return self.stage_ms / max(self.stage_count, 1)

    @property
    def commit_ms_avg(self) -> float:
        return self.commit_ms / max(self.commit_count, 1)

    def as_dict(self) -> Dict[str, float]:
        return {"loads": self.loads, "evictions": self.evictions,
                "resident_misses": self.resident_misses,
                "stage_attempts": self.stage_attempts,
                "stage_count": self.stage_count,
                "stage_failures": self.stage_failures,
                "stage_ms_avg": self.stage_ms_avg,
                "stage_cache_hits": self.stage_cache_hits,
                "commit_count": self.commit_count,
                "commit_ms_avg": self.commit_ms_avg}

    def __repr__(self) -> str:
        return (f"HubStats(loads={self.loads}, "
                f"evictions={self.evictions}, "
                f"resident_misses={self.resident_misses}, "
                f"stage={self.stage_count}x{self.stage_ms_avg:.1f}ms"
                f"(+{self.stage_cache_hits} cached, "
                f"{self.stage_failures} failed), "
                f"commit={self.commit_count}x{self.commit_ms_avg:.1f}ms)")


@dataclasses.dataclass
class CatalogEntry:
    """One known expert: where its weights live and who is using it.

    All fields below ``on_disk`` are shared between the scheduler
    thread and the staging worker and are guarded by the hub lock
    (``THREAD_CONTRACT["lock_guarded"]["entry_fields"]``)."""
    name: str
    params: Any = None              # host-staged numpy pytree (or None)
    store: Optional[str] = None     # cold-tier store root (checkpoint/io)
    on_disk: bool = False           # a checkpoint exists in the store
    state: str = "cold"             # cold | staging | staged | resident
    slot: int = -1                  # device bank slot while resident
    pins: int = 0                   # in-flight rows holding residency
    last_used: int = 0              # hub clock at last admission
    # per-expert lifecycle metrics (obs registry → future rebalancer):
    # residency wall time, admission misses, cumulative stage/commit
    # latency — all attributed to this expert, not just the hub total
    misses: int = 0                 # acquire() found this expert cold
    stage_ms: float = 0.0           # cumulative cold→host stage latency
    commit_ms: float = 0.0          # cumulative host→slot enqueue latency
    resident_s: float = 0.0         # total seconds spent resident
    resident_since: float = 0.0     # tracer clock at the last commit


@dataclasses.dataclass
class HubMember:
    """Registry-facing handle: one catalog expert served via the hub's
    slot bank (the dynamic-residency analogue of ``BankMember``)."""
    hub: "ExpertHub"
    expert: int

    def pad_shape(self, n_rows: int, prompt_len: int) -> Tuple[int, int]:
        return self.hub.bank.pad_shape(n_rows, prompt_len)

    @property
    def batch_buckets(self) -> Tuple[int, ...]:
        return self.hub.bank.batch_buckets

    @property
    def kv_layout(self) -> str:
        return self.hub.bank.kv_layout

    @property
    def stats(self):
        return self.hub.bank.stats

    @property
    def resident(self) -> bool:
        return self.hub.slot_of(self.expert) is not None


class ExpertHub:
    """Dynamic expert residency over a fixed slot bank.

    The hub owns one ``BankedEngine`` with ``n_slots`` expert slots and
    an unbounded catalog; ``acquire``/``pin``/``unpin`` are the
    scheduler's admission contract and ``service`` is the per-step
    lifecycle driver (commit staged experts into slots, kick prefetch,
    surface staging failures). Cold staging runs on one ``hub-stage``
    worker thread which publishes results under the hub lock — see the
    module docstring's threading model and ``THREAD_CONTRACT``. Call
    ``close()`` (or use the hub as a context manager) to join the
    worker on shutdown.
    """

    def __init__(self, model, *, n_slots: int, max_len: int = 256,
                 min_len_bucket: int = 8,
                 len_buckets: Optional[Sequence[int]] = None,
                 batch_buckets: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None, kv_layout: str = "ring",
                 page_size: int = 8, pool_pages: Optional[int] = None,
                 chunk_len: Optional[int] = None,
                 store: Optional[str] = None, prefetch: bool = True,
                 host_cache: Optional[int] = None,
                 stage_timeout: float = 120.0):
        if n_slots < 1:
            raise ValueError(f"ExpertHub needs n_slots >= 1, got {n_slots}")
        self.model = model
        self.n_slots = n_slots
        self.store = store
        self.prefetch = prefetch
        # how long service(block=True) waits for staging progress
        # before declaring the worker wedged (fail-fast, not a hang)
        self.stage_timeout = stage_timeout
        # bound on retained host-staged copies of *re-stageable*
        # (cold-store-backed) non-resident experts; None = keep every
        # staged copy (fastest reloads, host memory grows toward the
        # catalog size — fine for laptop runs, set a cap for real
        # long-tail catalogs)
        self.host_cache = host_cache
        # zero template params fill the slots until real experts commit;
        # every executable is traced against this stacked shape, so
        # later commits can never change a signature
        shapes = model.param_shapes()
        tmpl = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        self.bank = BankedEngine(
            model, [tmpl] * n_slots, max_len=max_len,
            min_len_bucket=min_len_bucket, len_buckets=len_buckets,
            batch_buckets=batch_buckets, mesh=mesh, kv_layout=kv_layout,
            page_size=page_size, pool_pages=pool_pages,
            chunk_len=chunk_len)
        self.spec = ExpertSpec(
            arch=model.cfg.replace(name=""), max_len=self.bank.max_len,
            len_buckets=tuple(self.bank.len_buckets),
            batch_buckets=tuple(self.bank.batch_buckets),
            kv_layout=self.bank.kv_layout,
            page=(self.bank.core.page if kv_layout == "paged" else None),
            pool_pages=(self.bank.core.pool.n_pages
                        if kv_layout == "paged" else None),
            chunk_len=(self.bank.core.chunk_len
                       if kv_layout == "paged" else None))
        if not self.spec.bankable:
            raise ValueError(
                f"{model.cfg.family!r} capacity-dispatch MoE experts "
                "cannot share a slot bank (outputs depend on batch "
                "padding); serve them per-engine")
        self._host_like = jax.tree_util.tree_map(
            lambda s: np.zeros(s.shape, s.dtype), shapes)
        self.catalog: List[CatalogEntry] = []
        self._index: Dict[str, int] = {}
        self._slot_expert: List[Optional[int]] = [None] * n_slots
        self._wanted: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        # experts with a staging job in flight (insertion-ordered set)
        self._staging: Dict[int, None] = {}
        # failures recorded by the worker, re-raised by service()
        self._stage_errors: List[Tuple[int, BaseException]] = []
        self._install = None
        self._tick = 0
        # router hit counts (rebound by bind_popularity when a Router
        # fronts the hub; pre-routed schedulers feed it via note_hit)
        self.popularity: collections.Counter = collections.Counter()
        self.stats = HubStats()
        # -- concurrency plumbing (THREAD_CONTRACT) ----------------------
        # the designated lock; _cv (same lock) is the one sanctioned
        # blocking point. _stage_q is the scheduler->worker job handoff.
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._stage_q: "queue.Queue[Optional[Tuple[int, str, str]]]" = \
            queue.Queue()
        self._stage_thread: Optional[threading.Thread] = None
        self._closed = False
        # seam for the schedule-fuzzing sanitizer: it swaps in managed
        # thread/lock/queue shims before the worker first spawns
        self._thread_factory = threading.Thread
        # lifecycle tracer (repro.obs). Bound once, before traffic, by
        # Scheduler.bind_tracer; both threads only ever *read* it, and
        # the disabled NULL_TRACER spans still measure (HubStats keeps
        # its stage/commit latencies with tracing off)
        self._tracer = NULL_TRACER

    def bind_tracer(self, tracer) -> None:
        """Install a lifecycle tracer (None restores the disabled
        NULL_TRACER). Call before traffic, from the scheduler thread —
        hub spans record stage/commit latency with the same clock reads
        that feed ``HubStats``."""
        self._tracer = tracer if tracer is not None else NULL_TRACER

    # -- catalog ---------------------------------------------------------
    def add_expert(self, name: str, params: Any = None, *,
                   cold: bool = False) -> int:
        """Register one expert. ``params`` (a host pytree) stages it
        immediately; ``cold=True`` writes the params to the checkpoint
        store and drops the host copy (the full lifecycle path);
        ``params=None`` points at an expert already in the store."""
        if name in self._index:
            raise ValueError(f"expert {name!r} already in the catalog")
        entry = CatalogEntry(name=name, store=self.store)
        if params is not None:
            params = jax.tree_util.tree_map(np.asarray, params)
            if cold:
                if self.store is None:
                    raise ValueError("cold=True needs a store directory")
                # checkpoint write happens before the lock: blocking
                # I/O never runs under _lock (rule R003's discipline,
                # even on this setup path)
                ckpt_io.save_expert(self.store, name, params)
                entry.on_disk = True
            else:
                entry.params = params
                entry.state = "staged"
        elif self.store is None:
            raise ValueError(
                f"expert {name!r}: no params and no checkpoint store")
        else:
            entry.on_disk = True          # pre-existing store checkpoint
        with self._lock:
            e = len(self.catalog)
            self.catalog.append(entry)
            self._index[name] = e
        return e

    def add_from_store(self, names: Optional[Sequence[str]] = None
                       ) -> List[int]:
        """Catalog every expert found in the checkpoint store."""
        if self.store is None:
            raise ValueError("hub has no checkpoint store")
        names = names if names is not None else \
            ckpt_io.list_experts(self.store)
        return [self.add_expert(n) for n in names]

    def build_registry(self) -> ExpertRegistry:
        """An ``ExpertRegistry`` over the catalog: every backend is a
        ``HubMember`` and every entry carries the hub's shared spec."""
        reg = ExpertRegistry()
        for e, c in enumerate(self.catalog):
            reg.add(c.name, HubMember(self, e), spec=self.spec)
        return reg

    def bind_popularity(self, counter: collections.Counter, *,
                        router=None) -> None:
        """Share the router's per-expert hit Counter as the eviction
        policy's popularity signal (same object, zero plumbing). The
        Counter becomes lock-guarded shared state: pass the ``Router``
        via ``router=`` so its own ``route()`` increments take the
        hub lock too (``Router.hits_lock``)."""
        with self._lock:
            counter.update(self.popularity)
            self.popularity = counter
        if router is not None:
            router.hits_lock = self._lock

    def note_hit(self, e: int, n: int = 1) -> None:
        """Record routing hits for the eviction policy. This is the
        designated mutation point for the shared popularity Counter —
        an unlocked ``popularity[e] += 1`` is a read-modify-write race
        against the eviction ranking (rule R001; the sanitizer's
        planted lost-update demonstrates the loss)."""
        with self._lock:
            self.popularity[e] += n

    def __len__(self) -> int:
        with self._lock:
            return len(self.catalog)

    # -- residency -------------------------------------------------------
    def slot_of(self, e: int) -> Optional[int]:
        with self._lock:
            c = self.catalog[e]
            return c.slot if c.state == "resident" else None

    def expert_in(self, slot: int) -> Optional[int]:
        with self._lock:
            return self._slot_expert[slot]

    @property
    def resident_experts(self) -> List[int]:
        with self._lock:
            return [e for e in self._slot_expert if e is not None]

    @property
    def has_wanted(self) -> bool:
        with self._lock:
            return bool(self._wanted)

    def total_pins(self) -> int:
        """Sum of residency pins over the catalog (the scheduler's
        pin-conservation check compares this against its in-flight
        row count)."""
        with self._lock:
            return sum(c.pins for c in self.catalog)

    def acquire(self, e: int) -> int:
        """Slot serving expert ``e`` (touching its LRU clock), or queue
        the want and raise ``NotResident`` — the scheduler's
        park-and-retry backpressure signal."""
        with self._lock:
            c = self.catalog[e]
            if c.state == "resident":
                c.last_used = self._tick
                return c.slot
            self._want_locked(e)
            self.stats.resident_misses += 1
            c.misses += 1
            name = c.name
        raise NotResident(e, name)

    def want(self, e: int) -> None:
        with self._lock:
            self._want_locked(e)

    def _want_locked(self, e: int) -> None:
        c = self.catalog[e]
        if c.state == "resident" or e in self._wanted:
            return
        if c.state == "staged":
            # satisfiable from the host cache: no cold-tier stage needed
            self.stats.stage_cache_hits += 1
        self._wanted[e] = None

    def pin(self, e: int, n: int = 1) -> None:
        """Admitted rows hold their expert resident until harvested."""
        with self._lock:
            c = self.catalog[e]
            if c.state != "resident":
                raise ValueError(f"pin of non-resident expert {c.name!r}")
            c.pins += n

    def unpin(self, e: int, n: int = 1) -> None:
        with self._lock:
            c = self.catalog[e]
            if c.pins < n:
                raise ValueError(f"unpin below zero for expert {c.name!r}")
            c.pins -= n

    # -- lifecycle driver ------------------------------------------------
    def service(self, *, block: bool = False) -> int:
        """One lifecycle round: surface staging failures, commit staged
        wanted experts into slots, kick prefetch for the rest. Returns
        commits made. ``block=True`` (nothing on device to overlap
        with) waits on ``_cv`` for staging progress instead of
        busy-spinning; the wait releases the lock, and a worker that
        makes no progress within ``stage_timeout`` fails fast instead
        of hanging the server. A recorded staging failure re-raises the
        original exception here, on the scheduler thread — loudly, but
        with the entry already reset to cold (retryable) by the worker.
        """
        committed = 0
        try:
            with self._lock:
                self._tick += 1
                self._raise_stage_failure_locked()
                committed = self._commit_ready_locked()
                sync_jobs = self._kick_staging_locked()
            # prefetch=False staging runs inline, through the exact
            # code path the worker uses — and, like the worker, with
            # no lock held across the checkpoint read (R003)
            for job in sync_jobs:
                self._stage_one(job)
            if sync_jobs or (block and not committed):
                with self._lock:
                    if (block and not committed and not sync_jobs
                            and self._wanted and self._staging
                            and not self._stage_errors):
                        if not self._cv.wait_for(
                                self._progress_locked,
                                timeout=self.stage_timeout):
                            raise RuntimeError(
                                "hub staging made no progress in "
                                f"{self.stage_timeout}s — worker "
                                "wedged? (see faulthandler dump)")
                    self._raise_stage_failure_locked()
                    committed += self._commit_ready_locked()
        finally:
            # the host-cache trim runs on EVERY exit, including the
            # staging-failure re-raise: skipping it there let staged
            # host copies outlive the host_cache cap for as long as a
            # flaky cold tier kept raising (rule L005's unpaired-exit
            # shape, found by the repro.analysis lifecycle review)
            with self._lock:
                self._trim_host_locked()
        return committed

    def _progress_locked(self) -> bool:
        """service(block=True)'s wake predicate: a failure to surface,
        a wanted expert staged and ready to commit, or nothing left in
        flight."""
        return (bool(self._stage_errors) or not self._staging
                or any(self.catalog[e].state == "staged"
                       for e in self._wanted))

    def _raise_stage_failure_locked(self) -> None:
        """Re-raise the oldest recorded staging failure (one per
        service round: traffic keeps flowing between raises). The
        worker already reset the entry to cold and dropped its want."""
        if self._stage_errors:
            _, exc = self._stage_errors.pop(0)
            raise exc

    def _trim_host_locked(self) -> None:
        """Enforce ``host_cache``: drop the host params of the least
        popular (then least recent) staged, unwanted, store-backed
        entries beyond the cap — they return to ``cold`` and re-stage
        from the checkpoint tier on their next want. Entries without a
        store are never dropped (their params are the only copy)."""
        if self.host_cache is None:
            return
        held = [e for e, c in enumerate(self.catalog)
                if c.state == "staged" and c.on_disk
                and e not in self._wanted]
        drop = len(held) - self.host_cache
        if drop <= 0:
            return
        held.sort(key=lambda e: (self.popularity[e],
                                 self.catalog[e].last_used))
        for e in held[:drop]:
            c = self.catalog[e]
            c.params = None
            c.state = "cold"

    def _commit_ready_locked(self) -> int:
        n = 0
        for e in list(self._wanted):
            c = self.catalog[e]
            if c.state == "resident":     # raced: wanted twice
                self._wanted.pop(e, None)
                continue
            if c.params is None:
                continue                  # still cold/staging
            slot = self._grab_slot_locked()
            if slot is None:
                break                     # every slot pinned: decode on
            self._commit_locked(e, slot)
            self._wanted.pop(e, None)
            n += 1
        return n

    def _kick_staging_locked(self) -> List[Tuple[int, str, str]]:
        """Queue a staging job for every wanted cold expert. With
        prefetch the jobs go to the worker over ``_stage_q`` (spawning
        it on first use); without, they are returned for the caller to
        run inline *after releasing the lock* — checkpoint reads never
        happen under ``_lock`` either way (R003)."""
        sync_jobs: List[Tuple[int, str, str]] = []
        for e in self._wanted:
            c = self.catalog[e]
            if c.state != "cold" or e in self._staging:
                continue
            c.state = "staging"
            self._staging[e] = None
            self.stats.stage_attempts += 1
            job = (e, c.name, c.store)
            if self.prefetch:
                self._ensure_worker_locked()
                self._stage_q.put(job)
            else:
                sync_jobs.append(job)
        return sync_jobs

    def _ensure_worker_locked(self) -> None:
        if self._stage_thread is not None:
            return
        if self._closed:
            raise RuntimeError("ExpertHub is closed: no staging worker")
        t = self._thread_factory(target=self._stage_loop,
                                 name="hub-stage", daemon=True)
        t.start()
        self._stage_thread = t

    # -- staging worker --------------------------------------------------
    def _stage_loop(self) -> None:
        """Staging-worker thread entry point (THREAD_CONTRACT thread
        ``stager``). Jobs arrive by queue handoff — the worker never
        reads the catalog to find its work — and ``None`` is the
        shutdown sentinel ``close()`` sends."""
        while True:
            job = self._stage_q.get()
            if job is None:
                break
            self._stage_one(job)

    def _stage_one(self, job: Tuple[int, str, str]) -> None:
        """Stage one expert: cold checkpoint → host numpy, then publish
        under the hub lock. Runs on the worker thread (prefetch) or
        inline on the scheduler thread (prefetch=False) — identical
        protocol either way: the blocking read holds no lock, and both
        the success publication and the failure reset are lock-guarded
        state transitions (the pre-gate code reset failed entries to
        cold with no lock at all — rule R001's finding)."""
        e, name, store = job
        # one clock-read pair: the span's measurement IS the HubStats
        # stage latency (sp.ms is taken even with tracing disabled, so
        # the counters never go dark). The span closes — with an error
        # attribute — before the failure path runs, so span balance
        # survives a flaky cold tier.
        sp = self._tracer.span("hub.stage", expert=e, expert_name=name)
        try:
            with sp:
                params = ckpt_io.load_expert(store, name,
                                             like=self._host_like)
        except Exception as exc:
            with self._lock:
                self._stage_fail_locked(e, exc)
                self._cv.notify_all()
            return
        with self._lock:
            self._stage_publish_locked(e, params, sp.ms)
            self._cv.notify_all()

    def _stage_publish_locked(self, e: int, params: Any,
                              ms: float) -> None:
        c = self.catalog[e]
        self._staging.pop(e, None)
        c.params = params             # payload before the publish (R004)
        c.state = "staged"
        self.stats.stage_count += 1
        self.stats.stage_ms += ms
        c.stage_ms += ms

    def _stage_fail_locked(self, e: int,
                           exc: BaseException) -> None:
        """Failure is loud but retryable: the entry returns to cold
        (not wedged in 'staging' forever with its rows parked), the
        want drops so other experts' traffic keeps flowing, and the
        exception is queued for service() to re-raise on the scheduler
        thread."""
        c = self.catalog[e]
        self._staging.pop(e, None)
        c.params = None
        c.state = "cold"
        self._wanted.pop(e, None)
        self.stats.stage_failures += 1
        self._stage_errors.append((e, exc))

    # -- shutdown --------------------------------------------------------
    def close(self, timeout: float = 10.0) -> None:
        """Join the staging worker (idempotent). Sends the queue
        sentinel, then joins with ``timeout`` — a worker that fails to
        exit raises instead of leaking a thread silently. After close
        the hub serves residents fine but can no longer stage cold
        experts."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            t, self._stage_thread = self._stage_thread, None
        if t is not None:
            self._stage_q.put(None)
            t.join(timeout)
            if t.is_alive():
                raise RuntimeError(
                    f"hub staging worker did not exit within {timeout}s")

    def __enter__(self) -> "ExpertHub":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- slot management (scheduler thread, under the hub lock) ----------
    def _slot_in_wave_locked(self, slot: int) -> bool:
        """Whether any active wave still carries rows for ``slot``.

        Pins alone are not enough to gate eviction: a row's pin drops
        the moment it is harvested, but its KV pages (paged layout) are
        only released when its *whole wave* retires — so an expert can
        be pin-free while a mixed-``max_new`` wave still holds its
        pages. The wave's row map is the source of truth.
        """
        return any(w.uids.get(slot) for w in self.bank.core._active)

    def _grab_slot_locked(self) -> Optional[int]:
        for s, owner in enumerate(self._slot_expert):
            if owner is None:
                return s
        victims = [e for e in self._slot_expert
                   if e is not None and self.catalog[e].pins == 0
                   and not self._slot_in_wave_locked(self.catalog[e].slot)]
        if not victims:
            return None
        # popularity-weighted LRU: fewest router hits first, oldest
        # last-use breaking ties — a hot expert outlives cold ones.
        # The ranking reads the shared popularity Counter, which is
        # why it must run under the hub lock (R001: submit/route feed
        # the very same Counter)
        victim = min(victims, key=lambda e: (self.popularity[e],
                                             self.catalog[e].last_used))
        return self._evict_locked(victim)

    def _evict_locked(self, e: int) -> int:
        c = self.catalog[e]
        slot = c.slot
        core = self.bank.core
        if core.kv_layout == "paged":
            # the slot's cached prefixes describe the OLD expert's KV;
            # drop them, then prove no live pages survive the eviction
            core.prefix_cache.invalidate(slot)
            used = core.pool.used_count(slot)
            if used:
                raise RuntimeError(
                    f"evicting {c.name!r} from slot {slot} with {used} "
                    "live page(s) — pin accounting broke")
        c.state = "staged"                # host copy retained: reloads
        c.slot = -1                       # skip the cold tier entirely
        #                                   (bounded by host_cache)
        c.resident_s += self._tracer.now() - c.resident_since
        self._slot_expert[slot] = None
        self.stats.evictions += 1
        return slot

    def _commit_locked(self, e: int, slot: int) -> None:
        """Host-staged params → device bank slot: one jitted donated
        per-slot scatter into the stacked params. Executables are keyed
        on the bank's (E, ...) shape only, so this never invalidates
        the prefill/decode jit caches — the no-recompile property the
        bench asserts. Publication order is payload-first (R004): the
        slot is recorded before ``state`` flips to resident, so no
        reader can see a resident entry with ``slot == -1``."""
        c = self.catalog[e]
        core = self.bank.core
        # enqueue_span, deliberately: the install scatter is async
        # dispatch and commit latency is *defined* as enqueue cost (the
        # device work completes under the wave's harvest sync) — the
        # O002 gate exempts enqueue_span by name for exactly this case.
        # One clock-read pair feeds both the span and HubStats.
        with self._tracer.enqueue_span("hub.commit", expert=e,
                                       slot=slot) as sp:
            if self._install is None:
                s = core._bank_sharding()
                def fn(bank, new, at):
                    return jax.tree_util.tree_map(
                        lambda a, b: a.at[at].set(b), bank, new)
                if s is not None:
                    self._install = jax.jit(fn, donate_argnums=(0,),
                                            out_shardings=s)
                else:
                    self._install = jax.jit(fn, donate_argnums=(0,))
            core.params = self._install(core.params, c.params,
                                        jnp.asarray(slot, jnp.int32))
        self.stats.commit_ms += sp.ms
        self.stats.commit_count += 1
        self.stats.loads += 1
        c.commit_ms += sp.ms
        c.slot = slot
        c.last_used = self._tick
        c.state = "resident"
        c.resident_since = self._tracer.now()
        self._slot_expert[slot] = e

    # -- warmup ----------------------------------------------------------
    def warmup(self, max_batch: Optional[int] = None,
               commit: bool = True) -> None:
        """Compile the bank's whole executable ladder up front.

        The steady-state contract the bench asserts — *zero new
        executables after warmup, no matter which experts rotate
        through the slots* — only holds if every (batch bucket, len
        bucket) shape traffic can produce exists before measurement
        starts. Admits one throwaway wave per ladder point (tuple uids:
        the scheduler's orphan path discards any stragglers) and, with
        ``commit=True``, faults the first ``n_slots`` catalog experts
        into their slots so the install scatter is compiled too.
        Warmup compute runs on whatever params the slots hold — shapes
        are expert-agnostic, which is the very property that makes slot
        swapping recompile-free.
        """
        from .core import bucket_for
        bank = self.bank
        cap = bucket_for(min(max_batch or bank.batch_buckets[-1],
                             bank.batch_buckets[-1]),
                         bank.batch_buckets)
        rng = np.random.default_rng(0)
        for Sb in bank.len_buckets:
            for Bb in bank.batch_buckets:
                if Bb > cap:
                    break
                uids = [("__warmup__", Sb, Bb, i) for i in range(Bb)]
                prompts = [rng.integers(0, 100, size=Sb)
                           for _ in range(Bb)]
                bank.admit({0: (uids, prompts, [2] * Bb)})
                while bank.n_active:
                    bank.tick()
                bank.poll()
        if commit:
            for e in range(min(self.n_slots, len(self))):
                self.want(e)
            while self.has_wanted:
                if not self.service(block=True):
                    break

    # -- bookkeeping -----------------------------------------------------
    def metrics_snapshot(self) -> Dict[str, Any]:
        """The hub's node in the unified metrics tree: the HubStats
        counters plus a per-expert breakdown (router hits, lifecycle
        state, pins, admission misses, cumulative stage/commit latency,
        resident wall time — with the live tail for currently-resident
        experts). This is the feature vector a future residency
        rebalancer would rank on. One lock hold, pure Python."""
        now = self._tracer.now()
        with self._lock:
            experts: Dict[str, Any] = {}
            for e, c in enumerate(self.catalog):
                live = (now - c.resident_since
                        if c.state == "resident" else 0.0)
                experts[c.name] = {
                    "hits": int(self.popularity[e]),
                    "state": c.state,
                    "pins": c.pins,
                    "misses": c.misses,
                    "stage_ms": c.stage_ms,
                    "commit_ms": c.commit_ms,
                    "resident_s": c.resident_s + live,
                }
            return {**self.stats.as_dict(),
                    "slots": self.n_slots,
                    "experts": experts}

    def check(self) -> None:
        """Invariant sweep (tests, the sanitizer, and the scheduler's
        ``--check-invariants`` mode): slot maps and catalog agree, pins
        only on residents, wanted entries never resident, and the
        HubStats conservation laws hold — every load is a commit, and
        every stage attempt is published, failed, or still in flight."""
        with self._lock:
            for s, e in enumerate(self._slot_expert):
                if e is not None:
                    c = self.catalog[e]
                    assert c.state == "resident" and c.slot == s, (s, c)
            for e, c in enumerate(self.catalog):
                if c.state == "resident":
                    assert self._slot_expert[c.slot] == e, (e, c)
                else:
                    assert c.slot == -1, (e, c)
                    assert c.pins == 0, \
                        f"pins on non-resident {c.name!r}"
                if c.state in ("staged", "resident"):
                    assert c.params is not None, \
                        f"{c.state} entry {c.name!r} published no params"
            assert all(self.catalog[e].state != "resident"
                       for e in self._wanted)
            st = self.stats
            assert st.loads == st.commit_count, \
                f"loads {st.loads} != commits {st.commit_count}"
            in_flight = len(self._staging)
            assert st.stage_attempts == (st.stage_count
                                         + st.stage_failures
                                         + in_flight), (
                f"stage conservation broke: {st.stage_attempts} "
                f"attempts vs {st.stage_count} published + "
                f"{st.stage_failures} failed + {in_flight} in flight")

    @property
    def install_compiles(self) -> int:
        """Real executables behind the slot-install wrapper (0 or 1 —
        counted into the bench's steady-state recompile assert)."""
        from .core import _wrapper_compiles
        return 0 if self._install is None else \
            _wrapper_compiles(self._install)
