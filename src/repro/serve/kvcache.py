"""Paged KV cache bookkeeping: page pool allocator + shared-prefix cache.

The ring-cache engines allocate one dense ``(L, B, C, KV, dh)`` KV
buffer per admitted wave, so two requests carrying the same prompt pay
for (and prefill) the same keys twice — exactly the waste the paper's
setting produces, where cohorts of clients in one region hit the server
with near-identical prompts. The paged layout replaces the per-wave
buffer with one per-shard pool of fixed-size *pages* on an
``(E, n_pages, ...)`` device buffer; each row owns a page table mapping
its logical cache slots to physical pages, and pages are refcounted so
prefix-sharing rows point at the *same* physical pages.

This module is the pure host-side bookkeeping half (no jax): the
allocator and the prefix index. The device half — the pooled buffers
and the gather/scatter through page tables — lives in
``models.attention`` (cache protocol) and ``serve.core`` (wave
machinery). Keeping the allocator free of device state makes the
refcount / free-list invariants property-testable in isolation
(``tests/test_paged_kv.py``).

Threading ownership: every structure here — ``PagePool.refs``, the
``_free`` stacks, the ``PrefixCache`` LRU — is **single-writer,
scheduler thread only** (``THREAD_CONTRACT["single_writer"]`` in
``serve/hub.py``; the hub's staging worker never reaches this module).
None of it is locked, and ``repro.analysis races`` proves statically
that no other thread can observe it.

Layout contract (shared with ``EngineCore``):

  * every length bucket (and ``max_len``) is a multiple of
    ``page_size``, so prefills always fill whole pages and decode
    appends never straddle a shared partial page;
  * physical page ``n_pages`` (one past the pool) is the *trash page*:
    rows scatter into it when their compute is discarded (padding rows,
    deduplicated rows) and logical slots that are never written map to
    it. It is never allocated and never read unmasked.
"""
from __future__ import annotations

import collections
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class PagePoolExhausted(RuntimeError):
    """Raised when an admission needs more free pages than the pool
    holds (after prefix-cache eviction). The scheduler treats this as
    backpressure: the rows go back to their queues and are re-admitted
    once resident waves retire and free their pages."""


def hash_chain(tokens: np.ndarray, page: int) -> List[bytes]:
    """Cumulative page-granular prefix fingerprints.

    ``chain[j]`` identifies the *entire* token prefix through page ``j``
    (tokens ``0 .. (j+1)*page - 1``): each digest folds in the previous
    one, so two rows share ``chain[j]`` iff they share the whole
    prefix, not just the j-th page. Causal attention makes the KV
    content of page ``j`` a pure function of exactly that prefix, which
    is what lets rows with equal digests share physical pages.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    out: List[bytes] = []
    prev = b""
    for j in range(len(toks) // page):
        h = hashlib.blake2b(digest_size=16)
        h.update(prev)
        h.update(toks[j * page:(j + 1) * page].tobytes())
        prev = h.digest()
        out.append(prev)
    return out


class PagePool:
    """Refcounted free-list allocator for ``n_experts`` independent
    per-expert page pools (the device buffer is ``(E, n_pages, ...)``;
    expert ``e`` may only hold pages from its own row of the buffer).

    Allocation is transactional: ``alloc`` either returns all ``n``
    requested pages or raises ``PagePoolExhausted`` having changed
    nothing — a failed admission can never leak pages or touch another
    row's mappings.
    """

    def __init__(self, n_experts: int, n_pages: int, page_size: int):
        if n_experts < 1 or n_pages < 1 or page_size < 1:
            raise ValueError(
                f"PagePool needs positive sizes, got E={n_experts}, "
                f"n_pages={n_pages}, page_size={page_size}")
        self.n_experts = n_experts
        self.n_pages = n_pages
        self.page = page_size
        self.refs = np.zeros((n_experts, n_pages), np.int32)
        # LIFO free stacks: recently-freed pages are reused first, which
        # keeps the hot working set small in the device buffer
        self._free: List[List[int]] = [
            list(range(n_pages - 1, -1, -1)) for _ in range(n_experts)]
        # cumulative traffic counters (obs registry): pages handed out /
        # returned over the pool's lifetime, and how many transactional
        # allocs bounced with PagePoolExhausted (the backpressure rate)
        self.page_allocs = 0
        self.page_releases = 0
        self.exhausted = 0

    @property
    def trash(self) -> int:
        """Physical index of the write-discard page (one past the pool)."""
        return self.n_pages

    def free_count(self, e: int) -> int:
        return len(self._free[e])

    def used_count(self, e: int) -> int:
        return self.n_pages - len(self._free[e])

    def counters(self) -> Dict[str, int]:
        """Pool-wide page totals: the live {free, used} conservation
        pair the scheduler's ``--check-invariants`` mode samples (free +
        used == E * n_pages always; ``check()`` proves the per-page
        books). Equality of two ``counters()`` snapshots means "no net
        page movement" — the transactional-rollback tests rely on it,
        so the monotonic traffic counters live in :meth:`telemetry`."""
        free = sum(len(f) for f in self._free)
        return {"free": free,
                "used": self.n_experts * self.n_pages - free}

    def telemetry(self) -> Dict[str, int]:
        """The obs-registry view: the live conservation pair plus the
        cumulative alloc/release traffic and how many transactional
        allocs bounced with ``PagePoolExhausted`` (the backpressure
        rate)."""
        return {**self.counters(),
                "page_allocs": self.page_allocs,
                "page_releases": self.page_releases,
                "exhausted": self.exhausted}

    def alloc(self, e: int, n: int) -> List[int]:
        """Take ``n`` pages for expert ``e`` (each at refcount 1), or
        raise ``PagePoolExhausted`` without side effects."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        free = self._free[e]
        if n > len(free):
            self.exhausted += 1
            raise PagePoolExhausted(
                f"expert {e}: need {n} pages, {len(free)} free of "
                f"{self.n_pages}")
        out = [free.pop() for _ in range(n)]
        self.refs[e, out] = 1
        self.page_allocs += n
        return out

    def retain(self, e: int, pages: Sequence[int]) -> None:
        """Add one reference to each page (prefix sharing / cache pin)."""
        for p in pages:
            if self.refs[e, p] <= 0:
                raise ValueError(f"retain of free page {p} (expert {e})")
            self.refs[e, p] += 1

    def release(self, e: int, pages: Sequence[int]) -> None:
        """Drop one reference per page; pages hitting zero return to the
        free list. Releasing a free page is an error (double free)."""
        for p in pages:
            if self.refs[e, p] <= 0:
                raise ValueError(f"double free of page {p} (expert {e})")
            self.refs[e, p] -= 1
            if self.refs[e, p] == 0:
                self._free[e].append(p)
                self.page_releases += 1

    def shared(self, e: int, page: int) -> bool:
        """True when more than one owner references the page — a row
        about to overwrite it must copy-on-write first."""
        return bool(self.refs[e, page] > 1)

    def check(self) -> None:
        """Invariant sweep (used by the property tests): every page is
        either on the free list with refcount 0 or off it with a
        positive refcount, exactly once."""
        for e in range(self.n_experts):
            free = self._free[e]
            if len(set(free)) != len(free):
                raise AssertionError(f"expert {e}: duplicate free pages")
            for p in free:
                if self.refs[e, p] != 0:
                    raise AssertionError(
                        f"expert {e}: page {p} free with refcount "
                        f"{self.refs[e, p]}")
            n_used = int((self.refs[e] > 0).sum())
            if n_used + len(free) != self.n_pages:
                raise AssertionError(
                    f"expert {e}: {n_used} used + {len(free)} free != "
                    f"{self.n_pages}")


class PrefixCache:
    """Shared-prefix index over pool pages, LRU-bounded.

    Two entry kinds, one LRU:

      * page entries ``(e, chain[j]) -> physical page`` — each holds one
        pool reference. A new row walks its own hash chain and *adopts*
        every leading page it finds (longest cached prefix), sharing
        storage with whichever row computed it first.
      * full-prompt entries ``(e, Sb, chain[-1]) -> first sampled
        token`` — when every page of a padded prompt is cached *and*
        the greedy first token is known, admission can skip the row's
        prefill compute entirely.

    Entries are inserted at harvest time (when the first token plane is
    already host-side, so registration never forces a device sync) and
    evicted LRU-first when the pool runs dry. Eviction releases the
    entry's pool reference; the page itself is freed only once live
    rows drop theirs too.
    """

    def __init__(self, pool: PagePool, capacity: int = 1024):
        self.pool = pool
        self.capacity = capacity
        self._lru: "collections.OrderedDict[tuple, int]" = \
            collections.OrderedDict()
        self.stats = {"inserts": 0, "page_hits": 0, "full_hits": 0,
                      "evictions": 0}

    def __len__(self) -> int:
        return len(self._lru)

    # -- lookup ----------------------------------------------------------
    def adopt_prefix(self, e: int, chain: Sequence[bytes]) -> List[int]:
        """Longest cached prefix of ``chain``: returns the physical
        pages (pool references already added for the caller, who owns
        them from here on)."""
        pages: List[int] = []
        for h in chain:
            got = self._lru.get(("pg", e, h))
            if got is None:
                break
            pages.append(got)
            self._lru.move_to_end(("pg", e, h))
        if pages:
            self.pool.retain(e, pages)
            self.stats["page_hits"] += len(pages)
        return pages

    def first_token(self, e: int, padded_len: int,
                    chain: Sequence[bytes]) -> Optional[int]:
        """The greedy first token for a fully-cached padded prompt, or
        None when unknown (row must be prefilled)."""
        if not chain:
            return None
        key = ("tok", e, padded_len, chain[-1])
        got = self._lru.get(key)
        if got is not None:
            self._lru.move_to_end(key)
            self.stats["full_hits"] += 1
        return got

    # -- insert / evict --------------------------------------------------
    def insert(self, e: int, padded_len: int, chain: Sequence[bytes],
               pages: Sequence[int], first_token: Optional[int]) -> None:
        """Register a computed row's prefix pages (one pool reference
        per newly-indexed page) and, when the whole padded prompt is
        covered, its greedy first token."""
        assert len(pages) == len(chain)
        for h, p in zip(chain, pages):
            key = ("pg", e, h)
            if key in self._lru:
                self._lru.move_to_end(key)
                continue
            self.pool.retain(e, [p])
            self._lru[key] = p
            self.stats["inserts"] += 1
        if first_token is not None and chain:
            self._lru[("tok", e, padded_len, chain[-1])] = int(first_token)
        self._trim(self.capacity)

    def _drop(self, key: tuple) -> None:
        val = self._lru.pop(key)
        if key[0] == "pg":
            self.pool.release(key[1], [val])
        self.stats["evictions"] += 1

    def _trim(self, limit: int) -> None:
        while len(self._lru) > limit:
            self._drop(next(iter(self._lru)))

    def evict_for(self, e: int, need: int) -> None:
        """Drop LRU entries of expert ``e`` until its pool has ``need``
        free pages or nothing evictable remains. Dropping an entry only
        *releases* its reference; pages still pinned by live rows free
        up when those waves retire."""
        if self.pool.free_count(e) >= need:
            return
        for key in [k for k in self._lru if k[1] == e]:
            self._drop(key)
            if self.pool.free_count(e) >= need:
                return

    def invalidate(self, e: int) -> None:
        """Drop every entry of expert ``e`` — its slot is being recycled
        for a different expert (hub eviction), so its cached prefixes
        describe KV content that is about to be overwritten."""
        for key in [k for k in self._lru if k[1] == e]:
            self._drop(key)

    def clear(self) -> None:
        self._trim(0)
