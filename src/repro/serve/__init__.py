"""Serving subsystem: router -> scheduler -> expert shards.

``RoutedServer`` keeps the seed one-shot API (``serve(requests)``);
``Scheduler.submit``/``step`` expose the continuous-batching path.

The moving parts, front to back:

  * ``Router`` — ExpertMatcher scoring with bounded jit shapes, a
    client-fingerprint LRU, and ``PrefixLRU``, the same idiom applied
    to prompt pages so cohorts sending near-identical prompts are
    detected at submission.
  * ``Scheduler`` — per-expert admission queues with length-bucketed
    continuous micro-batching; on paged shards, prefix-sharing rows are
    co-admitted into one wave and page-pool exhaustion requeues rows as
    clean backpressure.
  * ``plan_placement`` + ``BankedEngine`` — homogeneous experts banked
    onto a mesh ``expert`` axis: one vmapped/GSPMD-sharded dispatch
    serves every co-located expert.
  * ``EngineCore`` — the one residency/bucketing/harvest implementation
    behind both engine shims. Its KV cache has two layouts: ``ring``
    (dense per-wave buffers, the reference) and ``paged`` (a per-shard
    ``PagePool`` of fixed-size pages with per-row page tables,
    refcounted prefix sharing, copy-on-write, and prefill deduplication
    — see ``kvcache``).
  * speculative decoding (``draft``) — a cheap draft model proposes k
    tokens per wave per tick and the target expert verifies the whole
    window in ONE batched dispatch (``EngineCore._verify_fn``); greedy
    verification makes the emitted tokens bitwise identical to the
    one-by-one path while active rows advance 1..k+1 tokens per tick.
  * ``ExpertHub`` — checkpoint-backed dynamic expert lifecycle: an
    unbounded catalog (cold checkpoint store → host-staged params →
    device bank slot), refcounted residency with popularity-weighted
    LRU eviction fed by router hit counts, asynchronous prefetch, and
    ``NotResident`` admission backpressure — the expert population is
    no longer capped by device memory.
  * ``DispatchExecutor`` (``serial`` / ``overlapped``) — whether a
    scheduler step blocks per decode tick or enqueues all shards' work
    and harvests with one batched transfer per wave.

See README.md in this directory and ``docs/architecture.md`` for the
design and the paper mapping.
"""
from .core import (DispatchExecutor, EngineCore, EngineStats,
                   OverlappedExecutor, SerialExecutor, bucket_for,
                   get_executor, make_buckets)
from .draft import (AlwaysWrongDraft, BigramTableDraft, DraftModel,
                    MLPBaselineDraft, build_draft)
from .engine import ExpertEngine
from .hub import (CatalogEntry, ExpertHub, HubMember, HubStats,
                  NotResident)
from .kvcache import (PagePool, PagePoolExhausted, PrefixCache,
                      hash_chain)
from .placement import (BankMember, BankedEngine, PlacementPlan, Shard,
                        plan_placement)
from .router import PrefixLRU, Router, RouteResult
from .scheduler import (Request, Response, RoutedServer, Scheduler,
                        SchedulerConfig, SchedulerStats)

__all__ = [
    "EngineCore", "ExpertEngine", "EngineStats", "bucket_for",
    "make_buckets",
    "DispatchExecutor", "SerialExecutor", "OverlappedExecutor",
    "get_executor",
    "DraftModel", "MLPBaselineDraft", "BigramTableDraft",
    "AlwaysWrongDraft", "build_draft",
    "CatalogEntry", "ExpertHub", "HubMember", "HubStats", "NotResident",
    "PagePool", "PagePoolExhausted", "PrefixCache", "hash_chain",
    "BankedEngine", "BankMember", "PlacementPlan", "Shard",
    "plan_placement",
    "PrefixLRU", "Router", "RouteResult",
    "Request", "Response", "RoutedServer", "Scheduler", "SchedulerConfig",
    "SchedulerStats",
]
