from .engine import ExpertEngine, Request, Response, RoutedServer

__all__ = ["ExpertEngine", "Request", "Response", "RoutedServer"]
