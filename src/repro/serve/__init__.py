"""Serving subsystem: router -> scheduler -> expert shards.

``RoutedServer`` keeps the seed one-shot API (``serve(requests)``);
``Scheduler.submit``/``step`` expose the continuous-batching path.
``plan_placement`` + ``BankedEngine`` map homogeneous experts onto a
mesh ``expert`` axis so one dispatch serves every co-located expert.
``EngineCore`` is the shared residency/bucketing/harvest machinery both
engine shims delegate to; the ``DispatchExecutor`` seam (``serial`` /
``overlapped``) decides whether a scheduler step blocks per decode tick
or enqueues all shards' work and harvests with one batched transfer per
wave. See README.md in this directory for the design.
"""
from .core import (DispatchExecutor, EngineCore, EngineStats,
                   OverlappedExecutor, SerialExecutor, bucket_for,
                   get_executor, make_buckets)
from .engine import ExpertEngine
from .placement import (BankMember, BankedEngine, PlacementPlan, Shard,
                        plan_placement)
from .router import Router, RouteResult
from .scheduler import (Request, Response, RoutedServer, Scheduler,
                        SchedulerConfig)

__all__ = [
    "EngineCore", "ExpertEngine", "EngineStats", "bucket_for",
    "make_buckets",
    "DispatchExecutor", "SerialExecutor", "OverlappedExecutor",
    "get_executor",
    "BankedEngine", "BankMember", "PlacementPlan", "Shard",
    "plan_placement",
    "Router", "RouteResult",
    "Request", "Response", "RoutedServer", "Scheduler", "SchedulerConfig",
]
