"""Serving subsystem: router -> scheduler -> expert shards.

``RoutedServer`` keeps the seed one-shot API (``serve(requests)``);
``Scheduler.submit``/``step`` expose the continuous-batching path.
``plan_placement`` + ``BankedEngine`` map homogeneous experts onto a
mesh ``expert`` axis so one dispatch serves every co-located expert.
See README.md in this directory for the design.
"""
from .engine import EngineStats, ExpertEngine, bucket_for, make_buckets
from .placement import (BankMember, BankedEngine, PlacementPlan, Shard,
                        plan_placement)
from .router import Router, RouteResult
from .scheduler import (Request, Response, RoutedServer, Scheduler,
                        SchedulerConfig)

__all__ = [
    "ExpertEngine", "EngineStats", "bucket_for", "make_buckets",
    "BankedEngine", "BankMember", "PlacementPlan", "Shard",
    "plan_placement",
    "Router", "RouteResult",
    "Request", "Response", "RoutedServer", "Scheduler", "SchedulerConfig",
]
