"""Serving subsystem: router -> scheduler -> per-expert engines.

``RoutedServer`` keeps the seed one-shot API (``serve(requests)``);
``Scheduler.submit``/``step`` expose the continuous-batching path. See
README.md in this directory for the design.
"""
from .engine import EngineStats, ExpertEngine, bucket_for, make_buckets
from .router import Router, RouteResult
from .scheduler import (Request, Response, RoutedServer, Scheduler,
                        SchedulerConfig)

__all__ = [
    "ExpertEngine", "EngineStats", "bucket_for", "make_buckets",
    "Router", "RouteResult",
    "Request", "Response", "RoutedServer", "Scheduler", "SchedulerConfig",
]
