"""Sharded expert placement: banked multi-expert engines on a mesh.

PR 1's serving stack instantiates one independent ``ExpertEngine`` per
expert on a single implicit device: K experts mean K separate jit
caches (K x ``len(batch_buckets) * len(len_buckets)`` executables), K
serial prefill dispatches per scheduler step, and no use of the mesh
machinery at all. This module makes placement first-class:

  * ``plan_placement`` walks an ``ExpertRegistry``, groups *homogeneous*
    experts (same architecture config and bucket ladders) and rebinds
    each group to one ``BankedEngine``; heterogeneous or legacy backends
    keep their own singleton shard. The result is a ``PlacementPlan``
    the scheduler and router consume (shard ids ride through
    ``RouteResult`` / ``Response``).
  * ``BankedEngine`` stacks the params of its member experts along a
    leading ``expert`` axis and serves *every* member's micro-batch with
    a single jitted dispatch: ``vmap`` over the expert axis, optionally
    partitioned across devices by GSPMD via a 1-D ``expert`` mesh
    (``launch.mesh.make_expert_mesh``). Because the bank reuses one
    bucket ladder, the executable count is bounded at
    ``len(batch_buckets) * len(len_buckets)`` prefills +
    ``len(batch_buckets)`` decode steps *total* — not per expert.

On CPU the expert mesh is driven by a forced host device count
(``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before backend
init); on a TPU slice the same code places banks across real chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..sharding import leading_sharding
from .engine import EngineStats, ExpertEngine, bucket_for, make_buckets


# ---------------------------------------------------------------------------
# Banked engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _BankGroup:
    """One admitted (E, Bb) micro-batch wave resident in the bank."""
    uids: Dict[int, List[Any]]          # local expert -> row uids
    per_row_new: Dict[int, List[int]]
    done: Dict[int, List[bool]]
    cache: Any
    tok: jnp.ndarray                    # (E, Bb, 1) last emitted token
    emitted: List[np.ndarray]           # one (E, Bb) plane per step
    steps_left: int


class BankedEngine:
    """E homogeneous experts served by one vmapped/sharded dispatch.

    Params are stacked on a leading expert axis; prefill/decode are
    ``vmap`` over that axis, jitted once per (batch bucket, len bucket)
    for the *whole bank*. With ``mesh`` (1-D over ``"expert"``, size
    dividing ``n_experts``) the stacked params, caches and token planes
    are sharded over devices, so each device runs only its resident
    experts' slices of the single executable.
    """

    def __init__(self, model, params_list: Sequence[Any], *,
                 max_len: int = 256, min_len_bucket: int = 8,
                 len_buckets: Optional[Sequence[int]] = None,
                 batch_buckets: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None):
        if not params_list:
            raise ValueError("BankedEngine needs at least one expert")
        self.model = model
        self.n_experts = len(params_list)
        self.max_len = max_len
        self.len_buckets = tuple(len_buckets) if len_buckets else \
            make_buckets(min_len_bucket, max_len)
        self.batch_buckets = tuple(batch_buckets or make_buckets(1, 16))
        if mesh is not None and (
                "expert" not in mesh.shape
                or self.n_experts % mesh.shape["expert"]):
            raise ValueError(
                f"mesh expert axis {dict(mesh.shape)} must divide the "
                f"bank's {self.n_experts} experts")
        self.mesh = mesh if (mesh is not None
                             and mesh.shape.get("expert", 1) > 1) else None
        self.stats = EngineStats()
        self._active: List[_BankGroup] = []
        self._finished: List[Tuple[int, Any, np.ndarray]] = []
        self._prefill_fns: Dict[Tuple[int, int], Any] = {}
        self._decode_fns: Dict[int, Any] = {}
        params = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                        *params_list)
        if self.mesh is not None:
            sh = leading_sharding(params, "expert", self.mesh)
            params = jax.device_put(params, sh)
        self.params = params

    # -- sharded/bucketed executables -----------------------------------
    def _bank_sharding(self):
        """Prefix sharding for any expert-leading pytree (or None)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P("expert"))

    def _prefill_fn(self, Bb: int, Sb: int):
        key = (Bb, Sb)
        if key not in self._prefill_fns:
            fn = jax.vmap(lambda p, b: self.model.prefill(
                p, b, capacity=self.max_len))
            s = self._bank_sharding()
            if s is not None:
                jitted = jax.jit(fn, in_shardings=(s, s),
                                 out_shardings=(s, s))
            else:
                jitted = jax.jit(fn)
            self._prefill_fns[key] = jitted
            self.stats.prefill_compiles += 1
        return self._prefill_fns[key]

    def _decode_fn(self, Bb: int):
        if Bb not in self._decode_fns:
            fn = jax.vmap(self.model.decode)
            s = self._bank_sharding()
            if s is not None:
                jitted = jax.jit(fn, in_shardings=(s, s, s),
                                 out_shardings=(s, s), donate_argnums=(1,))
            else:
                jitted = jax.jit(fn, donate_argnums=(1,))
            self._decode_fns[Bb] = jitted
            self.stats.decode_compiles += 1
        return self._decode_fns[Bb]

    # -- admission -------------------------------------------------------
    def pad_shape(self, n_rows: int, prompt_len: int) -> Tuple[int, int]:
        """(batch bucket, length bucket) this admission would snap to."""
        return (bucket_for(n_rows, self.batch_buckets),
                bucket_for(prompt_len, self.len_buckets))

    def admit(self, groups: Mapping[int, Tuple[Sequence[Any],
                                               Sequence[np.ndarray],
                                               Sequence[int]]]) -> None:
        """Prefill one (E, Bb, Sb) wave: every member expert's micro-batch
        in a single dispatch.

        ``groups`` maps local expert index -> (uids, prompts, max_new);
        experts without traffic this wave ride along as zero rows. Row
        padding follows ``ExpertEngine.admit``: prompts right-truncated
        to the largest length bucket, zero-padded to the common bucket.
        """
        rows_max, len_max = 0, 1
        for local, (uids, prompts, max_new) in groups.items():
            if not 0 <= local < self.n_experts:
                raise ValueError(f"local expert {local} out of range")
            if len(uids) != len(prompts) or len(uids) != len(max_new):
                raise ValueError("uids/prompts/max_new length mismatch")
            if len(prompts) > self.batch_buckets[-1]:
                raise ValueError(
                    f"micro-batch of {len(prompts)} rows exceeds the "
                    f"largest batch bucket {self.batch_buckets[-1]}")
            rows_max = max(rows_max, len(prompts))
            len_max = max(len_max, max((len(p) for p in prompts),
                                       default=1))
        if rows_max == 0:
            return
        groups = {l: g for l, g in groups.items() if g[0]}
        Bb = bucket_for(rows_max, self.batch_buckets)
        Sb = bucket_for(len_max, self.len_buckets)
        E = self.n_experts
        toks = np.zeros((E, Bb, Sb), np.int32)
        uids: Dict[int, List[Any]] = {}
        per_row: Dict[int, List[int]] = {}
        done: Dict[int, List[bool]] = {}
        n_rows = 0
        for local, (u, prompts, max_new) in groups.items():
            for i, p in enumerate(prompts):
                p = np.asarray(p, np.int32)[-Sb:]
                toks[local, i, :len(p)] = p
            uids[local] = list(u)
            per_row[local] = [max(1, int(m)) for m in max_new]
            done[local] = [False] * len(u)
            n_rows += len(u)
        logits, cache = self._prefill_fn(Bb, Sb)(
            self.params, {"tokens": jnp.asarray(toks)})
        self.stats.prefill_calls += 1
        self.stats.rows_served += n_rows
        self.stats.rows_padded += E * Bb - n_rows
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[..., None]
        g = _BankGroup(uids=uids, per_row_new=per_row, done=done,
                       cache=cache, tok=tok,
                       emitted=[np.asarray(tok)[..., 0]],
                       steps_left=max(m for ms in per_row.values()
                                      for m in ms) - 1)
        self._active.append(g)
        self._harvest(g)
        if g.steps_left <= 0 and self._retired(g):
            self._active.remove(g)

    # -- decoding --------------------------------------------------------
    def tick(self) -> int:
        """Advance every active wave one decode step — one dispatch per
        wave covers all member experts. Returns waves advanced."""
        advanced = 0
        for g in list(self._active):
            if g.steps_left > 0:
                Bb = g.tok.shape[1]
                logits, g.cache = self._decode_fn(Bb)(
                    self.params, g.cache, {"token": g.tok})
                g.tok = jnp.argmax(logits, axis=-1).astype(
                    jnp.int32)[..., None]
                g.emitted.append(np.asarray(g.tok)[..., 0])
                g.steps_left -= 1
                self.stats.decode_steps += 1
                advanced += 1
            self._harvest(g)
            if g.steps_left <= 0 and self._retired(g):
                self._active.remove(g)
        return advanced

    @staticmethod
    def _retired(g: _BankGroup) -> bool:
        """Every row harvested — same retirement rule as ExpertEngine
        (today implied by steps_left == 0, kept explicit so the banked
        and per-engine residency paths cannot silently diverge)."""
        return all(all(d) for d in g.done.values())

    def _harvest(self, g: _BankGroup) -> None:
        have = len(g.emitted)
        for local, row_uids in g.uids.items():
            for i, uid in enumerate(row_uids):
                if g.done[local][i] or g.per_row_new[local][i] > have:
                    continue
                seq = np.asarray(
                    [plane[local, i] for plane in
                     g.emitted[:g.per_row_new[local][i]]], np.int32)
                self._finished.append((local, uid, seq))
                self.stats.tokens_generated += len(seq)
                g.done[local][i] = True

    def poll(self) -> List[Tuple[int, Any, np.ndarray]]:
        """Drain finished (local expert, uid, tokens) triples."""
        out, self._finished = self._finished, []
        return out

    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def has_pending(self) -> bool:
        """Active waves or finished rows not yet polled."""
        return bool(self._active or self._finished)


@dataclasses.dataclass
class BankMember:
    """Registry-facing handle: one expert's slot inside a BankedEngine."""
    bank: BankedEngine
    local: int

    def pad_shape(self, n_rows: int, prompt_len: int) -> Tuple[int, int]:
        return self.bank.pad_shape(n_rows, prompt_len)

    @property
    def batch_buckets(self) -> Tuple[int, ...]:
        return self.bank.batch_buckets

    @property
    def stats(self) -> EngineStats:
        return self.bank.stats


# ---------------------------------------------------------------------------
# Placement planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Shard:
    """One dispatch group: either a bank of co-located experts or a
    singleton wrapping whatever backend the registry already had."""
    sid: int
    experts: Tuple[int, ...]            # global registry indices
    bank: Optional[BankedEngine] = None
    devices: Tuple[Any, ...] = ()

    @property
    def banked(self) -> bool:
        return self.bank is not None


@dataclasses.dataclass
class PlacementPlan:
    shards: List[Shard]
    shard_of: Dict[int, int]            # expert index -> shard id
    mesh: Optional[Mesh] = None

    def describe(self, names: Optional[Sequence[str]] = None) -> str:
        lines = []
        for s in self.shards:
            label = ", ".join(names[e] if names else str(e)
                              for e in s.experts)
            dev = (f" on {len(s.devices)} device(s)" if s.devices else "")
            kind = "bank" if s.banked else "solo"
            lines.append(f"shard {s.sid} [{kind}]{dev}: {label}")
        return "\n".join(lines)


def _bankable(engine: ExpertEngine) -> bool:
    """Banking is only sound for models whose per-row outputs don't
    depend on batch padding: capacity-dispatch MoE computes its expert
    capacity from the *total* (padded) token count and padding rows
    consume capacity slots, so padding one member's micro-batch to the
    wave-wide batch bucket could change a real row's tokens vs the
    per-engine path. Those experts keep singleton shards."""
    cfg = engine.model.cfg
    return not (cfg.n_experts and cfg.moe_impl == "dispatch")


def _bank_signature(engine: ExpertEngine):
    """Experts are bankable iff they share arch config (minus name) and
    bucket ladders — identical shapes, identical executables."""
    cfg = engine.model.cfg.replace(name="")
    return (cfg, engine.max_len, engine.len_buckets, engine.batch_buckets)


def _bank_submesh(n_experts: int, mesh: Optional[Mesh], offset: int = 0):
    """Largest-divisor slice of the expert mesh this bank can shard over.

    ``offset`` rotates the device pool so successive banks land on
    *disjoint* slices (wrapping once the pool is exhausted) instead of
    all piling onto the mesh's first devices.
    """
    if mesh is None or "expert" not in mesh.shape:
        return None, ()
    devs = np.roll(np.asarray(mesh.devices).reshape(-1),
                   -(offset % max(mesh.shape["expert"], 1)))
    for d in range(min(len(devs), n_experts), 0, -1):
        if n_experts % d == 0:
            if d == 1:
                return None, ()   # unsharded: params stay wherever jax
                #                   puts them, claim no device
            sub = Mesh(devs[:d], axis_names=("expert",))
            return sub, tuple(devs[:d])
    return None, ()


def plan_placement(registry, *, mesh: Optional[Mesh] = None,
                   min_bank: int = 2) -> PlacementPlan:
    """Group homogeneous ``ExpertEngine`` backends into ``BankedEngine``s
    and lay the shards out over ``mesh`` (1-D ``expert`` axis, see
    ``launch.mesh.make_expert_mesh``).

    Mutates ``registry`` in place: banked entries' backends become
    ``BankMember`` handles (the per-expert engines they replace are
    dropped, their params moving into the stacked bank). Groups smaller
    than ``min_bank`` and non-``ExpertEngine`` backends keep singleton
    shards. Returns the ``PlacementPlan`` the scheduler/router consume.
    """
    by_sig: Dict[Any, List[int]] = {}
    for e in range(len(registry)):
        backend = registry[e].backend
        if isinstance(backend, BankMember):
            raise ValueError(
                f"expert {registry[e].name!r} is already bank-placed; "
                "plan_placement rebinds backends in place and cannot "
                "re-plan a planned registry — rebuild it from engines")
        if isinstance(backend, ExpertEngine) and _bankable(backend):
            by_sig.setdefault(_bank_signature(backend), []).append(e)

    shards: List[Shard] = []
    shard_of: Dict[int, int] = {}
    cursor = 0                      # rotates banks onto disjoint devices
    for experts in by_sig.values():
        if len(experts) < min_bank:
            continue
        engines = [registry[e].backend for e in experts]
        submesh, devices = _bank_submesh(len(experts), mesh, cursor)
        cursor += len(devices)
        bank = BankedEngine(
            engines[0].model, [eng.params for eng in engines],
            max_len=engines[0].max_len,
            len_buckets=engines[0].len_buckets,
            batch_buckets=engines[0].batch_buckets, mesh=submesh)
        sid = len(shards)
        shards.append(Shard(sid=sid, experts=tuple(experts), bank=bank,
                            devices=devices))
        for local, e in enumerate(experts):
            registry[e].backend = BankMember(bank, local)
            shard_of[e] = sid
    for e in range(len(registry)):
        if e in shard_of:
            continue
        sid = len(shards)
        shards.append(Shard(sid=sid, experts=(e,)))
        shard_of[e] = sid
    return PlacementPlan(shards=shards, shard_of=shard_of, mesh=mesh)
