"""Sharded expert placement: banked multi-expert engines on a mesh.

PR 1's serving stack instantiates one independent ``ExpertEngine`` per
expert on a single implicit device: K experts mean K separate jit
caches (K x ``len(batch_buckets) * len(len_buckets)`` executables), K
serial prefill dispatches per scheduler step, and no use of the mesh
machinery at all. This module makes placement first-class:

  * ``plan_placement`` walks an ``ExpertRegistry``, groups *homogeneous*
    experts (same architecture config and bucket ladders) and rebinds
    each group to one ``BankedEngine``; heterogeneous or legacy backends
    keep their own singleton shard. The result is a ``PlacementPlan``
    the scheduler and router consume (shard ids ride through
    ``RouteResult`` / ``Response``).
  * ``BankedEngine`` is the E>1 view of the shared ``EngineCore``
    (``serve.core``): the params of its member experts are stacked
    along a leading ``expert`` axis and *every* member's micro-batch is
    served by a single jitted dispatch — ``vmap`` over the expert axis,
    optionally partitioned across devices by GSPMD via a 1-D ``expert``
    mesh (``launch.mesh.make_expert_mesh``). Because the bank reuses one
    bucket ladder, the executable count is bounded at
    ``len(batch_buckets) * len(len_buckets)`` prefills +
    ``len(batch_buckets)`` decode steps *total* — not per expert.

On CPU the expert mesh is driven by a forced host device count
(``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before backend
init); on a TPU slice the same code places banks across real chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np
from jax.sharding import Mesh

from ..core.registry import ExpertSpec
from .core import EngineCore, EngineStats
from .engine import ExpertEngine


# ---------------------------------------------------------------------------
# Banked engine
# ---------------------------------------------------------------------------


class BankedEngine:
    """E homogeneous experts served by one vmapped/sharded dispatch —
    the E>1 shim over ``EngineCore``.

    Params are stacked on a leading expert axis; prefill/decode are
    ``vmap`` over that axis, jitted once per (batch bucket, len bucket)
    for the *whole bank*. With ``mesh`` (1-D over ``"expert"``, size
    dividing ``n_experts``) the stacked params, caches and token planes
    are sharded over devices, so each device runs only its resident
    experts' slices of the single executable.
    """

    def __init__(self, model, params_list: Sequence[Any], *,
                 max_len: int = 256, min_len_bucket: int = 8,
                 len_buckets: Optional[Sequence[int]] = None,
                 batch_buckets: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None,
                 kv_layout: str = "ring", page_size: int = 8,
                 pool_pages: Optional[int] = None,
                 chunk_len: Optional[int] = None,
                 speculate_k: int = 0, draft=None):
        if not params_list:
            raise ValueError("BankedEngine needs at least one expert")
        self.core = EngineCore(model, params_list, max_len=max_len,
                               min_len_bucket=min_len_bucket,
                               len_buckets=len_buckets,
                               batch_buckets=batch_buckets, mesh=mesh,
                               kv_layout=kv_layout, page_size=page_size,
                               pool_pages=pool_pages, chunk_len=chunk_len,
                               speculate_k=speculate_k, draft=draft)
        self.model = model
        self.n_experts = self.core.n_experts
        self.mesh = self.core.mesh
        self.max_len = self.core.max_len
        self.len_buckets = self.core.len_buckets
        self.batch_buckets = self.core.batch_buckets
        self.kv_layout = self.core.kv_layout

    @property
    def params(self):
        """The stacked (E, ...) params pytree — read through the core,
        which the expert hub may swap under us (a slot install donates
        the previous stacked buffer, so a cached reference would be a
        dead array)."""
        return self.core.params

    @property
    def stats(self) -> EngineStats:
        return self.core.stats

    def bind_tracer(self, tracer) -> None:
        """Install a lifecycle tracer on the core (None disables).
        Device spans open at admit/tick and close only at the core's
        harvest sync points — tracing adds no host blocks."""
        self.core.bind_tracer(tracer)

    # -- admission -------------------------------------------------------
    def pad_shape(self, n_rows: int, prompt_len: int) -> Tuple[int, int]:
        """(batch bucket, length bucket) this admission would snap to."""
        return self.core.pad_shape(n_rows, prompt_len)

    def admit(self, groups: Mapping[int, Tuple[Sequence[Any],
                                               Sequence[np.ndarray],
                                               Sequence[int]]],
              *, defer: bool = False) -> None:
        """Prefill one (E, Bb, Sb) wave: every member expert's micro-batch
        in a single dispatch. A wave with no rows at all is a no-op (the
        scheduler only calls with traffic; ``ExpertEngine.admit`` by
        contrast rejects empties loudly). See ``EngineCore.admit_wave``
        for padding rules and the ``defer`` contract.
        """
        self.core.admit_wave(groups, defer=defer)

    # -- decoding --------------------------------------------------------
    def tick(self, *, defer: bool = False) -> int:
        """Advance every active wave one decode step — one dispatch per
        wave covers all member experts. Returns waves advanced."""
        return self.core.tick(defer=defer)

    def harvest(self) -> None:
        """Materialise (one batched transfer per wave) and emit finished
        rows; retire fully-done waves."""
        self.core.harvest()

    def poll(self) -> List[Tuple[int, Any, np.ndarray]]:
        """Drain finished (local expert, uid, tokens) triples."""
        return self.core.poll()

    @property
    def n_active(self) -> int:
        return self.core.n_active

    @property
    def has_pending(self) -> bool:
        """Active waves or finished rows not yet polled."""
        return self.core.has_pending


@dataclasses.dataclass
class BankMember:
    """Registry-facing handle: one expert's slot inside a BankedEngine."""
    bank: BankedEngine
    local: int

    def pad_shape(self, n_rows: int, prompt_len: int) -> Tuple[int, int]:
        return self.bank.pad_shape(n_rows, prompt_len)

    @property
    def batch_buckets(self) -> Tuple[int, ...]:
        return self.bank.batch_buckets

    @property
    def kv_layout(self) -> str:
        return self.bank.kv_layout

    @property
    def stats(self) -> EngineStats:
        return self.bank.stats


# ---------------------------------------------------------------------------
# Placement planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Shard:
    """One dispatch group: either a bank of co-located experts or a
    singleton wrapping whatever backend the registry already had."""
    sid: int
    experts: Tuple[int, ...]            # global registry indices
    bank: Optional[BankedEngine] = None
    devices: Tuple[Any, ...] = ()

    @property
    def banked(self) -> bool:
        return self.bank is not None


@dataclasses.dataclass
class PlacementPlan:
    shards: List[Shard]
    shard_of: Dict[int, int]            # expert index -> shard id
    mesh: Optional[Mesh] = None

    def describe(self, names: Optional[Sequence[str]] = None) -> str:
        lines = []
        for s in self.shards:
            label = ", ".join(names[e] if names else str(e)
                              for e in s.experts)
            dev = (f" on {len(s.devices)} device(s)" if s.devices else "")
            kind = "bank" if s.banked else "solo"
            lines.append(f"shard {s.sid} [{kind}]{dev}: {label}")
        return "\n".join(lines)


# Bank grouping is keyed on ``ExpertSpec`` (core/registry.py) — the one
# catalog entry type the hub, router metadata and this planner share.
# Equal specs mean identical shapes, identical executables (a paged
# member's spec additionally carries its page-pool geometry, since the
# bank stacks pools on the expert axis); ``spec.bankable`` excludes
# capacity-dispatch MoE, whose outputs depend on batch padding.


def _bank_submesh(n_experts: int, mesh: Optional[Mesh], offset: int = 0):
    """Largest-divisor slice of the expert mesh this bank can shard over.

    ``offset`` rotates the device pool so successive banks land on
    *disjoint* slices (wrapping once the pool is exhausted) instead of
    all piling onto the mesh's first devices.
    """
    if mesh is None or "expert" not in mesh.shape:
        return None, ()
    devs = np.roll(np.asarray(mesh.devices).reshape(-1),
                   -(offset % max(mesh.shape["expert"], 1)))
    for d in range(min(len(devs), n_experts), 0, -1):
        if n_experts % d == 0:
            if d == 1:
                return None, ()   # unsharded: params stay wherever jax
                #                   puts them, claim no device
            sub = Mesh(devs[:d], axis_names=("expert",))
            return sub, tuple(devs[:d])
    return None, ()


def plan_placement(registry, *, mesh: Optional[Mesh] = None,
                   min_bank: int = 2) -> PlacementPlan:
    """Group homogeneous ``ExpertEngine`` backends into ``BankedEngine``s
    and lay the shards out over ``mesh`` (1-D ``expert`` axis, see
    ``launch.mesh.make_expert_mesh``).

    Mutates ``registry`` in place: banked entries' backends become
    ``BankMember`` handles (the per-expert engines they replace are
    dropped, their params moving into the stacked bank). Groups smaller
    than ``min_bank`` and non-``ExpertEngine`` backends keep singleton
    shards. Returns the ``PlacementPlan`` the scheduler/router consume.
    """
    by_sig: Dict[ExpertSpec, List[int]] = {}
    for e in range(len(registry)):
        backend = registry[e].backend
        if isinstance(backend, BankMember):
            raise ValueError(
                f"expert {registry[e].name!r} is already bank-placed; "
                "plan_placement rebinds backends in place and cannot "
                "re-plan a planned registry — rebuild it from engines")
        if isinstance(backend, ExpertEngine):
            # derive from the live engine (authoritative) and publish on
            # the entry, so hub/router consumers read the same spec the
            # plan grouped by
            spec = backend.spec
            registry[e].spec = spec
            if spec.bankable:
                by_sig.setdefault(spec, []).append(e)

    shards: List[Shard] = []
    shard_of: Dict[int, int] = {}
    cursor = 0                      # rotates banks onto disjoint devices
    for experts in by_sig.values():
        if len(experts) < min_bank:
            continue
        engines = [registry[e].backend for e in experts]
        submesh, devices = _bank_submesh(len(experts), mesh, cursor)
        cursor += len(devices)
        bank = BankedEngine(
            engines[0].model, [eng.params for eng in engines],
            max_len=engines[0].max_len,
            len_buckets=engines[0].len_buckets,
            batch_buckets=engines[0].batch_buckets, mesh=submesh,
            kv_layout=engines[0].kv_layout,
            page_size=(engines[0].core.page
                       if engines[0].kv_layout == "paged" else 8),
            pool_pages=(engines[0].core.pool.n_pages
                        if engines[0].kv_layout == "paged" else None),
            chunk_len=(engines[0].core.chunk_len
                       if engines[0].kv_layout == "paged" else None),
            speculate_k=engines[0].core.speculate_k,
            draft=engines[0].core.draft_name)
        sid = len(shards)
        shards.append(Shard(sid=sid, experts=tuple(experts), bank=bank,
                            devices=devices))
        for local, e in enumerate(experts):
            registry[e].backend = BankMember(bank, local)
            shard_of[e] = sid
    for e in range(len(registry)):
        if e in shard_of:
            continue
        sid = len(shards)
        shards.append(Shard(sid=sid, experts=(e,)))
        shard_of[e] = sid
    return PlacementPlan(shards=shards, shard_of=shard_of, mesh=mesh)
