"""Draft models for speculative decoding.

A draft proposes ``k`` cheap continuation tokens per wave row; the
target expert verifies the whole window in one batched dispatch
(``models.dense.verify``) and accepts the matched greedy prefix.
Correctness never depends on the draft — any proposal sequence yields
bitwise-identical emitted tokens, only the acceptance rate (and thus
throughput) changes — so drafts are free to be heuristic, adversarial,
or to learn online from the verifier's corrections.

All methods are pure-JAX and traced *inside* the engine's jitted
verify executable, operating on a single expert's state slice; the
engine stacks per-expert states on a leading E axis (``init_state``)
and vmaps over it exactly like model params. State therefore lives on
device with the bank sharding and persists across waves — the bigram
draft keeps learning for the lifetime of the engine.

Drafts:

- ``MLPBaselineDraft`` ("mlp", default): the paper's always-resident
  MLP-Softmax baseline (``core/mlp_baseline.py``) re-purposed as a
  next-token proposer over a fixed random token embedding. Static —
  it is the "cheap proxy predicts, big model verifies" pattern.
- ``BigramTableDraft`` ("table"): an online-distilled per-bank draft
  head — a (V+1,) successor table updated from every verified
  (window token -> greedy continuation) pair. On the greedy decode
  cycles small models collapse into, it converges to the target's own
  transition function and acceptance approaches 1.
- ``AlwaysWrongDraft`` ("always-wrong"): adversarial zero-acceptance
  draft proposing the out-of-range id ``vocab`` (argmax over logits is
  always < vocab, so no proposal is ever accepted; the embedding
  gather clamps, keeping verification deterministic). Tests use it to
  prove the >= 1 token-per-verify progress guarantee.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.mlp_baseline import forward as mlp_forward, init_mlp


def _stack(per_expert):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_expert)


class DraftModel:
    """Interface. ``propose``/``observe`` see ONE expert's state slice."""

    name = "?"

    def init_state(self, key, n_experts: int):
        """Stacked (leading E axis) per-expert draft state pytree."""
        raise NotImplementedError

    def propose(self, state, tok, k: int):
        """tok (B,) int32 last emitted token -> (B, k) int32 proposals."""
        raise NotImplementedError

    def observe(self, state, window, greedy, adv):
        """Learn from a verify outcome: window/greedy (B, K+1), adv (B,)
        tokens emitted this verify (0 for frozen rows). Returns new
        state; static drafts return it unchanged."""
        return state

    def describe(self) -> dict:
        """Identity metadata for the obs snapshot tree (pure host data,
        never device arrays)."""
        return {"name": self.name, "kind": type(self).__name__}

    def _chain(self, state, tok, k, step):
        def body(cur, _):
            nxt = step(state, cur)
            return nxt, nxt

        _, drafts = jax.lax.scan(body, tok, None, length=k)
        return jnp.moveaxis(drafts, 0, 1)  # (k, B) -> (B, k)


class MLPBaselineDraft(DraftModel):
    name = "mlp"

    def __init__(self, vocab: int, in_dim: int = 32):
        self.vocab = vocab
        self.in_dim = in_dim

    def _init_one(self, key):
        kp, ke = jax.random.split(key)
        params, states = init_mlp(kp, in_dim=self.in_dim,
                                  n_classes=self.vocab)
        emb = jax.random.normal(ke, (self.vocab, self.in_dim),
                                jnp.float32)
        return {"params": params, "states": states, "emb": emb}

    def init_state(self, key, n_experts: int):
        return _stack([self._init_one(k)
                       for k in jax.random.split(key, n_experts)])

    def propose(self, state, tok, k: int):
        def step(st, cur):
            x = st["emb"][cur]
            logits, _ = mlp_forward(st["params"], st["states"], x,
                                    train=False)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        return self._chain(state, tok, k, step)


class BigramTableDraft(DraftModel):
    name = "table"

    def __init__(self, vocab: int):
        self.vocab = vocab

    def init_state(self, key, n_experts: int):
        # identity successor (propose repetition) + sentinel row `vocab`
        # absorbing masked observe writes
        tbl = jnp.arange(self.vocab + 1, dtype=jnp.int32)
        return {"table": jnp.broadcast_to(tbl, (n_experts,) + tbl.shape)}

    def propose(self, state, tok, k: int):
        return self._chain(state, tok, k,
                           lambda st, cur: st["table"][cur])

    def observe(self, state, window, greedy, adv):
        # every emitted pair (window[:, i] -> greedy[:, i]), i < adv,
        # is a verified target transition; unemitted columns (and frozen
        # rows, adv == 0) are routed to the never-read sentinel row
        K1 = window.shape[1]
        mask = jnp.arange(K1)[None, :] < adv[:, None]
        idx = jnp.where(mask, window, self.vocab)
        return {"table": state["table"].at[idx].set(
            jnp.where(mask, greedy, 0).astype(jnp.int32))}


class AlwaysWrongDraft(DraftModel):
    name = "always-wrong"

    def __init__(self, vocab: int):
        self.vocab = vocab

    def init_state(self, key, n_experts: int):
        return {"_": jnp.zeros((n_experts,), jnp.int32)}

    def propose(self, state, tok, k: int):
        # id == vocab is outside argmax's range, so never accepted; the
        # verifier's embedding gather clamps it deterministically
        return jnp.full(tok.shape + (k,), self.vocab, jnp.int32)


_DRAFTS = {
    "mlp": MLPBaselineDraft,
    "table": BigramTableDraft,
    "always-wrong": AlwaysWrongDraft,
}


def build_draft(name: str, vocab: int) -> DraftModel:
    if name not in _DRAFTS:
        raise ValueError(
            f"unknown draft {name!r}; choose from {sorted(_DRAFTS)}")
    return _DRAFTS[name](vocab)
