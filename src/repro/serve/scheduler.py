"""Admission queue + continuous micro-batching scheduler (Fig. 2 as a
serving system).

Life of a request:

  submit() -> Router.route (fingerprint LRU + Pallas scoring, shard ids
              from the placement plan)
           -> per-expert FIFO queue, sub-bucketed by prompt-length bucket
  step()   -> the dispatch executor runs one round over all shards:
              admission (per *shard*, pick one length bucket — fullest
              wins, with age-based promotion so sparse buckets can't
              starve — and admit one dispatch group; a banked shard
              prefills every member expert's micro-batch in a single
              call), then decode (every shard with resident groups
              advances one token; one ``tick`` per bank, not per
              expert), then engine harvest. With the default
              ``overlapped`` executor every prefill and decode tick is
              *enqueued* before anything blocks — sampled tokens stay
              on device and the host blocks at most once per wave, in
              the batched harvest transfer — so prefill of one shard
              overlaps decode of another. ``executor="serial"`` keeps
              the blocking per-tick reference behaviour.
           -> harvest: finished rows become Responses immediately,
              demuxed through the shard's expert list
  drain()  -> step() until all queues and engines are empty

Because queues persist across calls, requests submitted in *different*
``submit`` calls coalesce into the same micro-batch — the continuous
part — and because shapes are snapped to the engine's buckets, a mixed
traffic stream compiles a bounded set of executables no matter how many
distinct (prompt length, batch, max_new) combinations arrive.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.matcher import ExpertMatcher
from ..core.registry import ExpertRegistry
from ..obs.metrics import Counter, Histogram, MetricsRegistry
from ..obs.trace import NULL_TRACER
from .core import DispatchExecutor, get_executor
from .engine import ExpertEngine
from .hub import ExpertHub, HubMember, NotResident
from .kvcache import PagePoolExhausted
from .placement import BankMember, PlacementPlan, Shard
from .router import PrefixLRU, Router


@dataclasses.dataclass
class Request:
    uid: int
    features: np.ndarray            # (784,) matcher fingerprint
    prompt: np.ndarray              # (S,) int32 tokens
    max_new_tokens: int = 8
    expert: Optional[int] = None    # pre-routed: skip the matcher (the
    #                                 paper's repeat clients know their
    #                                 expert; also the hub bench path)


@dataclasses.dataclass
class Response:
    uid: int
    expert: str
    fine_class: int
    tokens: np.ndarray
    coarse_scores: Optional[np.ndarray] = None
    shard: int = -1                 # placement shard that served the row


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 16             # micro-batch row cap (per expert)
    max_queue: int = 4096           # admission queue cap (backpressure)
    promote_after: int = 4          # rounds a waiting bucket may be
    #                                 skipped before it wins admission
    check_every: int = 0            # >0: run check_invariants() every N
    #                                 steps (PagePool.check + hub state
    #                                 machine + pin conservation) — the
    #                                 sanitizer's invariants under real
    #                                 traffic (serving_bench
    #                                 --check-invariants)
    prefill_tokens_per_step: int = 0
    #                                 per-shard prompt-token budget for
    #                                 pending prefill chunks each step
    #                                 (0 = unbounded); at least one chunk
    #                                 always dispatches, so whales make
    #                                 progress while bounded budgets keep
    #                                 co-resident decode latency flat
    speculate_k: Optional[int] = None
    #                                 speculative-decoding contract:
    #                                 None inherits whatever each engine
    #                                 was built with; an int asserts
    #                                 every tickable shard engine was
    #                                 built with exactly that
    #                                 speculate_k (engines own the
    #                                 verify executables, so the
    #                                 scheduler can only validate, not
    #                                 retrofit)


@dataclasses.dataclass(frozen=True)
class SchedulerStats:
    """Immutable snapshot of the scheduler's counters (one field per
    former loose-dict key). Read it as attributes; ``as_dict()`` is the
    shape the unified metrics registry snapshots. The live counters are
    ``repro.obs`` Counters on the scheduler — this type is only ever a
    point-in-time copy, so callers can hold one across a step without
    it mutating under them."""
    submitted: int = 0
    rejected: int = 0
    batches: int = 0
    ticks: int = 0
    responses: int = 0
    promotions: int = 0
    orphaned: int = 0
    kv_stalls: int = 0
    resident_stalls: int = 0
    invariant_checks: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Pending:
    req: Request
    fine: int
    scores: np.ndarray
    shard: int = -1
    seq: int = 0                    # submit order, for age promotion
    prefix_key: bytes = b""         # prompt-prefix cohort key (PrefixLRU)
    expert: int = -1                # routed expert (hub demux + unpin)
    # lifecycle accounting (tracer clock, seconds): queue time is
    # submit→admit minus the stalled share; ``stall_since`` is open
    # while the row is parked on NotResident / PagePoolExhausted
    # backpressure
    trace: int = 0                  # trace id (0 when tracing is off)
    t_submit: float = 0.0
    t_admit: float = 0.0
    stalled_s: float = 0.0
    stall_since: Optional[float] = None


class Scheduler:
    """Routes, queues, batches and ticks a fleet of expert shards."""

    def __init__(self, router: Optional[Router],
                 registry: ExpertRegistry,
                 config: Optional[SchedulerConfig] = None,
                 placement: Optional[PlacementPlan] = None,
                 executor: "str | DispatchExecutor" = "overlapped",
                 hub: Optional[ExpertHub] = None,
                 tracer=None):
        self.router = router
        self.registry = registry
        self.config = config or SchedulerConfig()
        self.placement = placement
        self.hub = hub
        self.executor = get_executor(executor)
        if hub is not None:
            if placement is not None:
                raise ValueError("hub and placement are exclusive: the "
                                 "hub owns its own slot bank")
            if len(hub) != len(registry):
                raise ValueError(
                    f"hub catalog ({len(hub)} experts) does not match "
                    f"the registry ({len(registry)}); build the "
                    "registry via hub.build_registry()")
            for e in range(len(registry)):
                be = registry[e].backend
                if not (isinstance(be, HubMember) and be.hub is hub
                        and be.expert == e):
                    # same contract as the placement branch's
                    # BankMember check: a same-length foreign registry
                    # would silently serve through the hub's slots
                    # under the wrong expert names / bucket ladders
                    raise ValueError(
                        f"registry entry {e} ({registry[e].name!r}) is "
                        "not this hub's HubMember; build the registry "
                        "via hub.build_registry()")
            # one dispatch-group shard over the whole catalog: every
            # wave is served by the hub's slot bank, groups keyed by
            # device slot rather than registry index
            self.shards = [Shard(sid=0,
                                 experts=tuple(range(len(registry))),
                                 bank=hub.bank)]
        elif placement is not None:
            # the plan must describe THIS registry: plan_placement
            # rebound each banked expert's backend to a BankMember of
            # its shard's bank — a stale plan for another registry
            # would silently serve with the wrong experts' params
            missing = set(range(len(registry))) - set(placement.shard_of)
            if missing:
                raise ValueError(
                    f"placement plan does not cover experts "
                    f"{sorted(missing)} (registry grown after "
                    f"plan_placement?); re-plan on this registry")
            for shard in placement.shards:
                if not shard.banked:
                    continue
                for local, e in enumerate(shard.experts):
                    be = registry[e].backend if e < len(registry) else None
                    if not (isinstance(be, BankMember)
                            and be.bank is shard.bank
                            and be.local == local):
                        raise ValueError(
                            f"placement plan does not match registry at "
                            f"expert {e}; re-plan with plan_placement "
                            f"on this registry")
            self.shards = list(placement.shards)
        else:  # PR 1 behaviour: every expert is its own dispatch group
            for e in range(len(registry)):
                if isinstance(registry[e].backend, BankMember):
                    raise ValueError(
                        f"expert {registry[e].name!r} is bank-placed "
                        "(plan_placement rebound its backend to a "
                        "BankMember); pass that PlacementPlan via "
                        "placement=")
            self.shards = [Shard(sid=e, experts=(e,))
                           for e in range(len(registry))]
        self._shard_of = {e: s.sid for s in self.shards for e in s.experts}
        if self.config.speculate_k is not None:
            want = int(self.config.speculate_k)
            for shard in self.shards:
                eng = self._shard_engine(shard)
                if eng is None:
                    continue
                got = getattr(eng.core, "speculate_k", 0)
                if got != want:
                    raise ValueError(
                        f"SchedulerConfig.speculate_k={want} but shard "
                        f"{shard.sid} engine was built with "
                        f"speculate_k={got}; rebuild its engines with "
                        "the matching speculate_k")
        # queues[expert][len_bucket] -> FIFO of _Pending
        self.queues: Dict[int, Dict[int, collections.deque]] = \
            collections.defaultdict(lambda: collections.defaultdict(
                collections.deque))
        self.n_queued = 0
        self._seq = 0
        self._skips: Dict[Tuple[int, int], int] = \
            collections.defaultdict(int)   # (shard, bucket) skip rounds
        self._counters: Dict[str, Counter] = {
            f.name: Counter() for f in dataclasses.fields(SchedulerStats)}
        self._steps = 0
        self._done: List[Response] = []
        self._meta: Dict[int, _Pending] = {}   # uid -> routing info
        # prompt-prefix cohort detection: keyed at the page granularity
        # of the first paged engine (8 when every shard rings)
        page = next((self._shard_engine(s).core.page for s in self.shards
                     if self._paged_shard(s)), 8)
        self.prefix_lru = PrefixLRU(page=page)
        # latency attribution — always on (two perf_counter stamps per
        # request, no numpy): queue_ms excludes the stalled share so the
        # two histograms decompose wait time the way the bench's stage
        # table reports it
        self._h_queue = Histogram()
        self._h_stalled = Histogram()
        self.tracer = NULL_TRACER
        self.bind_tracer(tracer)
        self.obs = self._build_metrics()

    @property
    def stats(self) -> SchedulerStats:
        """Frozen point-in-time snapshot of the scheduler counters."""
        return SchedulerStats(**{k: c.value
                                 for k, c in self._counters.items()})

    def bind_tracer(self, tracer) -> None:
        """Install a lifecycle tracer here, on every shard engine core
        and on the hub (None restores the disabled NULL_TRACER). Safe
        between steps; rows already in flight keep trace id 0."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        for shard in self.shards:
            eng = self._shard_engine(shard)
            core = getattr(eng, "core", None)
            if core is not None:
                core.bind_tracer(self.tracer)
        if self.hub is not None:
            self.hub.bind_tracer(self.tracer)

    def _build_metrics(self) -> MetricsRegistry:
        """The unified snapshot tree: scheduler counters + latency
        histograms, every shard engine's ``EngineStats``, every paged
        shard's page-pool counters, the router and (when present) the
        hub's per-expert metrics — one ``snapshot()`` call is the whole
        mesh's state."""
        obs = MetricsRegistry()
        obs.register("scheduler", lambda: self.stats.as_dict())
        obs.register("scheduler/latency/queue_ms", self._h_queue)
        obs.register("scheduler/latency/stalled_ms", self._h_stalled)
        obs.register("executor", lambda: {"name": self.executor.name})
        for shard in self.shards:
            eng = self._shard_engine(shard)
            if eng is None:
                continue
            label = f"shard{shard.sid}"
            obs.register(f"engines/{label}",
                         (lambda e=eng: e.stats.as_dict()))
            core = getattr(eng, "core", None)
            if core is not None and core.pool is not None:
                obs.register(f"kv/{label}", core.pool.telemetry)
            if core is not None and core.draft is not None:
                obs.register(f"engines/{label}/draft",
                             core.draft.describe())
        if self.router is not None:
            obs.register("router", self._router_metrics)
        if self.hub is not None:
            obs.register("hub", self.hub.metrics_snapshot)
        return obs

    def _router_metrics(self) -> Dict[str, Any]:
        r = self.router
        return {**r.stats, "expert_hits": dict(r.expert_hits),
                "prefix_lru": dict(self.prefix_lru.stats)}

    def _paged_shard(self, shard: Shard) -> bool:
        eng = self._shard_engine(shard)
        return eng is not None and getattr(eng, "kv_layout", "ring") == \
            "paged"

    def speculative_stats(self) -> Dict[str, Any]:
        """Aggregate speculative-decoding counters over every tickable
        shard — what the bench records and the CI acceptance-rate floor
        is asserted against."""
        drafted = accepted = verifies = fallback = 0
        for shard in self.shards:
            eng = self._shard_engine(shard)
            if eng is None:
                continue
            st = eng.stats
            drafted += st.tokens_drafted
            accepted += st.tokens_accepted
            verifies += st.verify_steps
            fallback += st.spec_fallback_waves
        return {"tokens_drafted": drafted, "tokens_accepted": accepted,
                "verify_steps": verifies,
                "spec_fallback_waves": fallback,
                "acceptance_rate": accepted / drafted if drafted
                else 0.0}

    # -- admission -------------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> int:
        """Route and enqueue; returns how many were admitted — always a
        prefix of ``requests``, so callers can resubmit the tail later.
        Requests beyond the queue cap are rejected unrouted
        (backpressure). uids must be unique among in-flight requests —
        they key response demultiplexing.

        Requests carrying ``expert=`` are pre-routed: they skip the
        matcher (and are the only kind a router-less hub scheduler
        accepts) but still feed the popularity counter the hub's
        eviction policy reads.
        """
        if not requests:
            return 0
        batch_seen = set()
        for r in requests:
            if r.uid in self._meta or r.uid in batch_seen:
                raise ValueError(f"duplicate in-flight uid {r.uid}")
            batch_seen.add(r.uid)
        room = max(self.config.max_queue - self.n_queued, 0)
        self._counters["rejected"].inc(
            len(requests) - min(len(requests), room))
        requests = requests[:room]
        if not requests:
            return 0
        miss = [i for i, r in enumerate(requests) if r.expert is None]
        if miss and self.router is None:
            raise ValueError(
                "scheduler has no router: every request must be "
                "pre-routed (Request.expert set)")
        routed = None
        if miss:
            with self.tracer.span("route", rows=len(miss),
                                  uids=[requests[i].uid for i in miss]):
                routed = self.router.route(np.stack(
                    [requests[i].features for i in miss]))
        routed_at = {i: j for j, i in enumerate(miss)}
        top_k = routed.coarse.shape[1] if routed is not None else 1
        admitted = 0
        for i, r in enumerate(requests):
            if r.expert is not None:
                e, fine = int(r.expert), 0
                if not 0 <= e < len(self.registry):
                    raise ValueError(f"pre-routed expert {e} out of "
                                     f"range [0, {len(self.registry)})")
                scores = np.zeros(top_k, np.float32)
                sid = self._shard_of.get(e, -1)
                # router.route counts its own rows; pre-routed hits go
                # through the hub's locked mutation point — the shared
                # popularity Counter races with the eviction ranking
                # otherwise (races.py R001; the sanitizer's lost-update
                # seed demonstrates the dropped increments)
                if self.hub is not None:
                    self.hub.note_hit(e)
                elif self.router is not None:
                    self.router.expert_hits[e] += 1
            else:
                j = routed_at[i]
                e = int(routed.coarse[j, 0])
                fine = int(routed.fine[j])
                scores = routed.coarse_score[j]
                # routed.shard is the placement-aware router's demux
                # contract (identical to _shard_of when both come from
                # one plan); the local map covers routers wired without
                # a placement
                sid = (int(routed.shard[j]) if routed.shard is not None
                       else self._shard_of.get(e, -1))
            engine = self.registry[e].backend
            sb = (engine.pad_shape(1, len(r.prompt))[1]
                  if hasattr(engine, "pad_shape") else len(r.prompt))
            self._seq += 1
            p = _Pending(r, fine, scores, shard=sid, seq=self._seq,
                         prefix_key=self.prefix_lru.observe(r.prompt),
                         expert=e, t_submit=self.tracer.now())
            if self.tracer.enabled:
                p.trace = self.tracer.next_id()
                self.tracer.bind_uid(r.uid, p.trace)
                self.tracer.event("request.submit", uid=r.uid,
                                  trace=p.trace, expert=e, shard=sid,
                                  prompt_len=len(r.prompt),
                                  max_new=int(r.max_new_tokens))
            self.queues[e][sb].append(p)
            self._meta[r.uid] = p
            self.n_queued += 1
            admitted += 1
        self._counters["submitted"].inc(admitted)
        return admitted

    # -- one scheduling round -------------------------------------------
    def step(self) -> List[Response]:
        self.executor.run_step(self)
        self._harvest()
        out, self._done = self._done, []
        self._counters["responses"].inc(len(out))
        self._steps += 1
        if (self.config.check_every
                and self._steps % self.config.check_every == 0):
            self.check_invariants()
        return out

    def drain(self) -> List[Response]:
        out: List[Response] = []
        while self.has_work:
            out.extend(self.step())
        return out

    @property
    def has_work(self) -> bool:
        if self.n_queued:
            return True
        # has_pending, not n_active: an interleaved generate() call may
        # tick a scheduler group to completion and park its rows in the
        # engine's finished buffer — they still need a harvest step
        return any(eng is not None and eng.has_pending
                   for eng in map(self._shard_engine, self.shards))

    def check_invariants(self) -> None:
        """The sanitizer's conservation invariants, under real traffic:
        page-pool refcount books balance (``PagePool.check``), the hub
        catalog/slot state machine is legal (``ExpertHub.check``), and
        residency pins conserve — every pin is held by exactly one
        in-flight admitted row, so pins == in-flight - queued. Enabled
        every N steps via ``SchedulerConfig.check_every`` (the bench's
        ``--check-invariants`` flag)."""
        for shard in self.shards:
            eng = self._shard_engine(shard)
            if eng is not None and \
                    getattr(eng, "kv_layout", "ring") == "paged":
                eng.core.pool.check()
        if self.hub is not None:
            self.hub.check()
            pins = self.hub.total_pins()
            in_flight = len(self._meta) - self.n_queued
            assert pins == in_flight, (
                f"pin conservation broke: hub holds {pins} pins but "
                f"{in_flight} rows are admitted and unharvested")
        self._counters["invariant_checks"].inc()

    def close(self) -> None:
        """Shut down background machinery (the hub's staging worker);
        idempotent, safe without a hub."""
        if self.hub is not None:
            self.hub.close()

    # -- internals -------------------------------------------------------
    def _shard_engine(self, shard: Shard):
        """The tickable engine behind a shard (bank or ExpertEngine);
        None for stub/legacy backends that complete at admission."""
        if shard.banked:
            return shard.bank
        engine = self.registry[shard.experts[0]].backend
        return engine if isinstance(engine, ExpertEngine) else None

    def _pick_bucket(self, shard: Shard) -> Optional[int]:
        """Length bucket this shard admits this round.

        Fullest bucket (summed over member experts) wins — best padding
        efficiency — unless a non-empty bucket has been skipped
        ``promote_after`` rounds in a row: then the starving bucket with
        the oldest waiting head wins. Without promotion, sustained
        traffic concentrated in one bucket starves sparse buckets
        indefinitely (the fullest-first rule never lets them drain).
        """
        counts: Dict[int, int] = collections.defaultdict(int)
        oldest: Dict[int, int] = {}
        for e in shard.experts:
            for sb, q in self.queues[e].items():
                if q:
                    counts[sb] += len(q)
                    oldest[sb] = min(oldest.get(sb, q[0].seq), q[0].seq)
        # prune drained buckets' counters: legacy backends key queues by
        # raw prompt length, so without pruning _skips would grow one
        # permanent entry per distinct length for the server's lifetime
        for key in [k for k in self._skips if k[0] == shard.sid
                    and k[1] not in counts]:
            del self._skips[key]
        if not counts:
            return None
        starving = [sb for sb in counts
                    if self._skips[(shard.sid, sb)]
                    >= self.config.promote_after]
        if starving:
            sb = min(starving, key=lambda b: oldest[b])
            self._counters["promotions"].inc()
        else:
            sb = max(counts, key=lambda b: (counts[b], -oldest[b]))
        for other in counts:
            if other != sb:
                self._skips[(shard.sid, other)] += 1
        self._skips.pop((shard.sid, sb), None)
        return sb

    def _pop(self, e: int, sb: int, cap: int,
             prefix_group: bool = False) -> List[_Pending]:
        """Take up to ``cap`` rows from one bucket queue.

        Plain FIFO normally; with ``prefix_group`` (paged shards) the
        head's prompt-prefix cohort is pulled forward so prefix-sharing
        rows land in the *same wave* — that co-residency is what lets
        the paged engine deduplicate their prefill and share pages.
        Non-matching rows keep their relative order and still fill any
        remaining capacity, and bucket-level age promotion bounds how
        long a displaced row can wait.
        """
        q = self.queues[e][sb]
        if prefix_group and len(q) > 1 and cap > 1:
            key = q[0].prefix_key
            idxs = [i for i, p in enumerate(q)
                    if p.prefix_key == key][:cap]
            if len(idxs) < cap:
                fill = [i for i, p in enumerate(q)
                        if p.prefix_key != key][:cap - len(idxs)]
                idxs = sorted(idxs + fill)
            picked = set(idxs)
            take = [q[i] for i in idxs]
            rest = [q[i] for i in range(len(q)) if i not in picked]
            q.clear()
            q.extend(rest)
        else:
            take = [q.popleft() for _ in range(min(len(q), cap))]
        self.n_queued -= len(take)
        if not q:
            # drop drained buckets: legacy backends key them by raw
            # prompt length, so keeping empties would grow the dict (and
            # _pick_bucket's scan) for the server's lifetime
            del self.queues[e][sb]
        return take

    def _requeue(self, e: int, sb: int, take: List[_Pending]) -> None:
        """Put popped rows back at the queue front (order preserved) —
        the page pool could not host their wave this round."""
        q = self.queues[e][sb]
        for p in reversed(take):
            q.appendleft(p)
        self.n_queued += len(take)

    def _note_stall(self, event: str, e: int, sb: int) -> None:
        """Open the stall clock on every parked row in queue (e, sb)
        that isn't already stalled, and emit one ``event`` (``hub.park``
        or ``kv.requeue``) covering exactly those rows — so a row parked
        across many rounds produces one event and one stall interval,
        not one per round."""
        q = self.queues[e].get(sb)
        if not q:
            return
        t = self.tracer.now()
        fresh = [p for p in q if p.stall_since is None]
        for p in fresh:
            p.stall_since = t
        if fresh and self.tracer.enabled:
            self.tracer.event(event, expert=e, rows=len(fresh),
                              uids=[p.req.uid for p in fresh],
                              traces=[p.trace for p in fresh])

    def _mark_admitted(self, takes: Sequence[List[_Pending]], sid: int,
                       sb: int) -> None:
        """Close stall clocks and stamp admission time on every row of
        a successfully admitted dispatch group."""
        t = self.tracer.now()
        rows = [p for take in takes for p in take]
        for p in rows:
            if p.stall_since is not None:
                p.stalled_s += t - p.stall_since
                p.stall_since = None
            p.t_admit = t
        if rows and self.tracer.enabled:
            self.tracer.event("request.admit", shard=sid, bucket=sb,
                              uids=[p.req.uid for p in rows],
                              traces=[p.trace for p in rows])

    def _finish_row(self, p: _Pending) -> None:
        """Close the row's lifecycle accounting at response emission:
        fold any still-open stall, decompose the wait into the
        queue/stalled histograms (milliseconds) and emit
        ``request.finish`` + release the uid→trace binding."""
        t = self.tracer.now()
        if p.stall_since is not None:
            p.stalled_s += t - p.stall_since
            p.stall_since = None
        admit = p.t_admit if p.t_admit else t
        queue_s = max(admit - p.t_submit - p.stalled_s, 0.0)
        self._h_queue.observe(queue_s * 1e3)
        self._h_stalled.observe(p.stalled_s * 1e3)
        if self.tracer.enabled:
            self.tracer.event(
                "request.finish", uid=p.req.uid, trace=p.trace,
                expert=p.expert, queue_ms=queue_s * 1e3,
                stalled_ms=p.stalled_s * 1e3,
                total_ms=(t - p.t_submit) * 1e3)
            self.tracer.release_uid(p.req.uid)

    def _service_hub(self) -> None:
        """Drive the expert hub's lifecycle one round (no-op without a
        hub): poll staged checkpoints, commit wanted experts into bank
        slots, kick prefetch. Runs at the *head* of every executor
        step, so with the overlapped executor the slot-install
        dispatches are enqueued before this step's decode ticks and
        checkpoint staging overlaps device compute. When nothing is
        resident (no decode to overlap with) the hub blocks on staging
        instead of busy-spinning the drain loop."""
        if self.hub is None:
            return
        idle = not any(eng is not None and eng.n_active
                       for eng in map(self._shard_engine, self.shards))
        self.hub.service(block=idle)

    def _admit_batches(self, *, defer: bool = False) -> None:
        """Issue one dispatch group per shard. With ``defer`` the
        prefills are only enqueued (tokens stay on device; the executor
        harvests once at the end of the step)."""
        for shard in self.shards:
            sb = self._pick_bucket(shard)
            if sb is None:
                continue
            if self.hub is not None:
                self._admit_hub(shard, sb, defer=defer)
            elif shard.banked:
                self._admit_banked(shard, sb, defer=defer)
            else:
                self._admit_single(shard.experts[0], sb, defer=defer)

    def _admit_hub(self, shard: Shard, sb: int, *,
                   defer: bool = False) -> None:
        """One dispatch group over the hub's slot bank: resident
        experts' micro-batches ride the wave keyed by *device slot*;
        a non-resident expert's rows park in their queue (the
        ``NotResident`` outcome — the residency analogue of
        ``PagePoolExhausted`` backpressure) while the hub stages and
        commits it in the background."""
        hub, bank = self.hub, shard.bank
        paged = self._paged_shard(shard)
        cap = min(self.config.max_batch, bank.batch_buckets[-1])
        groups, popped = {}, {}
        stalled = 0
        for e in shard.experts:
            if not self.queues[e].get(sb):
                continue
            try:
                slot = hub.acquire(e)
            except NotResident:
                stalled += 1        # rows stay parked in their queue
                self._note_stall("hub.park", e, sb)
                continue
            take = self._pop(e, sb, cap, prefix_group=paged)
            if not take:
                continue
            hub.pin(e, len(take))
            popped[e] = take
            groups[slot] = ([p.req.uid for p in take],
                            [p.req.prompt for p in take],
                            [p.req.max_new_tokens for p in take])
        if stalled:
            self._counters["resident_stalls"].inc(stalled)
        if not groups:
            return
        try:
            bank.admit(groups, defer=defer)
        except PagePoolExhausted:
            # unwind pops and pins on BOTH exits: the fatal re-raise
            # (pool too small for even one wave) must not strand rows
            # out of their queues or leave residency pins that would
            # make the experts permanently unevictable
            for e, take in popped.items():
                self._requeue(e, sb, take)
                hub.unpin(e, len(take))
            if not bank.n_active:
                raise            # pool too small for even one wave
            self._counters["kv_stalls"].inc()
            for e in popped:
                self._note_stall("kv.requeue", e, sb)
            return
        self._counters["batches"].inc()
        self._mark_admitted(list(popped.values()), shard.sid, sb)

    def _admit_banked(self, shard: Shard, sb: int, *,
                      defer: bool = False) -> None:
        """One dispatch group: every member expert's micro-batch from the
        chosen bucket rides a single BankedEngine prefill. A paged bank
        whose pool cannot host the wave requeues the rows (clean
        backpressure) instead of corrupting resident pages."""
        bank = shard.bank
        paged = self._paged_shard(shard)
        cap = min(self.config.max_batch, bank.batch_buckets[-1])
        groups, popped = {}, {}
        for local, e in enumerate(shard.experts):
            take = self._pop(e, sb, cap, prefix_group=paged)
            if take:
                popped[local] = take
                groups[local] = ([p.req.uid for p in take],
                                 [p.req.prompt for p in take],
                                 [p.req.max_new_tokens for p in take])
        if not groups:
            return
        try:
            bank.admit(groups, defer=defer)
        except PagePoolExhausted:
            if not bank.n_active:
                # no resident wave will ever free pages: the pool is
                # simply too small for a single wave — surface it
                raise
            for local, e in enumerate(shard.experts):
                if local in popped:
                    self._requeue(e, sb, popped[local])
                    self._note_stall("kv.requeue", e, sb)
            self._counters["kv_stalls"].inc()
            return
        self._counters["batches"].inc()
        self._mark_admitted(list(popped.values()), shard.sid, sb)

    def _admit_single(self, e: int, sb: int, *,
                      defer: bool = False) -> None:
        engine = self.registry[e].backend
        name = self.registry[e].name
        cap = self.config.max_batch
        paged = isinstance(engine, ExpertEngine) and \
            engine.kv_layout == "paged"
        if isinstance(engine, ExpertEngine):
            cap = min(cap, engine.batch_buckets[-1])
        take = self._pop(e, sb, cap, prefix_group=paged)
        if not take:
            return
        if isinstance(engine, ExpertEngine):
            try:
                engine.admit([p.req.uid for p in take],
                             [p.req.prompt for p in take],
                             [p.req.max_new_tokens for p in take],
                             defer=defer)
            except PagePoolExhausted:
                if not engine.n_active:
                    raise      # pool too small for even one wave
                self._requeue(e, sb, take)
                self._note_stall("kv.requeue", e, sb)
                self._counters["kv_stalls"].inc()
                return
            self._counters["batches"].inc()
            self._mark_admitted([take], self._shard_of.get(e, -1), sb)
        elif engine is None:
            self._counters["batches"].inc()
            for p in take:
                self._meta.pop(p.req.uid, None)
                self._done.append(self._response(
                    p, name, np.zeros(p.req.max_new_tokens, np.int32)))
                self._finish_row(p)
        else:
            # legacy blocking engines: one padded batch call
            self._counters["batches"].inc()
            m = max(len(p.req.prompt) for p in take)
            toks = np.zeros((len(take), m), np.int32)
            for i, p in enumerate(take):
                toks[i, :len(p.req.prompt)] = p.req.prompt
            gen = np.asarray(engine.generate(
                toks, max(p.req.max_new_tokens for p in take)))
            for i, p in enumerate(take):
                self._meta.pop(p.req.uid, None)
                self._done.append(self._response(
                    p, name, gen[i, :p.req.max_new_tokens]))
                self._finish_row(p)

    def _prefill_chunks(self) -> None:
        """Issue pending prefill chunks of partially-prefilled waves,
        bounded per shard by ``SchedulerConfig.prefill_tokens_per_step``
        (0 = drain). Runs between admission and decode ticks — the
        disaggregation point: a whale prompt admitted with deferred
        chunks spends at most the budget per step, and the decode ticks
        that follow run every step regardless of how much prefill work
        is still queued. A wave only becomes decode-eligible once its
        last chunk lands (chunk cursor tracked FIFO on the wave)."""
        budget = self.config.prefill_tokens_per_step
        for shard in self.shards:
            eng = self._shard_engine(shard)
            if eng is not None and getattr(eng, "core", None) is not None \
                    and eng.core.has_pending_chunks:
                eng.core.prefill_step(budget)

    def _tick_engines(self, *, defer: bool = False) -> None:
        """Advance every shard's resident waves one token. With
        ``defer`` the decode dispatches are only enqueued — no shard's
        tick blocks the host before the next shard's work is issued."""
        for shard in self.shards:
            eng = self._shard_engine(shard)
            if eng is not None and eng.n_active:
                eng.tick(defer=defer)
                self._counters["ticks"].inc()

    def _harvest_engines(self) -> None:
        """One batched device→host transfer per wave (at most): emit
        finished rows into each engine's poll buffer."""
        for shard in self.shards:
            eng = self._shard_engine(shard)
            if eng is not None:
                eng.harvest()

    def _harvest(self) -> None:
        for shard in self.shards:
            eng = self._shard_engine(shard)
            if eng is None:
                continue
            for item in eng.poll():
                if shard.banked:
                    local, uid, toks = item
                else:
                    uid, toks = item
                    local = 0
                if uid not in self._meta and isinstance(uid, tuple):
                    # generate()'s private tuple namespace: a call that
                    # raised mid-flight leaves its group resident, and
                    # its rows eventually surface here with no owner —
                    # drop them (with a stat). Unknown *int* uids stay
                    # a loud KeyError: that's a demux bug, not litter.
                    self._counters["orphaned"].inc()
                    continue
                p = self._meta.pop(uid)
                if self.hub is not None:
                    # hub waves key groups by device slot, whose owner
                    # changes over time — demux through the pending
                    # row's routed expert and release its residency pin
                    # (the slot is evictable once its last pin drops)
                    name = self.registry[p.expert].name
                    self.hub.unpin(p.expert)
                elif shard.banked:
                    name = self.registry[shard.experts[local]].name
                else:
                    name = self.registry[shard.experts[0]].name
                self._done.append(self._response(
                    p, name, toks[:p.req.max_new_tokens]))
                self._finish_row(p)

    def _response(self, p: _Pending, name: str,
                  tokens: np.ndarray) -> Response:
        return Response(uid=p.req.uid, expert=name, fine_class=p.fine,
                        tokens=tokens, coarse_scores=p.scores,
                        shard=p.shard)


class RoutedServer:
    """ExpertMatcher in front of a fleet of expert shards.

    Seed-compatible façade over Router + Scheduler: ``serve`` is
    submit-then-drain, returning responses in request order. Incremental
    users call ``submit``/``step`` directly for continuous batching.
    Pass ``placement`` (from ``serve.placement.plan_placement``) to
    serve banked multi-expert shards instead of one engine per expert,
    and ``executor`` (``"overlapped"`` — the default async dispatch —
    or ``"serial"``, the blocking reference) to pick how each step
    drives its shards; both executors are token-identical.

    Pass ``hub`` (an ``ExpertHub`` whose ``build_registry()`` produced
    ``registry``) for dynamic expert residency: the catalog may be far
    larger than the hub's device slots, non-resident experts park their
    rows while checkpoints stage in the background, and the router's
    per-expert hit counts drive the hub's eviction policy. With a hub,
    ``matcher=None`` is allowed when every request is pre-routed
    (``Request.expert``) — the long-tail bench path.
    """

    def __init__(self, matcher: Optional[ExpertMatcher],
                 registry: ExpertRegistry,
                 *, max_batch: int = 16, route_cache_size: int = 4096,
                 use_fine_kernel: bool = True,
                 placement: Optional[PlacementPlan] = None,
                 executor: "str | DispatchExecutor" = "overlapped",
                 hub: Optional[ExpertHub] = None,
                 check_every: int = 0,
                 prefill_tokens_per_step: int = 0,
                 speculate_k: Optional[int] = None,
                 tracer=None):
        self.matcher = matcher
        self.registry = registry
        self.placement = placement
        self.hub = hub
        if matcher is None:
            if hub is None:
                raise ValueError("matcher=None requires a hub serving "
                                 "pre-routed requests")
            self.router = None
        else:
            assert len(registry) == matcher.n_experts, \
                "registry/bank mismatch"
            self.router = Router(
                matcher, cache_size=route_cache_size,
                use_fine_kernel=use_fine_kernel,
                shard_of=placement.shard_of if placement else None)
        if hub is not None and self.router is not None:
            # routing decisions feed residency: the eviction policy
            # reads the very Counter route() increments — which makes
            # that Counter cross-thread state, so the router's own
            # increments take the hub lock from here on (hits_lock)
            hub.bind_popularity(self.router.expert_hits,
                                router=self.router)
        self.scheduler = Scheduler(
            self.router, registry,
            SchedulerConfig(max_batch=max_batch, check_every=check_every,
                            prefill_tokens_per_step=prefill_tokens_per_step,
                            speculate_k=speculate_k),
            placement=placement, executor=executor, hub=hub,
            tracer=tracer)
        #: the unified metrics registry — ``obs.snapshot()`` is the
        #: whole mesh's state as one nested dict
        self.obs = self.scheduler.obs

    def bind_tracer(self, tracer) -> None:
        """Install (or, with None, disable) a lifecycle tracer across
        the scheduler, every engine core and the hub."""
        self.scheduler.bind_tracer(tracer)

    def snapshot(self) -> Dict[str, Any]:
        """Resolve the unified metrics tree (scheduler / engines / kv /
        router / hub) into one nested dict."""
        return self.obs.snapshot()

    def close(self) -> None:
        """Join background threads (hub staging worker); idempotent."""
        self.scheduler.close()

    def __enter__(self) -> "RoutedServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def submit(self, requests: Sequence[Request]) -> int:
        return self.scheduler.submit(requests)

    def step(self) -> List[Response]:
        return self.scheduler.step()

    def serve(self, requests: Sequence[Request]) -> List[Response]:
        if not requests:
            return []
        got: Dict[int, Response] = {}
        todo = list(requests)
        while todo or self.scheduler.has_work:
            if todo:
                todo = todo[self.scheduler.submit(todo):]
            for r in self.scheduler.step():
                got[r.uid] = r
        return [got[r.uid] for r in requests]

    @property
    def stats(self) -> Dict:
        engines = {self.registry[e].name: self.registry[e].backend.stats
                   for e in range(len(self.registry))
                   if isinstance(self.registry[e].backend, ExpertEngine)}
        banks = {}
        for shard in self.scheduler.shards:
            if not shard.banked:
                continue
            if self.hub is not None:
                label = "hub(%d experts/%d slots)" % (
                    len(self.registry), self.hub.n_slots)
            else:
                label = "bank%d(%s)" % (shard.sid, ",".join(
                    self.registry[e].name for e in shard.experts))
            banks[label] = shard.bank.stats
        out = {"scheduler": self.scheduler.stats,
               "router": self.router.stats if self.router else {},
               "engines": engines, "banks": banks,
               "executor": self.scheduler.executor.name}
        if self.hub is not None:
            out["hub"] = self.hub.stats
        return out
