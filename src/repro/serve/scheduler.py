"""Admission queue + continuous micro-batching scheduler (Fig. 2 as a
serving system).

Life of a request:

  submit() -> Router.route (fingerprint LRU + Pallas scoring)
           -> per-expert FIFO queue, sub-bucketed by prompt-length bucket
  step()   -> admission: per expert, pop the fullest length bucket into
              one micro-batch (up to ``max_batch``) and prefill it into
              the expert's engine
           -> decode: every engine with resident groups advances one
              token (one ``tick``)
           -> harvest: finished rows become Responses immediately
  drain()  -> step() until all queues and engines are empty

Because queues persist across calls, requests submitted in *different*
``submit`` calls coalesce into the same micro-batch — the continuous
part — and because shapes are snapped to the engine's buckets, a mixed
traffic stream compiles a bounded set of executables no matter how many
distinct (prompt length, batch, max_new) combinations arrive.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.matcher import ExpertMatcher
from ..core.registry import ExpertRegistry
from .engine import ExpertEngine, bucket_for
from .router import Router


@dataclasses.dataclass
class Request:
    uid: int
    features: np.ndarray            # (784,) matcher fingerprint
    prompt: np.ndarray              # (S,) int32 tokens
    max_new_tokens: int = 8


@dataclasses.dataclass
class Response:
    uid: int
    expert: str
    fine_class: int
    tokens: np.ndarray
    coarse_scores: Optional[np.ndarray] = None


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 16             # micro-batch row cap
    max_queue: int = 4096           # admission queue cap (backpressure)


@dataclasses.dataclass
class _Pending:
    req: Request
    fine: int
    scores: np.ndarray


class Scheduler:
    """Routes, queues, batches and ticks a fleet of ExpertEngines."""

    def __init__(self, router: Router, registry: ExpertRegistry,
                 config: Optional[SchedulerConfig] = None):
        self.router = router
        self.registry = registry
        self.config = config or SchedulerConfig()
        # queues[expert][len_bucket] -> FIFO of _Pending
        self.queues: Dict[int, Dict[int, collections.deque]] = \
            collections.defaultdict(lambda: collections.defaultdict(
                collections.deque))
        self.n_queued = 0
        self.stats = {"submitted": 0, "rejected": 0, "batches": 0,
                      "ticks": 0, "responses": 0}
        self._done: List[Response] = []
        self._meta: Dict[int, _Pending] = {}   # uid -> routing info

    # -- admission -------------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> int:
        """Route and enqueue; returns how many were admitted — always a
        prefix of ``requests``, so callers can resubmit the tail later.
        Requests beyond the queue cap are rejected unrouted
        (backpressure). uids must be unique among in-flight requests —
        they key response demultiplexing."""
        if not requests:
            return 0
        seen = set(self._meta)
        for r in requests:
            if r.uid in seen:
                raise ValueError(f"duplicate in-flight uid {r.uid}")
            seen.add(r.uid)
        room = max(self.config.max_queue - self.n_queued, 0)
        self.stats["rejected"] += len(requests) - min(len(requests), room)
        requests = requests[:room]
        if not requests:
            return 0
        routed = self.router.route(
            np.stack([r.features for r in requests]))
        admitted = 0
        for i, r in enumerate(requests):
            e = int(routed.coarse[i, 0])
            engine = self.registry[e].backend
            sb = (engine.pad_shape(1, len(r.prompt))[1]
                  if isinstance(engine, ExpertEngine) else len(r.prompt))
            p = _Pending(r, int(routed.fine[i]), routed.coarse_score[i])
            self.queues[e][sb].append(p)
            self._meta[r.uid] = p
            self.n_queued += 1
            admitted += 1
        self.stats["submitted"] += admitted
        return admitted

    # -- one scheduling round -------------------------------------------
    def step(self) -> List[Response]:
        self._admit_batches()
        self._tick_engines()
        self._harvest()
        out, self._done = self._done, []
        self.stats["responses"] += len(out)
        return out

    def drain(self) -> List[Response]:
        out: List[Response] = []
        while self.has_work:
            out.extend(self.step())
        return out

    @property
    def has_work(self) -> bool:
        if self.n_queued:
            return True
        return any(isinstance(self.registry[e].backend, ExpertEngine)
                   and self.registry[e].backend.n_active
                   for e in range(len(self.registry)))

    # -- internals -------------------------------------------------------
    def _admit_batches(self) -> None:
        for e, by_len in self.queues.items():
            if not any(by_len.values()):
                continue
            engine = self.registry[e].backend
            name = self.registry[e].name
            # fullest length bucket first: best padding efficiency
            sb = max(by_len, key=lambda b: len(by_len[b]))
            q = by_len[sb]
            if not q:
                continue
            cap = self.config.max_batch
            if isinstance(engine, ExpertEngine):
                cap = min(cap, engine.batch_buckets[-1])
            take = [q.popleft() for _ in range(min(len(q), cap))]
            self.n_queued -= len(take)
            self.stats["batches"] += 1
            if isinstance(engine, ExpertEngine):
                engine.admit([p.req.uid for p in take],
                             [p.req.prompt for p in take],
                             [p.req.max_new_tokens for p in take])
            elif engine is None:
                for p in take:
                    self._meta.pop(p.req.uid, None)
                    self._done.append(self._response(
                        p, name, np.zeros(p.req.max_new_tokens, np.int32)))
            else:
                # legacy blocking engines: one padded batch call
                m = max(len(p.req.prompt) for p in take)
                toks = np.zeros((len(take), m), np.int32)
                for i, p in enumerate(take):
                    toks[i, :len(p.req.prompt)] = p.req.prompt
                gen = np.asarray(engine.generate(
                    toks, max(p.req.max_new_tokens for p in take)))
                for i, p in enumerate(take):
                    self._meta.pop(p.req.uid, None)
                    self._done.append(self._response(
                        p, name, gen[i, :p.req.max_new_tokens]))

    def _tick_engines(self) -> None:
        for e in range(len(self.registry)):
            engine = self.registry[e].backend
            if isinstance(engine, ExpertEngine) and engine.n_active:
                engine.tick()
                self.stats["ticks"] += 1

    def _harvest(self) -> None:
        for e in range(len(self.registry)):
            engine = self.registry[e].backend
            if not isinstance(engine, ExpertEngine):
                continue
            for uid, toks in engine.poll():
                p = self._meta.pop(uid)
                self._done.append(self._response(
                    p, self.registry[e].name,
                    toks[:p.req.max_new_tokens]))

    def _response(self, p: _Pending, name: str,
                  tokens: np.ndarray) -> Response:
        return Response(uid=p.req.uid, expert=name, fine_class=p.fine,
                        tokens=tokens, coarse_scores=p.scores)


class RoutedServer:
    """ExpertMatcher in front of a fleet of ExpertEngines.

    Seed-compatible façade over Router + Scheduler: ``serve`` is
    submit-then-drain, returning responses in request order. Incremental
    users call ``submit``/``step`` directly for continuous batching.
    """

    def __init__(self, matcher: ExpertMatcher, registry: ExpertRegistry,
                 *, max_batch: int = 16, route_cache_size: int = 4096,
                 use_fine_kernel: bool = True):
        assert len(registry) == matcher.n_experts, "registry/bank mismatch"
        self.matcher = matcher
        self.registry = registry
        self.router = Router(matcher, cache_size=route_cache_size,
                             use_fine_kernel=use_fine_kernel)
        self.scheduler = Scheduler(self.router, registry,
                                   SchedulerConfig(max_batch=max_batch))

    def submit(self, requests: Sequence[Request]) -> int:
        return self.scheduler.submit(requests)

    def step(self) -> List[Response]:
        return self.scheduler.step()

    def serve(self, requests: Sequence[Request]) -> List[Response]:
        if not requests:
            return []
        got: Dict[int, Response] = {}
        todo = list(requests)
        while todo or self.scheduler.has_work:
            if todo:
                todo = todo[self.scheduler.submit(todo):]
            for r in self.scheduler.step():
                got[r.uid] = r
        return [got[r.uid] for r in requests]

    @property
    def stats(self) -> Dict:
        engines = {self.registry[e].name: self.registry[e].backend.stats
                   for e in range(len(self.registry))
                   if isinstance(self.registry[e].backend, ExpertEngine)}
        return {"scheduler": self.scheduler.stats,
                "router": self.router.stats, "engines": engines}
