"""Routing front-end: ExpertMatcher + Pallas kernels + fingerprint cache.

The seed server jitted ``matcher.route`` wholesale, which (a) re-encoded
every sample under *all* K expert AEs for fine assignment and (b) left
the Pallas ``cosine_scores`` kernel dead. This front-end:

  * snaps routing batches to power-of-two row buckets, so the jit cache
    of the scoring functions stays bounded under arbitrary traffic;
  * runs fine assignment per routed-expert *group* — each sample is
    encoded only under its own expert, and the group's (z, centroids,
    mask) triple goes through the fused ``cosine_scores`` kernel
    (interpret mode on CPU, Mosaic on TPU);
  * memoizes routing decisions per client fingerprint in an LRU: clients
    in the paper's setting re-query with the same dataset fingerprint,
    so repeat routes cost a dict lookup instead of K AE forwards.

The coarse metric honours ``MatcherConfig``: ``use_kernel=True`` scores
through the fused Pallas expert-score kernel (with real BN statistics —
see ``ExpertMatcher.coarse_scores``), otherwise the vmapped reference.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import threading
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import autoencoder as ae
from ..core.matcher import ExpertMatcher
from .engine import bucket_for, make_buckets


@dataclasses.dataclass
class RouteResult:
    coarse: np.ndarray        # (B, top_k) expert indices, best first
    coarse_score: np.ndarray  # (B, top_k) scores (lower = better)
    fine: np.ndarray          # (B,) class index within the top-1 expert
    shard: Optional[np.ndarray] = None  # (B,) placement shard ids
    cache_hits: int = 0


class PrefixLRU:
    """Prompt-prefix index: the fingerprint-LRU idiom applied to prompt
    pages instead of client features.

    The paper's cohorts re-query the server with near-identical prompts;
    ``observe`` fingerprints the first KV page of each prompt (shorter
    prompts hash whole) and returns a grouping key. The scheduler uses
    equal keys to co-admit prefix-sharing rows into one wave, which is
    what lets the paged engine deduplicate their prefill and share
    pages; the LRU's repeat counter is the cohort-detection signal
    surfaced in routing stats.
    """

    def __init__(self, page: int = 8, capacity: int = 4096):
        self.page = page
        self.capacity = capacity
        self._lru: "collections.OrderedDict[bytes, int]" = \
            collections.OrderedDict()
        self.stats = {"observed": 0, "repeats": 0}

    def observe(self, prompt: np.ndarray) -> bytes:
        head = np.ascontiguousarray(
            np.asarray(prompt, np.int32)[:self.page]).tobytes()
        key = hashlib.blake2b(head, digest_size=16).digest()
        self.stats["observed"] += 1
        seen = self._lru.pop(key, 0)
        if seen:
            self.stats["repeats"] += 1
        self._lru[key] = seen + 1
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)
        return key


class Router:
    """Batch router with bounded jit shapes and a fingerprint LRU.

    ``shard_of`` (expert index -> shard id, from a ``PlacementPlan``)
    makes every ``RouteResult`` carry the shard serving each row, so the
    scheduler can plan per-shard dispatch groups and responses demux
    back through the right bank. Shard ids are derived from the top-1
    expert *after* the LRU, so cached decisions stay placement-agnostic.
    """

    def __init__(self, matcher: ExpertMatcher, *, cache_size: int = 4096,
                 use_fine_kernel: bool = True, max_rows: int = 256,
                 interpret: bool = True,
                 shard_of: Optional[Dict[int, int]] = None):
        self.matcher = matcher
        self.shard_of = dict(shard_of) if shard_of is not None else None
        self.use_fine_kernel = use_fine_kernel and \
            matcher.centroids is not None
        self.interpret = interpret
        self.row_buckets = make_buckets(1, max_rows)
        self._lru: "collections.OrderedDict[bytes, tuple]" = \
            collections.OrderedDict()
        self.cache_size = cache_size
        self.stats = {"routed": 0, "cache_hits": 0, "score_calls": 0}
        # per-expert top-1 hit counts — the popularity signal the expert
        # hub's eviction policy reads (ExpertHub.bind_popularity shares
        # this very Counter, so routing decisions feed residency).
        # Once hub-bound the Counter is cross-thread shared state:
        # bind_popularity(..., router=self) installs the hub lock here
        # and route() increments under it (rule R001)
        self.expert_hits: collections.Counter = collections.Counter()
        self.hits_lock: Optional[threading.Lock] = None
        self._coarse = jax.jit(matcher.assign_coarse_topk)
        self._fine_ref = jax.jit(matcher.assign_fine)
        # encode a group under ONE expert's AE (params sliced by index)
        self._encode_at = jax.jit(self._encode_at_impl)

    def _encode_at_impl(self, x, e):
        params = jax.tree_util.tree_map(lambda a: a[e],
                                        self.matcher.bank_params)
        state = jax.tree_util.tree_map(lambda a: a[e],
                                       self.matcher.bank_states)
        z, _ = ae.encode(params, state, x, train=False)
        return z

    # ------------------------------------------------------------------
    def _pad_rows(self, x: np.ndarray) -> Tuple[jnp.ndarray, int]:
        n = len(x)
        nb = bucket_for(n, self.row_buckets)
        if nb > n:
            x = np.concatenate([x, np.zeros((nb - n,) + x.shape[1:],
                                            x.dtype)])
        return jnp.asarray(x), n

    def _fine_grouped(self, x: np.ndarray,
                      coarse_top1: np.ndarray) -> np.ndarray:
        """Per-expert-group fine assignment through the cosine kernel."""
        from ..kernels import ops as kops
        m = self.matcher
        fine = np.zeros(len(x), np.int64)
        for e in np.unique(coarse_top1):
            rows = np.nonzero(coarse_top1 == e)[0]
            xg, n = self._pad_rows(x[rows])
            z = self._encode_at(xg, jnp.int32(e))
            sim = kops.cosine_scores(z, m.centroids[int(e)],
                                     m.centroid_mask[int(e)],
                                     interpret=self.interpret)
            fine[rows] = np.asarray(jnp.argmax(sim, axis=-1))[:n]
            self.stats["score_calls"] += 1
        return fine

    # ------------------------------------------------------------------
    def route(self, feats: np.ndarray) -> RouteResult:
        """feats: (B, 784) float32 fingerprints -> routing decisions."""
        feats = np.asarray(feats, np.float32)
        B = len(feats)
        top_k = self.matcher.config.top_k
        coarse = np.zeros((B, top_k), np.int64)
        score = np.zeros((B, top_k), np.float32)
        fine = np.zeros(B, np.int64)

        keys = [f.tobytes() for f in feats]
        miss = []
        hits = 0
        for i, k in enumerate(keys):
            got = self._lru.get(k)
            if got is not None:
                coarse[i], score[i], fine[i] = got
                self._lru.move_to_end(k)
                hits += 1
            else:
                miss.append(i)

        # chunk misses to the largest row bucket so batches beyond it
        # can't mint fresh executable shapes
        step = self.row_buckets[-1]
        for lo in range(0, len(miss), step):
            chunk = miss[lo:lo + step]
            xm = feats[chunk]
            xp, n = self._pad_rows(xm)
            c, s = self._coarse(xp)
            c = np.asarray(c)[:n]
            s = np.asarray(s)[:n]
            if self.use_fine_kernel:
                f = self._fine_grouped(xm, c[:, 0])
            elif self.matcher.centroids is not None:
                f = np.asarray(self._fine_ref(xp, jnp.asarray(
                    np.pad(c[:, 0], (0, len(xp) - n)))))[:n]
            else:
                f = np.zeros(n, np.int64)
            for j, i in enumerate(chunk):
                coarse[i], score[i], fine[i] = c[j], s[j], f[j]
                self._remember(keys[i], (c[j], s[j], f[j]))

        self.stats["routed"] += B
        self.stats["cache_hits"] += hits
        with (self.hits_lock if self.hits_lock is not None
              else contextlib.nullcontext()):
            for e in coarse[:, 0]:
                self.expert_hits[int(e)] += 1
        shard = None
        if self.shard_of is not None:
            shard = np.asarray([self.shard_of.get(int(e), -1)
                                for e in coarse[:, 0]], np.int64)
        return RouteResult(coarse, score, fine, shard=shard,
                           cache_hits=hits)

    def _remember(self, key: bytes, value) -> None:
        # copy: the (c, s) rows arrive as views into a whole routed
        # chunk's (rows, top_k) arrays — caching the views would pin
        # every chunk's full arrays in the LRU for their lifetime
        c, s, f = value
        self._lru[key] = (np.array(c, np.int64), np.array(s, np.float32),
                          int(f))
        if len(self._lru) > self.cache_size:
            self._lru.popitem(last=False)
