"""ExpertEngine: one expert model behind the router, continuous-batching
style.

The seed engine re-ran a blocking prefill+decode loop per ``serve`` call
and let ``jax.jit`` compile a fresh executable for every (batch, pad
length) combination a traffic mix produced. This engine instead:

  * admits work as *groups* (``admit``) whose shapes are snapped to a
    small fixed set of (batch, prompt-length) buckets, so the number of
    distinct XLA executables is bounded by ``len(batch_buckets) *
    len(len_buckets)`` prefills + ``len(batch_buckets)`` decode steps
    for the engine's whole lifetime;
  * keeps admitted groups resident (KV cache + last token) and advances
    every active group exactly one token per ``tick`` — the scheduler
    interleaves ticks across engines, so a long generation on one expert
    never blocks admission or progress elsewhere;
  * donates the decode cache on every step, so XLA reuses the same KV
    buffers in place instead of allocating per token;
  * emits per-row results as soon as a row has its ``max_new_tokens``,
    not when its whole group retires.

Decode executables are shared across prompt buckets because prefill
always builds the cache at ``capacity=max_len``; only the batch bucket
shows up in the decode shape signature.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import BaseModel


def make_buckets(lo: int, hi: int) -> Tuple[int, ...]:
    """Power-of-two ladder covering [lo, hi] (hi always included).

    Raises instead of silently returning ``(hi,)`` when ``lo > hi`` —
    that shape used to make ``ExpertEngine(max_len=4, min_len_bucket=8)``
    build a ladder that ignored ``min_len_bucket`` entirely.
    """
    lo, hi = int(lo), int(hi)
    if lo < 1:
        raise ValueError(f"make_buckets: lo must be >= 1, got {lo}")
    if lo > hi:
        raise ValueError(f"make_buckets: lo {lo} > hi {hi}")
    out = []
    b = lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return tuple(out)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n, clamped to the largest bucket."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class EngineStats:
    prefill_compiles: int = 0
    decode_compiles: int = 0
    prefill_calls: int = 0
    decode_steps: int = 0
    rows_served: int = 0
    rows_padded: int = 0
    tokens_generated: int = 0

    @property
    def jit_cache_entries(self) -> int:
        return self.prefill_compiles + self.decode_compiles


@dataclasses.dataclass
class _Group:
    """One admitted micro-batch resident in the engine."""
    uids: List[Any]                # caller ints or generate() tuples
    per_row_new: List[int]
    cache: Any
    tok: jnp.ndarray               # (Bb, 1) last emitted token
    emitted: List[np.ndarray]      # one (Bb,) column per generated step
    steps_left: int                # decode steps still to run
    done_rows: List[bool]


class ExpertEngine:
    """One expert model with bucketed jit caches and resident groups."""

    def __init__(self, model: BaseModel, params, *, max_len: int = 256,
                 min_len_bucket: int = 8,
                 batch_buckets: Optional[Sequence[int]] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.len_buckets = make_buckets(min_len_bucket, max_len)
        self.batch_buckets = tuple(batch_buckets or make_buckets(1, 16))
        self.stats = EngineStats()
        self._active: List[_Group] = []
        self._finished: List[Tuple[int, np.ndarray]] = []
        self._gen_serial = 0           # private generate() uid namespace
        # shape-keyed executables; dict size == XLA compile count
        self._prefill_fns: Dict[Tuple[int, int], Any] = {}
        self._decode_fns: Dict[int, Any] = {}

    # -- bucketed executables -------------------------------------------
    def _prefill_fn(self, Bb: int, Sb: int):
        key = (Bb, Sb)
        if key not in self._prefill_fns:
            self._prefill_fns[key] = jax.jit(
                lambda p, b: self.model.prefill(p, b, capacity=self.max_len))
            self.stats.prefill_compiles += 1
        return self._prefill_fns[key]

    def _decode_fn(self, Bb: int):
        if Bb not in self._decode_fns:
            self._decode_fns[Bb] = jax.jit(self.model.decode,
                                           donate_argnums=(1,))
            self.stats.decode_compiles += 1
        return self._decode_fns[Bb]

    # -- admission -------------------------------------------------------
    def pad_shape(self, n_rows: int, prompt_len: int) -> Tuple[int, int]:
        """(batch bucket, length bucket) this admission would snap to."""
        return (bucket_for(n_rows, self.batch_buckets),
                bucket_for(prompt_len, self.len_buckets))

    def admit(self, uids: Sequence[int], prompts: Sequence[np.ndarray],
              max_new: Sequence[int]) -> None:
        """Prefill a micro-batch and keep it resident for ticking.

        Prompts are right-truncated to the largest length bucket (keeping
        the most recent tokens) and zero-padded up to their bucket; the
        batch dim is zero-padded to its bucket. Decoding past cache
        capacity is safe: the cache is a position-tracked ring, so the
        oldest context is evicted rather than corrupted.
        """
        assert len(uids) == len(prompts) == len(max_new)
        if len(prompts) > self.batch_buckets[-1]:
            raise ValueError(
                f"micro-batch of {len(prompts)} rows exceeds the largest "
                f"batch bucket {self.batch_buckets[-1]}; split it or "
                f"construct the engine with larger batch_buckets")
        Bb, Sb = self.pad_shape(len(prompts),
                                max(len(p) for p in prompts))
        toks = np.zeros((Bb, Sb), np.int32)
        for i, p in enumerate(prompts):
            p = np.asarray(p, np.int32)[-Sb:]
            toks[i, :len(p)] = p
        per_row = [max(1, int(m)) for m in max_new]
        logits, cache = self._prefill_fn(Bb, Sb)(
            self.params, {"tokens": jnp.asarray(toks)})
        self.stats.prefill_calls += 1
        self.stats.rows_served += len(uids)
        self.stats.rows_padded += Bb - len(uids)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        g = _Group(uids=list(uids), per_row_new=per_row, cache=cache,
                   tok=tok, emitted=[np.asarray(tok)[:, 0]],
                   steps_left=max(per_row) - 1,
                   done_rows=[False] * len(uids))
        self._active.append(g)
        self._harvest(g)
        if g.steps_left <= 0 and all(g.done_rows):
            self._active.remove(g)

    # -- decoding --------------------------------------------------------
    def tick(self) -> int:
        """Advance every active group one decode step. Returns the number
        of groups advanced (0 == engine idle)."""
        advanced = 0
        for g in list(self._active):
            if g.steps_left > 0:
                Bb = g.tok.shape[0]
                logits, g.cache = self._decode_fn(Bb)(
                    self.params, g.cache, {"token": g.tok})
                g.tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
                g.emitted.append(np.asarray(g.tok)[:, 0])
                g.steps_left -= 1
                self.stats.decode_steps += 1
                advanced += 1
            self._harvest(g)
            if g.steps_left <= 0 and all(g.done_rows):
                self._active.remove(g)
        return advanced

    def _harvest(self, g: _Group) -> None:
        """Emit rows whose max_new tokens are all available."""
        have = len(g.emitted)
        for i, uid in enumerate(g.uids):
            if not g.done_rows[i] and g.per_row_new[i] <= have:
                seq = np.asarray([col[i] for col in
                                  g.emitted[:g.per_row_new[i]]], np.int32)
                self._finished.append((uid, seq))
                self.stats.tokens_generated += len(seq)
                g.done_rows[i] = True

    def poll(self) -> List[Tuple[int, np.ndarray]]:
        """Drain finished (uid, tokens) pairs."""
        out, self._finished = self._finished, []
        return out

    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def has_pending(self) -> bool:
        """Still decoding, or holding finished rows not yet polled —
        the latter matters when an interleaved ``generate`` call ticked
        another owner's group to completion and re-queued its rows."""
        return bool(self._active or self._finished)

    # -- blocking convenience (seed-API compatible) ----------------------
    def generate(self, tokens, max_new: int,
                 extra_inputs: Optional[Dict] = None) -> np.ndarray:
        """Greedy generation. tokens: (B, S) int32 -> (B, max_new).

        Safe to interleave with scheduler-owned ``admit``/``tick``/
        ``poll`` traffic: rows are admitted under a private uid
        namespace (tuples can never collide with caller-issued int
        uids), and only *this call's* rows are consumed from ``poll`` —
        any other engine's finished rows drained along the way are put
        back for their owner.
        """
        del extra_inputs  # stub-embed models are not served token-only
        toks = np.asarray(tokens)
        self._gen_serial += 1
        uids = [("__generate__", self._gen_serial, i)
                for i in range(len(toks))]
        self.admit(uids, list(toks), [max_new] * len(toks))
        want = set(uids)
        rows: Dict[Any, np.ndarray] = {}
        stash: List[Tuple[Any, np.ndarray]] = []

        def drain():
            for uid, seq in self.poll():
                if uid in want:
                    rows[uid] = seq
                else:
                    stash.append((uid, seq))

        try:
            drain()
            while len(rows) < len(uids):
                self.tick()
                drain()
        finally:
            # hand foreign rows back even if a tick raised, or their
            # owners would never see them (has_pending goes false)
            self._finished.extend(stash)
        return np.stack([rows[u] for u in uids])
