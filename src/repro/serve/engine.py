"""ExpertEngine: one expert model behind the router — the E=1 shim over
the shared ``EngineCore``.

PR 1 built this engine's residency/bucketing/harvest machinery inline;
PR 2 duplicated it for ``BankedEngine`` with the two copies kept aligned
by equivalence tests. Both now delegate to ``serve.core.EngineCore``
(this class is the single-expert view: params stacked to a leading axis
of one, waves carry exactly one local expert), which also moved the
decode hot path off the host: tokens stay on device and the only
blocking transfer is the batched one inside ``harvest()``. The
``defer`` flag on ``admit``/``tick`` selects between the blocking
reference behaviour (default — the seed-compatible API) and the
enqueue-only path the overlapped dispatch executor drives.

What the engine still guarantees (see ``EngineCore`` for mechanics):

  * admissions snap to (batch, prompt-length) buckets, so the number of
    distinct XLA executables is bounded by the bucket-ladder product for
    the engine's whole lifetime — and the bound is now asserted against
    *real* executable counts (``_cache_size``), not wrapper creations;
  * admitted groups stay resident (KV cache + last token) and advance
    one token per ``tick`` — the scheduler interleaves ticks across
    engines, so a long generation on one expert never blocks progress
    elsewhere;
  * the decode cache is donated every step, so XLA reuses the same KV
    buffers in place;
  * per-row results are emitted as soon as a row has its
    ``max_new_tokens``, not when its whole group retires.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.registry import ExpertSpec
from ..models.api import BaseModel
from .core import EngineCore, EngineStats, bucket_for, make_buckets

__all__ = ["ExpertEngine", "EngineStats", "bucket_for", "make_buckets"]


class ExpertEngine:
    """One expert model with bucketed jit caches and resident groups."""

    def __init__(self, model: BaseModel, params, *, max_len: int = 256,
                 min_len_bucket: int = 8,
                 batch_buckets: Optional[Sequence[int]] = None,
                 kv_layout: str = "ring", page_size: int = 8,
                 pool_pages: Optional[int] = None,
                 chunk_len: Optional[int] = None,
                 speculate_k: int = 0, draft=None):
        self.core = EngineCore(model, [params], max_len=max_len,
                               min_len_bucket=min_len_bucket,
                               batch_buckets=batch_buckets,
                               kv_layout=kv_layout, page_size=page_size,
                               pool_pages=pool_pages, chunk_len=chunk_len,
                               speculate_k=speculate_k, draft=draft)
        self.model = model
        # the caller's unstacked params: plan_placement restacks these
        # into a BankedEngine, so the E=1 leading axis must not leak out
        self.params = params
        self.max_len = self.core.max_len
        self.len_buckets = self.core.len_buckets
        self.batch_buckets = self.core.batch_buckets
        self.kv_layout = self.core.kv_layout
        self._gen_serial = 0           # private generate() uid namespace

    @property
    def stats(self) -> EngineStats:
        return self.core.stats

    def bind_tracer(self, tracer) -> None:
        """Install a lifecycle tracer on the core (None disables).
        Device spans open at admit/tick and close only at the core's
        harvest sync points — tracing adds no host blocks."""
        self.core.bind_tracer(tracer)

    @property
    def spec(self) -> ExpertSpec:
        """The shared catalog entry type describing this engine
        (``core.registry.ExpertSpec``): what the placement planner
        groups banks by and the expert hub keys slot compatibility on."""
        return ExpertSpec.of_engine(self)

    # -- admission -------------------------------------------------------
    def pad_shape(self, n_rows: int, prompt_len: int) -> Tuple[int, int]:
        """(batch bucket, length bucket) this admission would snap to."""
        return self.core.pad_shape(n_rows, prompt_len)

    def admit(self, uids: Sequence[int], prompts: Sequence[np.ndarray],
              max_new: Sequence[int], *, defer: bool = False) -> None:
        """Prefill a micro-batch and keep it resident for ticking.

        Empty micro-batches are rejected up front (previously a bare
        ``ValueError`` escaped from ``max()`` deep inside padding).
        ``defer=True`` enqueues only — see ``EngineCore.admit_wave``.
        """
        assert len(uids) == len(prompts) == len(max_new)
        if not len(uids):
            raise ValueError(
                "ExpertEngine.admit: empty micro-batch (0 rows); admit "
                "at least one row or skip the call")
        self.core.admit_wave(
            {0: (list(uids), list(prompts), list(max_new))}, defer=defer)

    # -- decoding --------------------------------------------------------
    def tick(self, *, defer: bool = False) -> int:
        """Advance every active group one decode step. Returns the number
        of groups advanced (0 == engine idle)."""
        return self.core.tick(defer=defer)

    def harvest(self) -> None:
        """Materialise (one batched transfer per wave) and emit every
        row whose tokens are all available; retire finished groups."""
        self.core.harvest()

    def poll(self) -> List[Tuple[int, np.ndarray]]:
        """Drain finished (uid, tokens) pairs."""
        return [(uid, seq) for _local, uid, seq in self.core.poll()]

    @property
    def n_active(self) -> int:
        return self.core.n_active

    @property
    def has_pending(self) -> bool:
        """Still decoding, or holding finished rows not yet polled —
        the latter matters when an interleaved ``generate`` call ticked
        another owner's group to completion and re-queued its rows."""
        return self.core.has_pending

    # -- blocking convenience (seed-API compatible) ----------------------
    def generate(self, tokens, max_new: int,
                 extra_inputs: Optional[Dict] = None) -> np.ndarray:
        """Greedy generation. tokens: (B, S) int32 -> (B, max_new).

        A zero-row batch short-circuits to an empty ``(0, max_new)``
        array (admitting nothing). Safe to interleave with
        scheduler-owned ``admit``/``tick``/``poll`` traffic: rows are
        admitted under a private uid namespace (tuples can never collide
        with caller-issued int uids), and only *this call's* rows are
        consumed from ``poll`` — any other engine's finished rows
        drained along the way are put back for their owner.
        """
        del extra_inputs  # stub-embed models are not served token-only
        toks = np.asarray(tokens)
        if len(toks) == 0:
            return np.zeros((0, max(1, int(max_new))), np.int32)
        self._gen_serial += 1
        uids = [("__generate__", self._gen_serial, i)
                for i in range(len(toks))]
        self.admit(uids, list(toks), [max_new] * len(toks))
        want = set(uids)
        rows: Dict[Any, np.ndarray] = {}
        stash: List[Tuple[Any, np.ndarray]] = []

        def drain():
            for uid, seq in self.poll():
                if uid in want:
                    rows[uid] = seq
                else:
                    stash.append((uid, seq))

        try:
            drain()
            while len(rows) < len(uids):
                self.tick()
                drain()
        finally:
            # hand foreign rows back even if a tick raised, or their
            # owners would never see them (has_pending goes false)
            self.core._finished.extend(
                (0, uid, seq) for uid, seq in stash)
        return np.stack([rows[u] for u in uids])
