"""Serving substrate: per-expert engines + the ExpertMatcher-routed server.

ExpertEngine wraps one zoo model with jitted prefill/decode and a KV/state
cache; RoutedServer is the paper's Fig. 2 pipeline as a serving system:

  payload -> featurize (784) -> ExpertMatcher.route -> per-expert batch
          -> engine.generate -> responses

Requests are grouped per routed expert and executed as padded batches
(static shapes for jit); the router itself is a jitted bank scoring —
the Pallas ``expert_score`` kernel on real TPUs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.matcher import ExpertMatcher
from ..core.registry import ExpertRegistry
from ..models.api import BaseModel


@dataclasses.dataclass
class Request:
    uid: int
    features: np.ndarray            # (784,) matcher fingerprint
    prompt: np.ndarray              # (S,) int32 tokens
    max_new_tokens: int = 8


@dataclasses.dataclass
class Response:
    uid: int
    expert: str
    fine_class: int
    tokens: np.ndarray
    coarse_scores: Optional[np.ndarray] = None


class ExpertEngine:
    """One expert model behind the router."""

    def __init__(self, model: BaseModel, params, *, max_len: int = 256):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, capacity=max_len))
        self._decode = jax.jit(model.decode, donate_argnums=(1,))

    def generate(self, tokens: jnp.ndarray, max_new: int,
                 extra_inputs: Optional[Dict] = None) -> np.ndarray:
        """Greedy generation. tokens: (B, S) int32 -> (B, max_new)."""
        batch = {"tokens": tokens}
        if extra_inputs:
            batch.update(extra_inputs)
        logits, cache = self._prefill(self.params, batch)
        outs = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        for _ in range(max_new):
            outs.append(tok)
            logits, cache = self._decode(self.params, cache, {"token": tok})
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return np.asarray(jnp.concatenate(outs, axis=1))


class RoutedServer:
    """ExpertMatcher in front of a fleet of ExpertEngines."""

    def __init__(self, matcher: ExpertMatcher, registry: ExpertRegistry,
                 *, max_batch: int = 16):
        assert len(registry) == matcher.n_experts, "registry/bank mismatch"
        self.matcher = matcher
        self.registry = registry
        self.max_batch = max_batch
        self._route = jax.jit(matcher.route)

    def serve(self, requests: Sequence[Request]) -> List[Response]:
        if not requests:
            return []
        feats = jnp.asarray(np.stack([r.features for r in requests]))
        routed = self._route(feats)
        coarse = np.asarray(routed["coarse"])[:, 0]
        fine = np.asarray(routed["fine"])
        scores = np.asarray(routed["coarse_score"])

        responses: List[Response] = [None] * len(requests)  # type: ignore
        # group by expert, run padded batches
        for e in range(self.matcher.n_experts):
            idxs = [i for i, c in enumerate(coarse) if c == e]
            if not idxs:
                continue
            engine = self.registry[e].backend
            name = self.registry[e].name
            for lo in range(0, len(idxs), self.max_batch):
                chunk = idxs[lo:lo + self.max_batch]
                toks, pad_to = _pad_prompts([requests[i].prompt
                                             for i in chunk])
                max_new = max(requests[i].max_new_tokens for i in chunk)
                if engine is not None:
                    gen = engine.generate(jnp.asarray(toks), max_new)
                else:
                    gen = np.zeros((len(chunk), max_new), np.int32)
                for row, i in enumerate(chunk):
                    responses[i] = Response(
                        uid=requests[i].uid, expert=name,
                        fine_class=int(fine[i]),
                        tokens=gen[row, :requests[i].max_new_tokens],
                        coarse_scores=scores[i])
        return responses


def _pad_prompts(prompts: List[np.ndarray]):
    """Left-align, zero-pad to a common power-of-two-ish length."""
    m = max(len(p) for p in prompts)
    pad_to = max(8, 1 << (m - 1).bit_length())
    out = np.zeros((len(prompts), pad_to), np.int32)
    for i, p in enumerate(prompts):
        out[i, :len(p)] = p
    return out, pad_to
