"""EngineCore: the shared residency / bucketed-jit / harvest machinery
behind every expert engine, plus the dispatch executors.

PR 2 left ``ExpertEngine`` and ``BankedEngine`` as two parallel
implementations of the same machinery (bucket snapping, resident
groups, per-row harvest, bounded jit caches), kept aligned only by the
equivalence tests — and both forced a device→host copy of the sampled
token on *every* decode tick, blocking JAX's async dispatch before the
next shard's work could even be issued. This module unifies and
de-syncs that hot path:

  * ``EngineCore`` serves E >= 1 experts whose params are stacked on a
    leading ``expert`` axis; prefill/decode are ``vmap`` over that axis
    (optionally GSPMD-sharded over a 1-D ``expert`` mesh), jitted once
    per (batch bucket, len bucket) for the whole core. ``ExpertEngine``
    is the E=1 shim, ``BankedEngine`` the E=K shim — one implementation,
    no equivalence-by-test.
  * a tick **enqueues** device work and keeps the sampled token on
    device: ``wave.tok`` stays a ``jnp.ndarray`` and emitted columns
    accumulate as device buffers. Nothing blocks until ``harvest()``,
    which materialises all planes a completable row needs in **one**
    batched device→host transfer per wave per step (instead of one per
    tick per group).
  * every host-blocking materialisation increments
    ``EngineStats.host_blocks`` — the CI-stable sync counter the bench
    and tests assert against (overlapped must block strictly less often
    per decoded token than serial).
  * ``EngineStats.prefill_compiles`` / ``decode_compiles`` count real
    XLA executables via each jit wrapper's ``_cache_size()``, not
    wrapper creations — a wrapper that silently recompiled (shape/dtype
    drift inside one bucket) now shows up in the bounded-compile
    invariant instead of hiding behind a stale Python-side counter.

The dispatch executors decide *when* the host blocks:

  * ``SerialExecutor`` — the reference: each tick materialises its
    token immediately (today's per-tick sync), shard after shard.
  * ``OverlappedExecutor`` — issues prefills and decode ticks for all
    shards before blocking on anything, then runs one batched harvest;
    prefill of one shard overlaps decode of another on the device
    queue.

Both orders produce token-identical results (the compute graph is the
same; only sync placement differs) — asserted property-style in
``tests/test_serving.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..obs.trace import NULL_TRACER
from ..sharding import leading_sharding
from .draft import build_draft
from .kvcache import PagePool, PagePoolExhausted, PrefixCache, hash_chain


# ---------------------------------------------------------------------------
# Shape buckets
# ---------------------------------------------------------------------------


def make_buckets(lo: int, hi: int) -> Tuple[int, ...]:
    """Power-of-two ladder covering [lo, hi] (hi always included).

    Raises instead of silently returning ``(hi,)`` when ``lo > hi`` —
    that shape used to make ``ExpertEngine(max_len=4, min_len_bucket=8)``
    build a ladder that ignored ``min_len_bucket`` entirely.
    """
    lo, hi = int(lo), int(hi)
    if lo < 1:
        raise ValueError(f"make_buckets: lo must be >= 1, got {lo}")
    if lo > hi:
        raise ValueError(f"make_buckets: lo {lo} > hi {hi}")
    out = []
    b = lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return tuple(out)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n, clamped to the largest bucket."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


# ---------------------------------------------------------------------------
# Stats
# ---------------------------------------------------------------------------


def _probe_cache_size() -> bool:
    try:
        return callable(getattr(jax.jit(lambda: 0), "_cache_size"))
    except Exception:
        return False


# ``_cache_size`` is a private jax API (present on the pinned 0.4.37);
# probe once at import so a build without it degrades *visibly* — the
# compile counters revert to one-count-per-wrapper and tests/tools that
# need exact semantics check this flag instead of silently passing.
COMPILE_COUNTER_EXACT = _probe_cache_size()


def _wrapper_compiles(fn) -> int:
    """Real XLA executables behind one jit wrapper.

    ``_cache_size()`` is the C++ pjit cache entry count — it grows when
    a wrapper recompiles for a signature the Python-side bucket key
    didn't capture (cache dtype/shape drift), which a
    one-count-per-wrapper scheme silently missed. On jax builds without
    the API (``COMPILE_COUNTER_EXACT`` False) this falls back to 1 per
    wrapper — the pre-refactor upper-bound semantics.
    """
    if not COMPILE_COUNTER_EXACT:
        return 1
    try:
        return int(fn._cache_size())
    except TypeError:
        return 1


class EngineStats:
    """Serving counters for one ``EngineCore``.

    ``prefill_compiles`` / ``decode_compiles`` are *live* properties
    summing real executable counts over the core's jit wrappers (see
    ``_wrapper_compiles``); the rest are plain counters.
    ``host_blocks`` counts host-blocking device→host materialisations —
    the sync counter the overlapped-dispatch invariants assert against.
    """

    def __init__(self, core: Optional["EngineCore"] = None):
        self._core = core
        self.prefill_calls = 0
        self.decode_steps = 0
        self.rows_served = 0
        self.rows_padded = 0
        self.tokens_generated = 0
        self.host_blocks = 0
        # prefill-compute accounting (the shared-prefix savings signal):
        # submitted counts every prompt token clients sent; computed
        # counts Sb per row that actually went through a prefill
        # dispatch — rows deduplicated in-wave or fully served from the
        # prefix cache contribute zero
        self.prefill_tokens_submitted = 0
        self.prefill_tokens_computed = 0
        self.prefill_rows_computed = 0
        self.prefix_full_hits = 0       # rows skipped via cross-wave cache
        self.prefix_dup_rows = 0        # rows deduplicated inside a wave
        self.prefix_pages_shared = 0    # page refs shared instead of built
        self.pages_copied = 0           # copy-on-write page copies
        # speculative decoding: drafted counts k per verified row,
        # accepted counts the matched greedy prefix (<= k); fallback
        # waves wanted to speculate but hit the no-wrap/chunk gate
        self.verify_steps = 0
        self.tokens_drafted = 0
        self.tokens_accepted = 0
        self.spec_fallback_waves = 0

    @property
    def prefill_compiles(self) -> int:
        if self._core is None:
            return 0
        return sum(_wrapper_compiles(fn)
                   for fn in self._core._prefill_fns.values())

    @property
    def decode_compiles(self) -> int:
        if self._core is None:
            return 0
        return sum(_wrapper_compiles(fn)
                   for fn in self._core._decode_fns.values())

    @property
    def suffix_compiles(self) -> int:
        if self._core is None:
            return 0
        return sum(_wrapper_compiles(fn)
                   for fn in self._core._suffix_fns.values())

    @property
    def verify_compiles(self) -> int:
        if self._core is None:
            return 0
        return sum(_wrapper_compiles(fn)
                   for fn in self._core._verify_fns.values())

    @property
    def acceptance_rate(self) -> float:
        if not self.tokens_drafted:
            return 0.0
        return self.tokens_accepted / self.tokens_drafted

    @property
    def jit_cache_entries(self) -> int:
        return (self.prefill_compiles + self.suffix_compiles
                + self.decode_compiles + self.verify_compiles)

    def as_dict(self) -> Dict[str, Any]:
        """Every counter plus the live compile properties — the shape
        the unified metrics registry snapshots (one engine = one leaf
        group in the tree)."""
        return {
            "prefill_calls": self.prefill_calls,
            "decode_steps": self.decode_steps,
            "rows_served": self.rows_served,
            "rows_padded": self.rows_padded,
            "tokens_generated": self.tokens_generated,
            "host_blocks": self.host_blocks,
            "prefill_tokens_submitted": self.prefill_tokens_submitted,
            "prefill_tokens_computed": self.prefill_tokens_computed,
            "prefill_rows_computed": self.prefill_rows_computed,
            "prefix_full_hits": self.prefix_full_hits,
            "prefix_dup_rows": self.prefix_dup_rows,
            "prefix_pages_shared": self.prefix_pages_shared,
            "pages_copied": self.pages_copied,
            "verify_steps": self.verify_steps,
            "tokens_drafted": self.tokens_drafted,
            "tokens_accepted": self.tokens_accepted,
            "acceptance_rate": self.acceptance_rate,
            "spec_fallback_waves": self.spec_fallback_waves,
            "prefill_compiles": self.prefill_compiles,
            "suffix_compiles": self.suffix_compiles,
            "decode_compiles": self.decode_compiles,
            "verify_compiles": self.verify_compiles,
            "jit_cache_entries": self.jit_cache_entries,
        }

    def __repr__(self) -> str:
        return (f"EngineStats(prefill_compiles={self.prefill_compiles}, "
                f"decode_compiles={self.decode_compiles}, "
                f"prefill_calls={self.prefill_calls}, "
                f"decode_steps={self.decode_steps}, "
                f"rows_served={self.rows_served}, "
                f"rows_padded={self.rows_padded}, "
                f"tokens_generated={self.tokens_generated}, "
                f"host_blocks={self.host_blocks}, "
                f"prefill_tokens={self.prefill_tokens_computed}/"
                f"{self.prefill_tokens_submitted}, "
                f"prefix_hits={self.prefix_full_hits}+"
                f"{self.prefix_dup_rows}dup)")


# ---------------------------------------------------------------------------
# Core
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Wave:
    """One admitted (E, Bb) micro-batch wave resident in the core.

    ``emitted`` holds one (E, Bb) token plane per generated step; planes
    start life as device buffers and are swapped for host arrays by
    ``_materialize`` — ``n_host`` is the already-materialised prefix.

    Ring waves own a dense ``cache``; paged waves instead carry a page
    ``table`` into the core's shared pool plus the wave's ``pos``/``t``
    tracking (lockstep rows share positions, only physical storage is
    per-row), the pages each row must release at retirement, and the
    prefix chains to register in the cross-wave cache.
    """
    uids: Dict[int, List[Any]]          # local expert -> row uids
    per_row_new: Dict[int, List[int]]
    done: Dict[int, List[bool]]
    cache: Any
    tok: Optional[jnp.ndarray]          # (E, Bb, 1) last sampled token;
    #   None while prefill chunks are still pending (decode is gated)
    emitted: List[Any]                  # (E, Bb) planes, device or host
    steps_left: int
    n_host: int = 0                     # emitted[:n_host] are host arrays
    # paged-layout fields (None / empty on ring waves)
    table: Optional[jnp.ndarray] = None      # (E, Bb, n_logical) int32
    pos: Optional[jnp.ndarray] = None        # (E, C) slot positions
    t: Optional[jnp.ndarray] = None          # (E,) next write position
    pages_held: Dict[int, List[List[int]]] = \
        dataclasses.field(default_factory=dict)
    register: List[Tuple[int, int, int, List[bytes], List[int]]] = \
        dataclasses.field(default_factory=list)
    #   ^ (local, row, padded_len, chain, pages) to insert at retirement
    # chunked-prefill fields (empty / None on unchunked waves): each
    # pending descriptor is one not-yet-dispatched prefill chunk; the
    # chunk cursor is implicit — descriptors are dispatched FIFO, and
    # the wave's first token (and decode eligibility) materialises only
    # when the last chunk lands (see EngineCore._finalize_wave)
    pending_chunks: List[Dict[str, Any]] = \
        dataclasses.field(default_factory=list)
    finalize: Optional[Dict[str, Any]] = None
    _tok_c: Optional[jnp.ndarray] = None     # last chunk's packed argmax
    # speculative-decoding fields (inert on plain waves). Spec waves
    # advance rows at *different* rates, so they carry per-row
    # ``row_pos``/``row_t`` instead of the shared pos/t planes; ``cap``
    # freezes a row once it has written every token it must emit; each
    # verify tick appends an (emit, adv, acc) device triple to
    # ``spec_pending``, drained by ``_materialize_spec`` into the host
    # per-row token buffer ``host_buf`` (column 0 is the prefill token).
    spec: bool = False
    row_pos: Optional[jnp.ndarray] = None    # (E, Bb, C) per-row slots
    row_t: Optional[jnp.ndarray] = None      # (E, Bb) per-row write pos
    cap: Optional[jnp.ndarray] = None        # (E, Bb) freeze position
    spec_pending: List[Any] = dataclasses.field(default_factory=list)
    host_buf: Optional[np.ndarray] = None    # (E, Bb, 1 + steps) int32
    host_fill: Optional[np.ndarray] = None   # (E, Bb) tokens in host_buf
    spec_seeded: bool = False                # host_buf column 0 written
    # tracing (inert under NULL_TRACER): the wave's id in the trace and
    # the open device-span handles, begun at enqueue and ended only
    # inside _materialize/_materialize_spec — the existing sync sites —
    # so tracing never adds a host block (rule O002)
    wave_id: int = 0
    sp_prefill: Any = None
    sp_decode: Any = None


class EngineCore:
    """E homogeneous experts: bucketed executables, resident waves,
    device-side token state, batched harvest.

    Admission and decode *enqueue* work; the only host-blocking points
    are ``_materialize`` calls — per tick in sync mode (``defer=False``,
    the serial reference and the seed-compatible blocking API), or one
    batched transfer per wave inside ``harvest()`` in deferred mode.
    """

    def __init__(self, model, params_list: Sequence[Any], *,
                 max_len: int = 256, min_len_bucket: int = 8,
                 len_buckets: Optional[Sequence[int]] = None,
                 batch_buckets: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None,
                 kv_layout: str = "ring", page_size: int = 8,
                 pool_pages: Optional[int] = None,
                 prefix_cache_size: int = 1024,
                 chunk_len: Optional[int] = None,
                 speculate_k: int = 0, draft=None):
        if not params_list:
            raise ValueError("EngineCore needs at least one expert")
        if kv_layout not in ("ring", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}; expected "
                             "'ring' or 'paged'")
        self.model = model
        self.n_experts = len(params_list)
        self.max_len = max_len
        self.len_buckets = tuple(len_buckets) if len_buckets else \
            make_buckets(min_len_bucket, max_len)
        self.batch_buckets = tuple(batch_buckets or make_buckets(1, 16))
        if mesh is not None and (
                "expert" not in mesh.shape
                or self.n_experts % mesh.shape["expert"]):
            raise ValueError(
                f"mesh expert axis {dict(mesh.shape)} must divide the "
                f"bank's {self.n_experts} experts")
        self.mesh = mesh if (mesh is not None
                             and mesh.shape.get("expert", 1) > 1) else None
        self.stats = EngineStats(self)
        # lifecycle tracing; rebound by the scheduler (bind_tracer) when
        # the server carries a live tracer. Under NULL_TRACER every
        # call below is a no-op (begin_device returns None).
        self.tracer = NULL_TRACER
        self._active: List[_Wave] = []
        self._finished: List[Tuple[int, Any, np.ndarray]] = []
        # shape-keyed jit wrappers; real executable counts come from
        # each wrapper's _cache_size() (see EngineStats)
        self._prefill_fns: Dict[Tuple[int, int], Any] = {}
        self._suffix_fns: Dict[Tuple[int, int], Any] = {}  # (Bb, chunk k)
        self._decode_fns: Dict[int, Any] = {}
        self._verify_fns: Dict[Tuple[int, int], Any] = {}  # (Bb, k)
        self._copy_fns: Dict[int, Any] = {}     # COW page-copy, by count
        params = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                        *params_list)
        if self.mesh is not None:
            sh = leading_sharding(params, "expert", self.mesh)
            params = jax.device_put(params, sh)
        self.params = params
        # -- paged KV state (None in ring layout) ------------------------
        self.kv_layout = kv_layout
        self.pool: Optional[PagePool] = None
        self.prefix_cache: Optional[PrefixCache] = None
        self.kv_pool = None                  # {k, v}: (E, P1, L, page, ...)
        if kv_layout == "paged":
            if not model.supports_paged_kv:
                raise ValueError(
                    f"model family {model.cfg.family!r} does not "
                    "implement the paged KV cache protocol; use "
                    "kv_layout='ring'")
            self.page = int(page_size)
            bad = [b for b in (*self.len_buckets, self.max_len)
                   if b % self.page]
            if bad:
                raise ValueError(
                    f"paged layout needs every length bucket to be a "
                    f"multiple of page_size={self.page}; offending "
                    f"buckets {bad} (prefills must fill whole pages so "
                    "prefix-shared pages are never partially written)")
            self.n_logical = self.max_len // self.page
            per_expert = int(pool_pages) if pool_pages else \
                3 * self.batch_buckets[-1] * self.n_logical
            self.pool = PagePool(self.n_experts, per_expert, self.page)
            self.prefix_cache = PrefixCache(self.pool,
                                            capacity=prefix_cache_size)
            shape = jax.eval_shape(
                lambda: model.init_paged_pool(per_expert, self.page))
            kv = jax.tree_util.tree_map(
                lambda s: jnp.zeros((self.n_experts,) + s.shape, s.dtype),
                shape)
            if self.mesh is not None:
                kv = jax.device_put(
                    kv, leading_sharding(kv, "expert", self.mesh))
            self.kv_pool = kv
        # -- chunked prefill geometry (paged only) -----------------------
        self.chunk_len: Optional[int] = None
        if chunk_len is not None:
            cl = int(chunk_len)
            if kv_layout != "paged":
                raise ValueError("chunk_len requires kv_layout='paged' "
                                 "(suffix prefill attends over pool pages)")
            if cl % self.page:
                raise ValueError(
                    f"chunk_len={cl} must be a multiple of "
                    f"page_size={self.page}")
            if self.max_len % cl:
                raise ValueError(
                    f"max_len={self.max_len} must be a multiple of "
                    f"chunk_len={cl} (the suffix ladder tiles max_len)")
            if cl not in self.len_buckets:
                raise ValueError(
                    f"chunk_len={cl} must itself be a length bucket "
                    f"(got buckets {self.len_buckets}) — chunk 0 reuses "
                    "the monolithic prefill executable at that bucket")
            bad = [b for b in self.len_buckets if b > cl and b % cl]
            if bad:
                raise ValueError(
                    f"length buckets above chunk_len must be multiples "
                    f"of chunk_len={cl}; offending buckets {bad} (every "
                    "padded prompt must split into whole chunks)")
            self.chunk_len = cl
        # -- speculative decoding ----------------------------------------
        self.speculate_k = int(speculate_k)
        self.draft = None
        self.draft_name: Optional[str] = None
        self.draft_state = None
        if self.speculate_k < 0:
            raise ValueError(f"speculate_k must be >= 0, got "
                             f"{self.speculate_k}")
        if self.speculate_k:
            if not model.supports_verify:
                raise ValueError(
                    f"model family {model.cfg.family!r} does not "
                    "implement the speculative verify protocol; use "
                    "speculate_k=0")
            d = draft if draft is not None else "mlp"
            if isinstance(d, str):
                d = build_draft(d, int(model.cfg.padded_vocab))
            self.draft = d
            self.draft_name = d.name
            # draft state is ENGINE-level (leading E axis, bank-sharded
            # like params): it threads through every verify dispatch, so
            # an online draft keeps learning across waves
            st = d.init_state(jax.random.PRNGKey(0), self.n_experts)
            if self.mesh is not None:
                st = jax.device_put(
                    st, leading_sharding(st, "expert", self.mesh))
            self.draft_state = st
        elif draft is not None:
            raise ValueError("draft requires speculate_k > 0")

    # -- sharded/bucketed executables -----------------------------------
    def _bank_sharding(self):
        """Prefix sharding for any expert-leading pytree (or None)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P("expert"))

    def _prefill_fn(self, Bb: int, Sb: int):
        key = (Bb, Sb)
        if key not in self._prefill_fns:
            s = self._bank_sharding()
            if self.kv_layout == "paged":
                # (params, {tokens}, kv_pool, scatter_tbl) ->
                # (logits, kv_pool'); the pool buffers are donated so
                # XLA scatters the new pages in place
                fn = jax.vmap(
                    lambda p, b, pool, tbl: self.model.paged_prefill(
                        p, b, pool, tbl, page=self.page,
                        capacity=self.max_len)[:2])
                if s is not None:
                    jitted = jax.jit(fn, in_shardings=(s, s, s, s),
                                     out_shardings=(s, s),
                                     donate_argnums=(2,))
                else:
                    jitted = jax.jit(fn, donate_argnums=(2,))
            else:
                fn = jax.vmap(lambda p, b: self.model.prefill(
                    p, b, capacity=self.max_len))
                if s is not None:
                    jitted = jax.jit(fn, in_shardings=(s, s),
                                     out_shardings=(s, s))
                else:
                    jitted = jax.jit(fn)
            self._prefill_fns[key] = jitted
        return self._prefill_fns[key]

    def _suffix_fn(self, Bb: int, k: int):
        """Suffix-prefill executable for chunk index ``k >= 1``: computes
        exactly ``chunk_len`` tokens at static offset ``k * chunk_len``,
        attending over the prefix pages already resident in the pool.
        Keyed (Bb, k) so the ladder is bounded by
        ``(max(len_buckets) // chunk_len - 1) * len(batch_buckets)``."""
        key = (Bb, k)
        if key not in self._suffix_fns:
            s = self._bank_sharding()
            offset = k * self.chunk_len
            # (params, {tokens}, kv_pool, prefix_tbl, scatter_tbl) ->
            # (logits, kv_pool'); pool donated as in _prefill_fn
            fn = jax.vmap(
                lambda p, b, pool, ptbl, stbl:
                self.model.paged_prefill_suffix(
                    p, b, pool, ptbl, stbl, offset=offset,
                    page=self.page))
            if s is not None:
                jitted = jax.jit(fn, in_shardings=(s, s, s, s, s),
                                 out_shardings=(s, s),
                                 donate_argnums=(2,))
            else:
                jitted = jax.jit(fn, donate_argnums=(2,))
            self._suffix_fns[key] = jitted
        return self._suffix_fns[key]

    def bind_tracer(self, tracer) -> None:
        """Install a lifecycle tracer (None restores NULL_TRACER). The
        core only *opens* device spans at enqueue points and closes
        them inside its existing sync sites, so binding a live tracer
        cannot change ``stats.host_blocks``."""
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def executable_bounds(self) -> Dict[str, int]:
        """Steady-state executable-count bound per wrapper family.

        With chunking enabled, monolithic prefill executables only exist
        for length buckets <= chunk_len (longer prompts go through the
        chunk ladder), and the suffix ladder adds one executable per
        (batch bucket, chunk index >= 1) pair. The H004 gate and the
        serving bench assert the live counts against exactly this."""
        nB = len(self.batch_buckets)
        if self.chunk_len:
            prefill = nB * sum(1 for b in self.len_buckets
                               if b <= self.chunk_len)
            # deepest reachable chunk index: prompts snap to len_buckets,
            # so the largest bucket (not max_len, which may exceed it)
            # caps the ladder
            suffix = nB * (max(self.len_buckets) // self.chunk_len - 1)
        else:
            prefill = nB * len(self.len_buckets)
            suffix = 0
        # the verify ladder is keyed (Bb, k) with k fixed per engine, so
        # it adds at most one executable per batch bucket; engines that
        # never speculate must build none
        return {"prefill": prefill, "suffix": suffix, "decode": nB,
                "verify": nB if self.speculate_k else 0}

    def _decode_fn(self, Bb: int):
        if Bb not in self._decode_fns:
            s = self._bank_sharding()
            if self.kv_layout == "paged":
                # (params, kv_pool, table, pos, t, {token}) ->
                # (logits, kv_pool', pos', t')
                fn = jax.vmap(
                    lambda p, pool, tbl, pos, t, b: self.model.paged_decode(
                        p, pool, tbl, pos, t, b, page=self.page))
                if s is not None:
                    jitted = jax.jit(fn,
                                     in_shardings=(s, s, s, s, s, s),
                                     out_shardings=(s, s, s, s),
                                     donate_argnums=(1,))
                else:
                    jitted = jax.jit(fn, donate_argnums=(1,))
            else:
                fn = jax.vmap(self.model.decode)
                if s is not None:
                    jitted = jax.jit(fn, in_shardings=(s, s, s),
                                     out_shardings=(s, s),
                                     donate_argnums=(1,))
                else:
                    jitted = jax.jit(fn, donate_argnums=(1,))
            self._decode_fns[Bb] = jitted
        return self._decode_fns[Bb]

    def _verify_fn(self, Bb: int, k: int):
        """Fused draft-k/verify-1 executable for one batch bucket.

        One dispatch per wave per tick: the draft proposes ``k`` tokens
        from each row's last emitted token, the target scores the whole
        (Bb, k+1) window through ``model.verify`` (k+1 chained
        single-token decode steps — bitwise identical to the plain
        decode ladder, see models/dense.py), the matched greedy prefix
        is accepted, per-row positions advance by ``adv``, the rejected
        suffix's optimistically written slots roll back to pos == -1,
        and the draft observes the verified transitions. Rows frozen at
        ``cap`` (done emitting) get adv == 0 and acc == -1.

        Returns (emit (E,Bb,k+1) greedy tokens — the host keeps the
        first ``adv`` per row, adv (E,Bb), acc (E,Bb) accepted draft
        count or -1, tok' (E,Bb) next feed token, kv', row_pos',
        row_t', draft_state')."""
        key = (Bb, k)
        if key not in self._verify_fns:
            s = self._bank_sharding()
            K1 = k + 1
            draft = self.draft
            model = self.model

            def accept(window, greedy, row_pos, row_t, tok, cap, dstate):
                # accepted prefix: drafts matching the greedy chain
                match = (window[:, 1:] == greedy[:, :-1])
                j = jnp.cumprod(match.astype(jnp.int32), axis=1) \
                    .sum(axis=1)                       # (Bb,) <= k
                remaining = jnp.maximum(cap - row_t, 0)
                adv = jnp.minimum(j + 1, remaining)    # >= 1 while active
                active = remaining > 0
                acc = jnp.where(active, j, -1).astype(jnp.int32)
                # roll back the rejected suffix: written slots past the
                # accepted prefix return to pos == -1 (they were -1 on
                # entry — the admit gate guarantees slots t..t+k are
                # unused and never wrap onto live context)
                C = row_pos.shape[1]
                offs = row_t[:, None] + jnp.arange(K1)[None, :]
                keep = jnp.arange(K1)[None, :] < adv[:, None]
                rowsB = jnp.arange(Bb)[:, None]
                new_pos = row_pos.at[rowsB, offs % C].set(
                    jnp.where(keep, offs, -1).astype(row_pos.dtype))
                new_t = row_t + adv
                tok2 = jnp.where(
                    active,
                    jnp.take_along_axis(
                        greedy, jnp.maximum(adv - 1, 0)[:, None],
                        axis=1)[:, 0],
                    tok)
                dstate2 = draft.observe(dstate, window, greedy, adv)
                return (greedy, adv.astype(jnp.int32), acc, tok2,
                        new_pos, new_t, dstate2)

            if self.kv_layout == "paged":
                # (params, kv_pool, table, row_pos, row_t, tok, cap,
                #  dstate) -> (emit, adv, acc, tok', kv_pool', row_pos',
                #  row_t', dstate')
                def one(p, pool, tbl, row_pos, row_t, tok, cap, dstate):
                    drafts = draft.propose(dstate, tok, k)
                    window = jnp.concatenate([tok[:, None], drafts], 1)
                    greedy, pool = model.paged_verify(
                        p, pool, tbl, row_pos, row_t,
                        {"tokens": window}, page=self.page)
                    (emit, adv, acc, tok2, new_pos, new_t,
                     dstate2) = accept(window, greedy, row_pos, row_t,
                                       tok, cap, dstate)
                    return emit, adv, acc, tok2, pool, new_pos, new_t, \
                        dstate2

                fn = jax.vmap(one)
                if s is not None:
                    jitted = jax.jit(
                        fn, in_shardings=(s,) * 8,
                        out_shardings=(s,) * 8, donate_argnums=(1,))
                else:
                    jitted = jax.jit(fn, donate_argnums=(1,))
            else:
                # (params, cache, row_pos, row_t, tok, cap, dstate) ->
                # (emit, adv, acc, tok', cache', row_pos', row_t',
                #  dstate')
                def one(p, cache, row_pos, row_t, tok, cap, dstate):
                    drafts = draft.propose(dstate, tok, k)
                    window = jnp.concatenate([tok[:, None], drafts], 1)
                    greedy, cache = model.verify(
                        p, cache, row_pos, row_t, {"tokens": window})
                    (emit, adv, acc, tok2, new_pos, new_t,
                     dstate2) = accept(window, greedy, row_pos, row_t,
                                       tok, cap, dstate)
                    return emit, adv, acc, tok2, cache, new_pos, \
                        new_t, dstate2

                fn = jax.vmap(one)
                if s is not None:
                    jitted = jax.jit(
                        fn, in_shardings=(s,) * 7,
                        out_shardings=(s,) * 8, donate_argnums=(1,))
                else:
                    jitted = jax.jit(fn, donate_argnums=(1,))
            self._verify_fns[key] = jitted
        return self._verify_fns[key]

    def _copy_pages_fn(self, m: int):
        """Jitted COW page copier for ``m`` (expert, src, dst) triples.
        The pool is donated so XLA scatters the copied pages in place —
        an eager ``.at[].set`` would materialise a full copy of the
        engine's largest device buffer per call. ``m`` is snapped to a
        power-of-two ladder (padding copies trash -> trash, a no-op),
        so the wrapper count stays bounded under arbitrary traffic."""
        if m not in self._copy_fns:
            def fn(pool, es, srcs, dsts):
                return {k: v.at[es, dsts].set(v[es, srcs])
                        for k, v in pool.items()}
            s = self._bank_sharding()
            if s is not None:
                jitted = jax.jit(fn, in_shardings=(s, None, None, None),
                                 out_shardings=s, donate_argnums=(0,))
            else:
                jitted = jax.jit(fn, donate_argnums=(0,))
            self._copy_fns[m] = jitted
        return self._copy_fns[m]

    def _copy_pages(self, copies: Mapping[int, Sequence[Tuple[int, int]]]
                    ) -> None:
        """Apply copy-on-write page copies: flatten every expert's
        (src, dst) pairs into one padded, jitted, donated dispatch."""
        triples = [(local, s_, d) for local, pairs in copies.items()
                   for s_, d in pairs]
        if not triples:
            return
        m = 1
        while m < len(triples):
            m *= 2
        trash = self.pool.trash
        triples += [(0, trash, trash)] * (m - len(triples))
        es, srcs, dsts = (np.asarray(col, np.int32)
                          for col in zip(*triples))
        self.kv_pool = self._copy_pages_fn(m)(
            self.kv_pool, jnp.asarray(es), jnp.asarray(srcs),
            jnp.asarray(dsts))

    # -- admission -------------------------------------------------------
    def pad_shape(self, n_rows: int, prompt_len: int) -> Tuple[int, int]:
        """(batch bucket, length bucket) this admission would snap to."""
        return (bucket_for(n_rows, self.batch_buckets),
                bucket_for(prompt_len, self.len_buckets))

    def _make_spec_wave(self, uids, per_row, done, Bb: int, Sb: int,
                        steps: int, *, cache=None, tok=None,
                        row_pos=None, row_t=None, table=None,
                        pages_held=None, register=None) -> _Wave:
        """Assemble a speculative wave: per-row position planes, the
        per-row freeze position ``cap`` (a row stops once it has written
        its last emitted token; padding rows freeze immediately), the
        host-side token buffer, and the sharding commit — every
        wave-carried array must enter the first verify with the bank
        sharding or pjit mints one executable per sharding combination
        (see the commit comment in ``_admit_paged``)."""
        E = self.n_experts
        cap = np.full((E, Bb), Sb, np.int32)
        for local, ms in per_row.items():
            for i, m in enumerate(ms):
                cap[local, i] = Sb + m - 1
        cap = jnp.asarray(cap)
        s = self._bank_sharding()
        if s is not None:
            row_pos, row_t, tok, cap = jax.device_put(
                (row_pos, row_t, tok, cap), s)
            if table is not None:
                table = jax.device_put(table, s)
        return _Wave(uids=uids, per_row_new=per_row, done=done,
                     cache=cache, tok=tok, emitted=[tok[..., 0]],
                     steps_left=steps, table=table,
                     pages_held=pages_held if pages_held is not None
                     else {},
                     register=register if register is not None else [],
                     spec=True, row_pos=row_pos, row_t=row_t, cap=cap,
                     host_buf=np.zeros((E, Bb, steps + 1), np.int32),
                     host_fill=np.zeros((E, Bb), np.int32))

    def admit_wave(self, groups: Mapping[int, Tuple[Sequence[Any],
                                                    Sequence[np.ndarray],
                                                    Sequence[int]]],
                   *, defer: bool = False) -> bool:
        """Prefill one (E, Bb, Sb) wave: every member expert's micro-batch
        in a single dispatch. Returns False when no group has rows.

        ``groups`` maps local expert index -> (uids, prompts, max_new);
        experts without traffic this wave ride along as zero rows.
        Prompts are right-truncated to the largest length bucket (keeping
        the most recent tokens) and zero-padded to the common bucket; the
        batch dim is zero-padded to its bucket. Decoding past cache
        capacity is safe: the cache is a position-tracked ring, so the
        oldest context is evicted rather than corrupted.

        With ``defer=True`` the prefill (and the first sampled token)
        stays enqueued on device — call ``harvest()`` to materialise and
        emit. With ``defer=False`` the first token plane is materialised
        and harvested before returning (the blocking reference path).
        """
        rows_max, len_max = 0, 1
        for local, (uids, prompts, max_new) in groups.items():
            if not 0 <= local < self.n_experts:
                raise ValueError(f"local expert {local} out of range")
            if len(uids) != len(prompts) or len(uids) != len(max_new):
                raise ValueError("uids/prompts/max_new length mismatch")
            if len(prompts) > self.batch_buckets[-1]:
                raise ValueError(
                    f"micro-batch of {len(prompts)} rows exceeds the "
                    f"largest batch bucket {self.batch_buckets[-1]}")
            rows_max = max(rows_max, len(prompts))
            len_max = max(len_max, max((len(p) for p in prompts),
                                       default=1))
        if rows_max == 0:
            return False
        groups = {l: g for l, g in groups.items() if g[0]}
        Bb = bucket_for(rows_max, self.batch_buckets)
        Sb = bucket_for(len_max, self.len_buckets)
        E = self.n_experts
        toks = np.zeros((E, Bb, Sb), np.int32)
        uids: Dict[int, List[Any]] = {}
        per_row: Dict[int, List[int]] = {}
        done: Dict[int, List[bool]] = {}
        n_rows, n_submitted = 0, 0
        for local, (u, prompts, max_new) in groups.items():
            for i, p in enumerate(prompts):
                p = np.asarray(p, np.int32)[-Sb:]
                toks[local, i, :len(p)] = p
                n_submitted += len(p)
            uids[local] = list(u)
            per_row[local] = [max(1, int(m)) for m in max_new]
            done[local] = [False] * len(u)
            n_rows += len(u)
        fb0 = self.stats.spec_fallback_waves
        if self.kv_layout == "paged":
            # may raise PagePoolExhausted with no state changed — the
            # scheduler requeues the rows as backpressure; the device
            # span below opens only after admission succeeds, so span
            # balance holds trivially across the rollback
            w = self._admit_paged(toks, uids, per_row, done, Bb, Sb)
        else:
            logits, cache = self._prefill_fn(Bb, Sb)(
                self.params, {"tokens": jnp.asarray(toks)})
            self.stats.prefill_calls += 1
            self.stats.prefill_rows_computed += n_rows
            self.stats.prefill_tokens_computed += n_rows * Sb
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[..., None]
            steps = max(m for ms in per_row.values() for m in ms) - 1
            sk = self.speculate_k
            # no-wrap gate: every slot a verify may optimistically write
            # (up to Sb + steps - 1 + k) must fit the ring without
            # wrapping onto live context
            if sk and steps > 0 and Sb + steps + sk <= self.max_len:
                w = self._make_spec_wave(
                    uids, per_row, done, Bb, Sb, steps,
                    cache={"k": cache["k"], "v": cache["v"]}, tok=tok,
                    row_pos=jnp.broadcast_to(
                        cache["pos"][:, None], (E, Bb, self.max_len)),
                    row_t=jnp.broadcast_to(cache["t"][:, None], (E, Bb)))
            else:
                if sk:
                    self.stats.spec_fallback_waves += 1
                w = _Wave(uids=uids, per_row_new=per_row, done=done,
                          cache=cache, tok=tok, emitted=[tok[..., 0]],
                          steps_left=steps)
        self.stats.rows_served += n_rows
        self.stats.rows_padded += E * Bb - n_rows
        self.stats.prefill_tokens_submitted += n_submitted
        if self.tracer.enabled:
            w.wave_id = self.tracer.next_id()
            flat = [u for us in uids.values() for u in us]
            w.sp_prefill = self.tracer.begin_device(
                "wave.prefill", wave=w.wave_id, Bb=Bb, Sb=Sb,
                rows=n_rows, spec=w.spec, chunks=len(w.pending_chunks),
                uids=flat,
                traces=[self.tracer.trace_of(u) for u in flat])
            if self.stats.spec_fallback_waves > fb0:
                self.tracer.event("spec.fallback", wave=w.wave_id)
        self._active.append(w)
        if not defer:
            # blocking reference: drain the wave's prefill chunks (a
            # no-op on unchunked waves) before materialising the first
            # token — callers of the sync API see a fully-prefilled row
            while w.pending_chunks:
                self._dispatch_chunk(w)
            self._materialize(w, 1)
            self.harvest()
        return True

    # -- paged admission -------------------------------------------------
    def _alloc_pages(self, local: int, n: int,
                     ledger: List[Tuple[int, List[int]]]) -> List[int]:
        """Pool allocation with prefix-cache eviction as the fallback;
        every page taken is recorded in ``ledger`` for rollback."""
        try:
            pages = self.pool.alloc(local, n)
        except PagePoolExhausted:
            self.prefix_cache.evict_for(local, n)
            pages = self.pool.alloc(local, n)
        ledger.append((local, pages))
        return pages

    def _admit_paged(self, toks: np.ndarray, uids, per_row, done,
                     Bb: int, Sb: int) -> _Wave:
        """Plan page tables for one wave, sharing prefixes, then prefill
        only the rows no cached/duplicated prefix covers.

        Host phase (transactional): every row is classified as

          * ``cached`` — its full padded prompt's pages are in the
            cross-wave prefix cache and the greedy first token is known:
            the row adopts the pages (refcount++) and skips prefill
            compute entirely;
          * ``dup`` — an earlier row in this wave carries the identical
            padded prompt: share its pages, take its first token;
          * ``computed`` — adopt whatever cached prefix exists (those
            pages are scattered to trash — storage shared, compute not),
            allocate fresh pages for the rest, and join the packed
            prefill batch.

        Rows that wrap (Sb + steps > capacity) overwrite prompt pages
        during decode, so shared pages in the write range are
        copy-on-write remapped to fresh copies before the first tick.
        If the pool cannot cover the wave even after evicting cache
        entries, every reference taken so far is rolled back and
        ``PagePoolExhausted`` propagates with the pool untouched.

        Device phase: computed rows are packed into a (E, Bbc, Sb)
        prefill — Bbc buckets the *computed* row count, which is where
        the measured prefill-compute saving comes from — followed by the
        COW page copies and the first-token plane assembly (gather from
        packed logits + cached-token overrides), all enqueued without a
        host block.
        """
        E, page, nlp, C = self.n_experts, self.page, self.n_logical, \
            self.max_len
        npp = Sb // page
        trash = self.pool.trash
        steps = max(m for ms in per_row.values() for m in ms) - 1
        # chunked geometry: prompts longer than chunk_len split into
        # n_chunks dispatches; partial-prefix adoption snaps DOWN to a
        # chunk boundary so every dispatched chunk is fully uncached,
        # and is capped at npp - ppc so the last chunk always computes
        # (its logits carry every computed row's first token)
        chunked = self.chunk_len is not None and Sb > self.chunk_len
        ppc = (self.chunk_len // page) if chunked else npp
        start_chunk: Dict[Tuple[int, int], int] = {}
        # speculative gate: the last verify of a row may start at
        # Sb + steps - 1 and optimistically write k slots past it, so
        # the whole write window [Sb, Sb + steps + k) must fit without
        # wrapping — which also keeps every speculative write inside
        # wave-owned decode pages (never a shared/prompt page) and COW
        # out of the picture. Chunked whale waves fall back to plain
        # decode (still token-identical, just unaccelerated).
        sk = self.speculate_k
        spec_ok = bool(sk) and steps > 0 and Sb + steps + sk <= C \
            and not chunked
        if sk and not spec_ok:
            self.stats.spec_fallback_waves += 1
        slack = sk if spec_ok else 0
        wr_pages = sorted({(s % C) // page
                           for s in range(Sb, Sb + steps + slack)})
        wr_prompt = [lp for lp in wr_pages if lp < npp]
        wr_decode = [lp for lp in wr_pages if lp >= npp]
        register_ok = not wr_prompt      # decode never clobbers a prefix

        table = np.full((E, Bb, nlp), trash, np.int32)
        ledger: List[Tuple[int, List[int]]] = []    # refs for rollback
        to_release: List[Tuple[int, List[int]]] = []  # COW'd-out pages
        copies: Dict[int, List[Tuple[int, int]]] = {}  # local -> (src, dst)
        scatter: Dict[Tuple[int, int], List[int]] = {}  # computed rows
        cached_tok: Dict[Tuple[int, int], int] = {}
        dup_src: Dict[Tuple[int, int], int] = {}    # row -> computed row
        register: List[Tuple[int, int, int, List[bytes], List[int]]] = []
        n_cached = n_dup = n_shared = 0
        try:
            for local, row_uids in uids.items():
                seen: Dict[bytes, int] = {}       # full-prompt key -> row
                for i in range(len(row_uids)):
                    chain = hash_chain(toks[local, i], page)
                    key = chain[-1]
                    prow: List[int]
                    if key in seen:
                        # only computed rows enter ``seen`` (a row equal
                        # to a cache-hit row takes the cached branch
                        # itself), so a dup's first token always comes
                        # from its representative's packed logits
                        rep = seen[key]
                        prow = list(table[local, rep, :npp])
                        self.pool.retain(local, prow)
                        # ledger entries must own their page lists: the
                        # COW remap below mutates prow in place, and an
                        # aliased entry would double-free the fresh COW
                        # page on rollback while leaking the shared one
                        ledger.append((local, list(prow)))
                        dup_src[(local, i)] = rep
                        n_dup += 1
                        n_shared += npp
                    else:
                        adopted = self.prefix_cache.adopt_prefix(local,
                                                                 chain)
                        if adopted:
                            ledger.append((local, list(adopted)))
                        ftok = None
                        if len(adopted) == npp:
                            ftok = self.prefix_cache.first_token(
                                local, Sb, chain)
                        if ftok is not None:
                            prow = list(adopted)
                            cached_tok[(local, i)] = ftok
                            n_cached += 1
                            n_shared += npp
                        else:
                            if wr_prompt and adopted:
                                # a wrapping row must own its wrapped
                                # prompt pages; trash the adoption and
                                # compute everything into fresh pages
                                self.pool.release(local, adopted)
                                ledger.pop()
                                adopted = []
                            d = len(adopted)
                            if chunked and d:
                                # snap adoption to the chunk grid: kept
                                # pages are compute-shared (their chunks
                                # are skipped, not re-run-to-trash)
                                keep = min((d // ppc) * ppc, npp - ppc)
                                if keep < d:
                                    self.pool.release(local,
                                                      adopted[keep:])
                                    if keep:
                                        ledger[-1] = (local,
                                                      list(adopted[:keep]))
                                    else:
                                        ledger.pop()
                                    adopted = adopted[:keep]
                                    d = keep
                            fresh = self._alloc_pages(local, npp - d,
                                                      ledger)
                            prow = list(adopted) + fresh
                            scatter[(local, i)] = [trash] * d + fresh
                            if chunked:
                                start_chunk[(local, i)] = d // ppc
                            n_shared += d
                            if register_ok:
                                register.append((local, i, Sb, chain,
                                                 list(prow)))
                            seen[key] = i
                    # copy-on-write: shared pages decode will overwrite
                    for lp in wr_prompt:
                        if self.pool.shared(local, prow[lp]):
                            new = self._alloc_pages(local, 1, ledger)[0]
                            copies.setdefault(local, []).append(
                                (prow[lp], new))
                            to_release.append((local, [prow[lp]]))
                            prow[lp] = new
                    decode_pages = self._alloc_pages(
                        local, len(wr_decode), ledger)
                    table[local, i, :npp] = prow
                    for lp, pg in zip(wr_decode, decode_pages):
                        table[local, i, lp] = pg
        except PagePoolExhausted:
            for local, pages in ledger:
                self.pool.release(local, pages)
            raise
        # commit: COW'd-out shared pages lose this wave's reference
        # (rollback above must NOT see these as held, hence deferred)
        for local, pages in to_release:
            self.pool.release(local, pages)
        pages_held = {
            local: [[int(p) for p in table[local, i] if p != trash]
                    for i in range(len(row_uids))]
            for local, row_uids in uids.items()}

        # device phase: packed prefill over computed rows only
        computed = sorted(scatter)                 # [(local, i), ...]
        per_local: Dict[int, List[int]] = {}
        for local, i in computed:
            per_local.setdefault(local, []).append(i)
        n_computed = len(computed)
        use_chunks = chunked and n_computed > 0
        mask = vals = None
        if cached_tok:
            mask = np.zeros((E, Bb), bool)
            vals = np.zeros((E, Bb), np.int32)
            for (local, i), ft in cached_tok.items():
                mask[local, i] = True
                vals[local, i] = ft
        tok = None
        pending: List[Dict[str, Any]] = []
        fin: Optional[Dict[str, Any]] = None
        if use_chunks:
            # plan (don't dispatch) one descriptor per chunk: chunk k
            # packs every computed row whose adopted prefix doesn't
            # already cover it; chunk 0 reuses the monolithic prefill
            # executable at the chunk_len bucket, chunks >= 1 go through
            # the suffix ladder. Dispatch happens in _dispatch_chunk —
            # immediately (blocking admit) or interleaved with decode
            # ticks under the executor's token budget (deferred admit).
            cl = self.chunk_len
            for k in range(Sb // cl):
                rows_k = [(l, i) for (l, i) in computed
                          if start_chunk[(l, i)] <= k]
                if not rows_k:
                    continue
                pl_k: Dict[int, List[int]] = {}
                for l, i in rows_k:
                    pl_k.setdefault(l, []).append(i)
                Bbk = bucket_for(max(len(v) for v in pl_k.values()),
                                 self.batch_buckets)
                toks_k = np.zeros((E, Bbk, cl), np.int32)
                stbl_k = np.full((E, Bbk, ppc), trash, np.int32)
                # padding rows read the trash page through their prefix
                # table — finite garbage, outputs discarded
                ptbl_k = np.full((E, Bbk, k * ppc), trash, np.int32)
                slot_of_k: Dict[Tuple[int, int], int] = {}
                for l, rows in pl_k.items():
                    for c, i in enumerate(rows):
                        toks_k[l, c] = toks[l, i, k * cl:(k + 1) * cl]
                        stbl_k[l, c] = \
                            scatter[(l, i)][k * ppc:(k + 1) * ppc]
                        if k:
                            ptbl_k[l, c] = table[l, i, :k * ppc]
                        slot_of_k[(l, i)] = c
                pending.append({"k": k, "toks": toks_k, "stbl": stbl_k,
                                "ptbl": ptbl_k, "rows": len(rows_k),
                                "slot_of": slot_of_k})
            # every computed row rides the last chunk (adoption is
            # capped at npp - ppc), so its packed logits carry every
            # first token; dups resolve through their representative
            last = pending[-1]["slot_of"]
            src = np.zeros((E, Bb), np.int32)
            for local, row_uids in uids.items():
                for i in range(len(row_uids)):
                    src[local, i] = last.get(
                        (local, i),
                        last.get((local, dup_src.get((local, i), -1)),
                                 0))
            fin = {"src": src, "mask": mask, "vals": vals,
                   "copies": copies}
        else:
            if n_computed:
                Bbc = bucket_for(max(len(v) for v in per_local.values()),
                                 self.batch_buckets)
                toks_c = np.zeros((E, Bbc, Sb), np.int32)
                stbl = np.full((E, Bbc, npp), trash, np.int32)
                slot_of: Dict[Tuple[int, int], int] = {}
                for local, rows in per_local.items():
                    for c, i in enumerate(rows):
                        toks_c[local, c] = toks[local, i]
                        stbl[local, c] = scatter[(local, i)]
                        slot_of[(local, i)] = c
                logits, self.kv_pool = self._prefill_fn(Bbc, Sb)(
                    self.params, {"tokens": jnp.asarray(toks_c)},
                    self.kv_pool, jnp.asarray(stbl))
                self.stats.prefill_calls += 1
                tok_c = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                src = np.zeros((E, Bb), np.int32)
                for local, row_uids in uids.items():
                    for i in range(len(row_uids)):
                        src[local, i] = slot_of.get(
                            (local, i),
                            slot_of.get((local,
                                         dup_src.get((local, i), -1)),
                                        0))
                tok = jnp.take_along_axis(tok_c, jnp.asarray(src),
                                          axis=1)
            if mask is not None:
                tok = jnp.asarray(vals) if tok is None else \
                    jnp.where(jnp.asarray(mask), jnp.asarray(vals), tok)
            assert tok is not None, "wave with rows but no token source"
            # COW copies read post-prefill pages (a dup's source may
            # have been written by this very wave's scatter)
            self._copy_pages(copies)
            self.stats.pages_copied += sum(len(p)
                                           for p in copies.values())
            self.stats.prefill_tokens_computed += n_computed * Sb

        self.stats.prefill_rows_computed += n_computed
        self.stats.prefix_full_hits += n_cached
        self.stats.prefix_dup_rows += n_dup
        self.stats.prefix_pages_shared += n_shared
        pos = np.where(np.arange(C) < Sb, np.arange(C), -1).astype(
            np.int32)
        table_dev = jnp.asarray(table)
        pos_dev = jnp.asarray(np.broadcast_to(pos, (E, C)).copy())
        t_dev = jnp.full((E,), Sb, jnp.int32)
        s = self._bank_sharding()
        if use_chunks:
            if s is not None:
                # same sharding-commit reasoning as below; tok commits
                # separately in _finalize_wave once the last chunk lands
                table_dev, pos_dev, t_dev = jax.device_put(
                    (table_dev, pos_dev, t_dev), s)
            return _Wave(uids=uids, per_row_new=per_row, done=done,
                         cache=None, tok=None, emitted=[],
                         steps_left=steps,
                         table=table_dev, pos=pos_dev, t=t_dev,
                         pages_held=pages_held, register=register,
                         pending_chunks=pending, finalize=fin)
        tok = tok[..., None]
        if spec_ok:
            # per-row position planes (rows advance at different rates);
            # _make_spec_wave performs the sharding commit
            return self._make_spec_wave(
                uids, per_row, done, Bb, Sb, steps, cache=None, tok=tok,
                row_pos=jnp.broadcast_to(pos_dev[:, None], (E, Bb, C)),
                row_t=jnp.broadcast_to(t_dev[:, None], (E, Bb)),
                table=table_dev, pages_held=pages_held,
                register=register)
        if s is not None:
            # commit every wave-carried array to the bank sharding now:
            # tick 1 must present the decode executable with the same
            # input shardings as every later tick (whose pos/t/tok come
            # out of the decode itself via out_shardings), or pjit mints
            # one executable per sharding combination and the
            # bounded-compile invariant breaks
            table_dev, pos_dev, t_dev, tok = jax.device_put(
                (table_dev, pos_dev, t_dev, tok), s)
        return _Wave(uids=uids, per_row_new=per_row, done=done,
                     cache=None, tok=tok, emitted=[tok[..., 0]],
                     steps_left=steps,
                     table=table_dev, pos=pos_dev, t=t_dev,
                     pages_held=pages_held, register=register)

    # -- chunked prefill dispatch ----------------------------------------
    def _dispatch_chunk(self, w: _Wave) -> int:
        """Issue the wave's next pending prefill chunk (FIFO). Chunk 0
        goes through the monolithic prefill executable at the chunk_len
        bucket; later chunks attend over the pages earlier chunks (or an
        adopted prefix) already wrote. When the last chunk is issued the
        wave is finalized — its first-token plane is assembled and it
        becomes decode-eligible. Returns prompt tokens dispatched (real
        rows x chunk_len, the budget currency)."""
        d = w.pending_chunks.pop(0)
        k = d["k"]
        Bbk = d["toks"].shape[1]
        if k == 0:
            logits, self.kv_pool = self._prefill_fn(Bbk, self.chunk_len)(
                self.params, {"tokens": jnp.asarray(d["toks"])},
                self.kv_pool, jnp.asarray(d["stbl"]))
        else:
            logits, self.kv_pool = self._suffix_fn(Bbk, k)(
                self.params, {"tokens": jnp.asarray(d["toks"])},
                self.kv_pool, jnp.asarray(d["ptbl"]),
                jnp.asarray(d["stbl"]))
        self.stats.prefill_calls += 1
        spent = d["rows"] * self.chunk_len
        self.stats.prefill_tokens_computed += spent
        self.tracer.event("wave.chunk", wave=w.wave_id, chunk=k,
                          tokens=spent,
                          remaining=len(w.pending_chunks))
        if not w.pending_chunks:
            w._tok_c = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self._finalize_wave(w)
        return spent

    def _finalize_wave(self, w: _Wave) -> None:
        """Last chunk landed: gather every row's first token from the
        final chunk's packed logits (cached rows overlay their known
        token), apply the deferred COW copies, and commit the token
        plane to the bank sharding — the wave is now decode-eligible."""
        f = w.finalize
        w.finalize = None
        tok = jnp.take_along_axis(w._tok_c, jnp.asarray(f["src"]),
                                  axis=1)
        w._tok_c = None
        if f["mask"] is not None:
            tok = jnp.where(jnp.asarray(f["mask"]),
                            jnp.asarray(f["vals"]), tok)
        # COW copies must read fully-written prompt pages, so they wait
        # for the last chunk (the unchunked path runs them post-prefill
        # for the same reason)
        self._copy_pages(f["copies"])
        self.stats.pages_copied += sum(len(p)
                                       for p in f["copies"].values())
        tok = tok[..., None]
        s = self._bank_sharding()
        if s is not None:
            tok = jax.device_put(tok, s)
        w.tok = tok
        w.emitted.append(tok[..., 0])

    def prefill_step(self, budget: int = 0) -> int:
        """Dispatch pending prefill chunks FIFO across active waves —
        at least one chunk per call so whales always make progress —
        stopping once ``budget`` prompt tokens (0 = unbounded) have been
        issued. The executor calls this between admission and decode
        ticks, which is the disaggregation: a whale's remaining chunks
        interleave with co-resident waves' decode steps instead of
        monopolising the dispatch slot. Returns tokens dispatched."""
        spent = 0
        for w in list(self._active):
            while w.pending_chunks:
                spent += self._dispatch_chunk(w)
                if budget and spent >= budget:
                    return spent
        return spent

    @property
    def has_pending_chunks(self) -> bool:
        return any(w.pending_chunks for w in self._active)

    # -- decoding --------------------------------------------------------
    def tick(self, *, defer: bool = False) -> int:
        """Advance every active wave one decode step — one dispatch per
        wave covers all member experts. Returns waves advanced.

        ``defer=False`` (the blocking reference) materialises each
        wave's new token plane immediately — one host block per wave —
        and harvests before returning. ``defer=True`` only enqueues:
        ``wave.tok`` feeds the next decode without ever leaving the
        device, and the host blocks once per wave at ``harvest()``.
        """
        advanced = 0
        for w in list(self._active):
            # a wave with prefill chunks still pending has no sampled
            # token yet — decode only admits it once its last chunk
            # lands (w.tok set in _finalize_wave)
            if w.tok is None:
                continue
            if w.steps_left > 0:
                Bb = w.tok.shape[1]
                if w.sp_decode is None and self.tracer.enabled:
                    # covers every tick enqueued until the next harvest
                    # sync closes it (one span per materialise window)
                    w.sp_decode = self.tracer.begin_device(
                        "wave.verify" if w.spec else "wave.decode",
                        wave=w.wave_id, Bb=Bb)
                if w.spec:
                    self._spec_tick(w, Bb)
                    advanced += 1
                    if not defer:
                        self._materialize_spec(w)
                    continue
                if self.kv_layout == "paged":
                    # the pool buffers thread through every wave's tick
                    # (donated each dispatch); pos/t stay per-wave
                    logits, self.kv_pool, w.pos, w.t = self._decode_fn(
                        Bb)(self.params, self.kv_pool, w.table, w.pos,
                            w.t, {"token": w.tok})
                else:
                    logits, w.cache = self._decode_fn(Bb)(
                        self.params, w.cache, {"token": w.tok})
                w.tok = jnp.argmax(logits, axis=-1).astype(
                    jnp.int32)[..., None]
                w.emitted.append(w.tok[..., 0])
                w.steps_left -= 1
                self.stats.decode_steps += 1
                advanced += 1
                if not defer:
                    self._materialize(w, len(w.emitted))
        if not defer:
            self.harvest()
        return advanced

    def _spec_tick(self, w: _Wave, Bb: int) -> None:
        """One verify dispatch for a speculative wave: every active row
        advances by at least one token (the corrected greedy token when
        all drafts miss), so the wave finishes in at most ``steps``
        ticks and usually far fewer. ``steps_left`` stays the plain
        tick-count upper bound; harvest zeroes it early once every row
        has its tokens."""
        args = (w.row_pos, w.row_t, w.tok[..., 0], w.cap,
                self.draft_state)
        if self.kv_layout == "paged":
            (emit, adv, acc, tok2, self.kv_pool, w.row_pos, w.row_t,
             self.draft_state) = self._verify_fn(Bb, self.speculate_k)(
                self.params, self.kv_pool, w.table, *args)
        else:
            (emit, adv, acc, tok2, w.cache, w.row_pos, w.row_t,
             self.draft_state) = self._verify_fn(Bb, self.speculate_k)(
                self.params, w.cache, *args)
        w.tok = tok2[..., None]
        w.spec_pending.append((emit, adv, acc))
        w.steps_left -= 1
        self.stats.decode_steps += 1
        self.stats.verify_steps += 1

    # -- harvest ---------------------------------------------------------
    def _materialize(self, w: _Wave, upto: int) -> None:
        """Bring ``emitted[:upto]`` to host in one blocking transfer."""
        upto = min(upto, len(w.emitted))
        if upto <= w.n_host:
            return
        host = jax.device_get(w.emitted[w.n_host:upto])
        for k, plane in enumerate(host):
            w.emitted[w.n_host + k] = np.asarray(plane)
        w.n_host = upto
        self.stats.host_blocks += 1
        # blessed sync site: the device_get above completed everything
        # enqueued for this wave, so its open device spans close here —
        # tracing rides the sync the engine already pays for (O002)
        if w.sp_prefill is not None:
            self.tracer.end_device(w.sp_prefill, planes=upto)
            w.sp_prefill = None
        if w.sp_decode is not None:
            self.tracer.end_device(w.sp_decode, planes=upto)
            w.sp_decode = None

    def _materialize_spec(self, w: _Wave) -> None:
        """Drain a speculative wave's pending (emit, adv, acc) verify
        triples (plus the prefill token plane the first time) to host in
        one batched transfer, advancing each row's token buffer by its
        *actual* accepted count — the host learns real progress, which
        is what lets harvest retire the wave after ~steps/E[adv] ticks
        instead of steps."""
        if w.spec_seeded and not w.spec_pending:
            return
        first, triples = jax.device_get((w.emitted[0], w.spec_pending))
        self.stats.host_blocks += 1
        # blessed sync site (the speculative twin of _materialize)
        if w.sp_prefill is not None:
            self.tracer.end_device(w.sp_prefill)
            w.sp_prefill = None
        if w.sp_decode is not None:
            self.tracer.end_device(w.sp_decode,
                                   verifies=len(triples))
            w.sp_decode = None
        if not w.spec_seeded:
            w.emitted[0] = np.asarray(first)
            w.n_host = max(w.n_host, 1)
            w.host_buf[:, :, 0] = w.emitted[0]
            np.maximum(w.host_fill, 1, out=w.host_fill)
            w.spec_seeded = True
        k = self.speculate_k
        for emit, adv, acc in triples:
            emit, adv, acc = (np.asarray(x) for x in (emit, adv, acc))
            for local, row_uids in w.uids.items():
                for i in range(len(row_uids)):
                    a = int(adv[local, i])
                    if a > 0:
                        f = int(w.host_fill[local, i])
                        w.host_buf[local, i, f:f + a] = emit[local, i, :a]
                        w.host_fill[local, i] = f + a
                    c = int(acc[local, i])
                    if c >= 0:
                        self.stats.tokens_drafted += k
                        self.stats.tokens_accepted += c
        w.spec_pending = []

    def _harvest_spec(self, w: _Wave) -> None:
        """Emit every speculative row whose token buffer is full; once
        all rows are done, zero ``steps_left`` so the wave retires now
        instead of burning its remaining tick budget.

        The device transfer is gated the same way plain waves gate
        ``_materialize`` (``need > n_host``): each verify advances a row
        by at most ``k + 1`` tokens, so until the pending triples could
        arithmetically complete some unfinished row there is nothing to
        emit and the sync is skipped — without this, speculative waves
        host-block every harvest and give back much of the verify win.
        """
        if w.spec_pending and w.steps_left > 0:
            bound = (len(w.spec_pending) * (self.speculate_k + 1)
                     + (0 if w.spec_seeded else 1))
            if not any(not w.done[local][i]
                       and w.host_fill[local, i] + bound
                       >= w.per_row_new[local][i]
                       for local, row_uids in w.uids.items()
                       for i in range(len(row_uids))):
                return
        self._materialize_spec(w)
        for local, row_uids in w.uids.items():
            for i, uid in enumerate(row_uids):
                if w.done[local][i]:
                    continue
                n = w.per_row_new[local][i]
                if w.host_fill[local, i] >= n:
                    seq = np.array(w.host_buf[local, i, :n], np.int32)
                    self._finished.append((local, uid, seq))
                    self.stats.tokens_generated += n
                    w.done[local][i] = True
        if all(all(d) for d in w.done.values()):
            w.steps_left = 0
        if w.steps_left <= 0 and all(all(d) for d in w.done.values()):
            self._active.remove(w)
            if self.kv_layout == "paged":
                self._retire_paged(w)

    def harvest(self) -> None:
        """Emit every row whose ``max_new`` tokens are all available and
        retire fully-done waves.

        Per wave, all planes any completable row needs are materialised
        in a single batched device→host transfer (at most one host
        block per wave per call) — the per-tick sync of the old engines
        is gone from the deferred path entirely.
        """
        for w in list(self._active):
            if w.spec:
                self._harvest_spec(w)
                continue
            have = len(w.emitted)
            need = 0
            for local, row_uids in w.uids.items():
                for i in range(len(row_uids)):
                    if (not w.done[local][i]
                            and w.per_row_new[local][i] <= have):
                        need = max(need, w.per_row_new[local][i])
            if need > w.n_host:
                self._materialize(w, need)
            for local, row_uids in w.uids.items():
                for i, uid in enumerate(row_uids):
                    if w.done[local][i] or w.per_row_new[local][i] > have:
                        continue
                    seq = np.asarray(
                        [w.emitted[t][local, i] for t in
                         range(w.per_row_new[local][i])], np.int32)
                    self._finished.append((local, uid, seq))
                    self.stats.tokens_generated += len(seq)
                    w.done[local][i] = True
            if w.steps_left <= 0 and all(all(d) for d in w.done.values()):
                self._active.remove(w)
                if self.kv_layout == "paged":
                    self._retire_paged(w)

    def _retire_paged(self, w: _Wave) -> None:
        """Register computed prefixes in the cross-wave cache (the
        first-token plane is host-side by now, so registration costs no
        sync), then release every page the wave's rows held."""
        for local, i, padded_len, chain, pages in w.register:
            self.prefix_cache.insert(local, padded_len, chain, pages,
                                     int(w.emitted[0][local, i]))
        for local, rows in w.pages_held.items():
            for pages in rows:
                self.pool.release(local, pages)
        w.pages_held = {}
        w.register = []

    def poll(self) -> List[Tuple[int, Any, np.ndarray]]:
        """Drain finished (local expert, uid, tokens) triples."""
        out, self._finished = self._finished, []
        return out

    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def has_pending(self) -> bool:
        """Active waves or finished rows not yet polled."""
        return bool(self._active or self._finished)


# ---------------------------------------------------------------------------
# Dispatch executors
# ---------------------------------------------------------------------------


class DispatchExecutor:
    """How one scheduler step drives its shards.

    ``run_step`` first drives the expert hub's lifecycle (a no-op on
    hubless schedulers), then issues every shard's prefill, then every
    shard's decode tick, then harvests — the ``defer`` flag decides
    whether each dispatch blocks on its own device→host copy (serial,
    the reference) or whether nothing blocks until the single batched
    harvest transfer per wave (overlapped). Because both orders run the
    identical compute graph, they are token-identical by construction;
    only ``EngineStats.host_blocks`` differs. Hub slot installs ride
    the same ordering: with the overlapped executor they are enqueued
    ahead of the step's decode ticks, so checkpoint staging (a worker
    thread) and the install scatter overlap in-flight decode.
    """

    name = "base"
    defer = False

    def run_step(self, sched) -> None:
        sched._service_hub()
        sched._admit_batches(defer=self.defer)
        # prefill/decode disaggregation: pending chunks of partially-
        # prefilled waves are issued here, bounded per step by
        # SchedulerConfig.prefill_tokens_per_step, so the decode ticks
        # below run every step even while a whale prompt prefills (on
        # the blocking path admission already drained its chunks and
        # this is a no-op)
        sched._prefill_chunks()
        sched._tick_engines(defer=self.defer)
        sched._harvest_engines()


class SerialExecutor(DispatchExecutor):
    """Reference behaviour: every admit/tick materialises its sampled
    token immediately, blocking the host once per tick per wave before
    the next shard's work is issued."""

    name = "serial"
    defer = False


class OverlappedExecutor(DispatchExecutor):
    """Async dispatch: prefills and decode ticks for *all* shards are
    enqueued before anything blocks; tokens stay on device and the host
    blocks at most once per wave per step, inside the batched harvest.
    Prefill of one shard overlaps decode of another on the device
    queue."""

    name = "overlapped"
    defer = True


def get_executor(executor) -> DispatchExecutor:
    """Resolve ``'serial'`` / ``'overlapped'`` / an instance."""
    if isinstance(executor, DispatchExecutor):
        return executor
    if executor == "serial":
        return SerialExecutor()
    if executor == "overlapped":
        return OverlappedExecutor()
    raise ValueError(f"unknown executor {executor!r}; expected 'serial', "
                     "'overlapped' or a DispatchExecutor instance")
