"""EngineCore: the shared residency / bucketed-jit / harvest machinery
behind every expert engine, plus the dispatch executors.

PR 2 left ``ExpertEngine`` and ``BankedEngine`` as two parallel
implementations of the same machinery (bucket snapping, resident
groups, per-row harvest, bounded jit caches), kept aligned only by the
equivalence tests — and both forced a device→host copy of the sampled
token on *every* decode tick, blocking JAX's async dispatch before the
next shard's work could even be issued. This module unifies and
de-syncs that hot path:

  * ``EngineCore`` serves E >= 1 experts whose params are stacked on a
    leading ``expert`` axis; prefill/decode are ``vmap`` over that axis
    (optionally GSPMD-sharded over a 1-D ``expert`` mesh), jitted once
    per (batch bucket, len bucket) for the whole core. ``ExpertEngine``
    is the E=1 shim, ``BankedEngine`` the E=K shim — one implementation,
    no equivalence-by-test.
  * a tick **enqueues** device work and keeps the sampled token on
    device: ``wave.tok`` stays a ``jnp.ndarray`` and emitted columns
    accumulate as device buffers. Nothing blocks until ``harvest()``,
    which materialises all planes a completable row needs in **one**
    batched device→host transfer per wave per step (instead of one per
    tick per group).
  * every host-blocking materialisation increments
    ``EngineStats.host_blocks`` — the CI-stable sync counter the bench
    and tests assert against (overlapped must block strictly less often
    per decoded token than serial).
  * ``EngineStats.prefill_compiles`` / ``decode_compiles`` count real
    XLA executables via each jit wrapper's ``_cache_size()``, not
    wrapper creations — a wrapper that silently recompiled (shape/dtype
    drift inside one bucket) now shows up in the bounded-compile
    invariant instead of hiding behind a stale Python-side counter.

The dispatch executors decide *when* the host blocks:

  * ``SerialExecutor`` — the reference: each tick materialises its
    token immediately (today's per-tick sync), shard after shard.
  * ``OverlappedExecutor`` — issues prefills and decode ticks for all
    shards before blocking on anything, then runs one batched harvest;
    prefill of one shard overlaps decode of another on the device
    queue.

Both orders produce token-identical results (the compute graph is the
same; only sync placement differs) — asserted property-style in
``tests/test_serving.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..sharding import leading_sharding


# ---------------------------------------------------------------------------
# Shape buckets
# ---------------------------------------------------------------------------


def make_buckets(lo: int, hi: int) -> Tuple[int, ...]:
    """Power-of-two ladder covering [lo, hi] (hi always included).

    Raises instead of silently returning ``(hi,)`` when ``lo > hi`` —
    that shape used to make ``ExpertEngine(max_len=4, min_len_bucket=8)``
    build a ladder that ignored ``min_len_bucket`` entirely.
    """
    lo, hi = int(lo), int(hi)
    if lo < 1:
        raise ValueError(f"make_buckets: lo must be >= 1, got {lo}")
    if lo > hi:
        raise ValueError(f"make_buckets: lo {lo} > hi {hi}")
    out = []
    b = lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return tuple(out)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n, clamped to the largest bucket."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


# ---------------------------------------------------------------------------
# Stats
# ---------------------------------------------------------------------------


def _probe_cache_size() -> bool:
    try:
        return callable(getattr(jax.jit(lambda: 0), "_cache_size"))
    except Exception:
        return False


# ``_cache_size`` is a private jax API (present on the pinned 0.4.37);
# probe once at import so a build without it degrades *visibly* — the
# compile counters revert to one-count-per-wrapper and tests/tools that
# need exact semantics check this flag instead of silently passing.
COMPILE_COUNTER_EXACT = _probe_cache_size()


def _wrapper_compiles(fn) -> int:
    """Real XLA executables behind one jit wrapper.

    ``_cache_size()`` is the C++ pjit cache entry count — it grows when
    a wrapper recompiles for a signature the Python-side bucket key
    didn't capture (cache dtype/shape drift), which a
    one-count-per-wrapper scheme silently missed. On jax builds without
    the API (``COMPILE_COUNTER_EXACT`` False) this falls back to 1 per
    wrapper — the pre-refactor upper-bound semantics.
    """
    if not COMPILE_COUNTER_EXACT:
        return 1
    try:
        return int(fn._cache_size())
    except TypeError:
        return 1


class EngineStats:
    """Serving counters for one ``EngineCore``.

    ``prefill_compiles`` / ``decode_compiles`` are *live* properties
    summing real executable counts over the core's jit wrappers (see
    ``_wrapper_compiles``); the rest are plain counters.
    ``host_blocks`` counts host-blocking device→host materialisations —
    the sync counter the overlapped-dispatch invariants assert against.
    """

    def __init__(self, core: Optional["EngineCore"] = None):
        self._core = core
        self.prefill_calls = 0
        self.decode_steps = 0
        self.rows_served = 0
        self.rows_padded = 0
        self.tokens_generated = 0
        self.host_blocks = 0

    @property
    def prefill_compiles(self) -> int:
        if self._core is None:
            return 0
        return sum(_wrapper_compiles(fn)
                   for fn in self._core._prefill_fns.values())

    @property
    def decode_compiles(self) -> int:
        if self._core is None:
            return 0
        return sum(_wrapper_compiles(fn)
                   for fn in self._core._decode_fns.values())

    @property
    def jit_cache_entries(self) -> int:
        return self.prefill_compiles + self.decode_compiles

    def __repr__(self) -> str:
        return (f"EngineStats(prefill_compiles={self.prefill_compiles}, "
                f"decode_compiles={self.decode_compiles}, "
                f"prefill_calls={self.prefill_calls}, "
                f"decode_steps={self.decode_steps}, "
                f"rows_served={self.rows_served}, "
                f"rows_padded={self.rows_padded}, "
                f"tokens_generated={self.tokens_generated}, "
                f"host_blocks={self.host_blocks})")


# ---------------------------------------------------------------------------
# Core
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Wave:
    """One admitted (E, Bb) micro-batch wave resident in the core.

    ``emitted`` holds one (E, Bb) token plane per generated step; planes
    start life as device buffers and are swapped for host arrays by
    ``_materialize`` — ``n_host`` is the already-materialised prefix.
    """
    uids: Dict[int, List[Any]]          # local expert -> row uids
    per_row_new: Dict[int, List[int]]
    done: Dict[int, List[bool]]
    cache: Any
    tok: jnp.ndarray                    # (E, Bb, 1) last sampled token
    emitted: List[Any]                  # (E, Bb) planes, device or host
    steps_left: int
    n_host: int = 0                     # emitted[:n_host] are host arrays


class EngineCore:
    """E homogeneous experts: bucketed executables, resident waves,
    device-side token state, batched harvest.

    Admission and decode *enqueue* work; the only host-blocking points
    are ``_materialize`` calls — per tick in sync mode (``defer=False``,
    the serial reference and the seed-compatible blocking API), or one
    batched transfer per wave inside ``harvest()`` in deferred mode.
    """

    def __init__(self, model, params_list: Sequence[Any], *,
                 max_len: int = 256, min_len_bucket: int = 8,
                 len_buckets: Optional[Sequence[int]] = None,
                 batch_buckets: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None):
        if not params_list:
            raise ValueError("EngineCore needs at least one expert")
        self.model = model
        self.n_experts = len(params_list)
        self.max_len = max_len
        self.len_buckets = tuple(len_buckets) if len_buckets else \
            make_buckets(min_len_bucket, max_len)
        self.batch_buckets = tuple(batch_buckets or make_buckets(1, 16))
        if mesh is not None and (
                "expert" not in mesh.shape
                or self.n_experts % mesh.shape["expert"]):
            raise ValueError(
                f"mesh expert axis {dict(mesh.shape)} must divide the "
                f"bank's {self.n_experts} experts")
        self.mesh = mesh if (mesh is not None
                             and mesh.shape.get("expert", 1) > 1) else None
        self.stats = EngineStats(self)
        self._active: List[_Wave] = []
        self._finished: List[Tuple[int, Any, np.ndarray]] = []
        # shape-keyed jit wrappers; real executable counts come from
        # each wrapper's _cache_size() (see EngineStats)
        self._prefill_fns: Dict[Tuple[int, int], Any] = {}
        self._decode_fns: Dict[int, Any] = {}
        params = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                        *params_list)
        if self.mesh is not None:
            sh = leading_sharding(params, "expert", self.mesh)
            params = jax.device_put(params, sh)
        self.params = params

    # -- sharded/bucketed executables -----------------------------------
    def _bank_sharding(self):
        """Prefix sharding for any expert-leading pytree (or None)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P("expert"))

    def _prefill_fn(self, Bb: int, Sb: int):
        key = (Bb, Sb)
        if key not in self._prefill_fns:
            fn = jax.vmap(lambda p, b: self.model.prefill(
                p, b, capacity=self.max_len))
            s = self._bank_sharding()
            if s is not None:
                jitted = jax.jit(fn, in_shardings=(s, s),
                                 out_shardings=(s, s))
            else:
                jitted = jax.jit(fn)
            self._prefill_fns[key] = jitted
        return self._prefill_fns[key]

    def _decode_fn(self, Bb: int):
        if Bb not in self._decode_fns:
            fn = jax.vmap(self.model.decode)
            s = self._bank_sharding()
            if s is not None:
                jitted = jax.jit(fn, in_shardings=(s, s, s),
                                 out_shardings=(s, s), donate_argnums=(1,))
            else:
                jitted = jax.jit(fn, donate_argnums=(1,))
            self._decode_fns[Bb] = jitted
        return self._decode_fns[Bb]

    # -- admission -------------------------------------------------------
    def pad_shape(self, n_rows: int, prompt_len: int) -> Tuple[int, int]:
        """(batch bucket, length bucket) this admission would snap to."""
        return (bucket_for(n_rows, self.batch_buckets),
                bucket_for(prompt_len, self.len_buckets))

    def admit_wave(self, groups: Mapping[int, Tuple[Sequence[Any],
                                                    Sequence[np.ndarray],
                                                    Sequence[int]]],
                   *, defer: bool = False) -> bool:
        """Prefill one (E, Bb, Sb) wave: every member expert's micro-batch
        in a single dispatch. Returns False when no group has rows.

        ``groups`` maps local expert index -> (uids, prompts, max_new);
        experts without traffic this wave ride along as zero rows.
        Prompts are right-truncated to the largest length bucket (keeping
        the most recent tokens) and zero-padded to the common bucket; the
        batch dim is zero-padded to its bucket. Decoding past cache
        capacity is safe: the cache is a position-tracked ring, so the
        oldest context is evicted rather than corrupted.

        With ``defer=True`` the prefill (and the first sampled token)
        stays enqueued on device — call ``harvest()`` to materialise and
        emit. With ``defer=False`` the first token plane is materialised
        and harvested before returning (the blocking reference path).
        """
        rows_max, len_max = 0, 1
        for local, (uids, prompts, max_new) in groups.items():
            if not 0 <= local < self.n_experts:
                raise ValueError(f"local expert {local} out of range")
            if len(uids) != len(prompts) or len(uids) != len(max_new):
                raise ValueError("uids/prompts/max_new length mismatch")
            if len(prompts) > self.batch_buckets[-1]:
                raise ValueError(
                    f"micro-batch of {len(prompts)} rows exceeds the "
                    f"largest batch bucket {self.batch_buckets[-1]}")
            rows_max = max(rows_max, len(prompts))
            len_max = max(len_max, max((len(p) for p in prompts),
                                       default=1))
        if rows_max == 0:
            return False
        groups = {l: g for l, g in groups.items() if g[0]}
        Bb = bucket_for(rows_max, self.batch_buckets)
        Sb = bucket_for(len_max, self.len_buckets)
        E = self.n_experts
        toks = np.zeros((E, Bb, Sb), np.int32)
        uids: Dict[int, List[Any]] = {}
        per_row: Dict[int, List[int]] = {}
        done: Dict[int, List[bool]] = {}
        n_rows = 0
        for local, (u, prompts, max_new) in groups.items():
            for i, p in enumerate(prompts):
                p = np.asarray(p, np.int32)[-Sb:]
                toks[local, i, :len(p)] = p
            uids[local] = list(u)
            per_row[local] = [max(1, int(m)) for m in max_new]
            done[local] = [False] * len(u)
            n_rows += len(u)
        logits, cache = self._prefill_fn(Bb, Sb)(
            self.params, {"tokens": jnp.asarray(toks)})
        self.stats.prefill_calls += 1
        self.stats.rows_served += n_rows
        self.stats.rows_padded += E * Bb - n_rows
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[..., None]
        w = _Wave(uids=uids, per_row_new=per_row, done=done,
                  cache=cache, tok=tok, emitted=[tok[..., 0]],
                  steps_left=max(m for ms in per_row.values()
                                 for m in ms) - 1)
        self._active.append(w)
        if not defer:
            self._materialize(w, 1)
            self.harvest()
        return True

    # -- decoding --------------------------------------------------------
    def tick(self, *, defer: bool = False) -> int:
        """Advance every active wave one decode step — one dispatch per
        wave covers all member experts. Returns waves advanced.

        ``defer=False`` (the blocking reference) materialises each
        wave's new token plane immediately — one host block per wave —
        and harvests before returning. ``defer=True`` only enqueues:
        ``wave.tok`` feeds the next decode without ever leaving the
        device, and the host blocks once per wave at ``harvest()``.
        """
        advanced = 0
        for w in list(self._active):
            if w.steps_left > 0:
                Bb = w.tok.shape[1]
                logits, w.cache = self._decode_fn(Bb)(
                    self.params, w.cache, {"token": w.tok})
                w.tok = jnp.argmax(logits, axis=-1).astype(
                    jnp.int32)[..., None]
                w.emitted.append(w.tok[..., 0])
                w.steps_left -= 1
                self.stats.decode_steps += 1
                advanced += 1
                if not defer:
                    self._materialize(w, len(w.emitted))
        if not defer:
            self.harvest()
        return advanced

    # -- harvest ---------------------------------------------------------
    def _materialize(self, w: _Wave, upto: int) -> None:
        """Bring ``emitted[:upto]`` to host in one blocking transfer."""
        upto = min(upto, len(w.emitted))
        if upto <= w.n_host:
            return
        host = jax.device_get(w.emitted[w.n_host:upto])
        for k, plane in enumerate(host):
            w.emitted[w.n_host + k] = np.asarray(plane)
        w.n_host = upto
        self.stats.host_blocks += 1

    def harvest(self) -> None:
        """Emit every row whose ``max_new`` tokens are all available and
        retire fully-done waves.

        Per wave, all planes any completable row needs are materialised
        in a single batched device→host transfer (at most one host
        block per wave per call) — the per-tick sync of the old engines
        is gone from the deferred path entirely.
        """
        for w in list(self._active):
            have = len(w.emitted)
            need = 0
            for local, row_uids in w.uids.items():
                for i in range(len(row_uids)):
                    if (not w.done[local][i]
                            and w.per_row_new[local][i] <= have):
                        need = max(need, w.per_row_new[local][i])
            if need > w.n_host:
                self._materialize(w, need)
            for local, row_uids in w.uids.items():
                for i, uid in enumerate(row_uids):
                    if w.done[local][i] or w.per_row_new[local][i] > have:
                        continue
                    seq = np.asarray(
                        [w.emitted[t][local, i] for t in
                         range(w.per_row_new[local][i])], np.int32)
                    self._finished.append((local, uid, seq))
                    self.stats.tokens_generated += len(seq)
                    w.done[local][i] = True
            if w.steps_left <= 0 and all(all(d) for d in w.done.values()):
                self._active.remove(w)

    def poll(self) -> List[Tuple[int, Any, np.ndarray]]:
        """Drain finished (local expert, uid, tokens) triples."""
        out, self._finished = self._finished, []
        return out

    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def has_pending(self) -> bool:
        """Active waves or finished rows not yet polled."""
        return bool(self._active or self._finished)


# ---------------------------------------------------------------------------
# Dispatch executors
# ---------------------------------------------------------------------------


class DispatchExecutor:
    """How one scheduler step drives its shards.

    ``run_step`` always issues every shard's prefill, then every
    shard's decode tick, then harvests — the ``defer`` flag decides
    whether each dispatch blocks on its own device→host copy (serial,
    the reference) or whether nothing blocks until the single batched
    harvest transfer per wave (overlapped). Because both orders run the
    identical compute graph, they are token-identical by construction;
    only ``EngineStats.host_blocks`` differs.
    """

    name = "base"
    defer = False

    def run_step(self, sched) -> None:
        sched._admit_batches(defer=self.defer)
        sched._tick_engines(defer=self.defer)
        sched._harvest_engines()


class SerialExecutor(DispatchExecutor):
    """Reference behaviour: every admit/tick materialises its sampled
    token immediately, blocking the host once per tick per wave before
    the next shard's work is issued."""

    name = "serial"
    defer = False


class OverlappedExecutor(DispatchExecutor):
    """Async dispatch: prefills and decode ticks for *all* shards are
    enqueued before anything blocks; tokens stay on device and the host
    blocks at most once per wave per step, inside the batched harvest.
    Prefill of one shard overlaps decode of another on the device
    queue."""

    name = "overlapped"
    defer = True


def get_executor(executor) -> DispatchExecutor:
    """Resolve ``'serial'`` / ``'overlapped'`` / an instance."""
    if isinstance(executor, DispatchExecutor):
        return executor
    if executor == "serial":
        return SerialExecutor()
    if executor == "overlapped":
        return OverlappedExecutor()
    raise ValueError(f"unknown executor {executor!r}; expected 'serial', "
                     "'overlapped' or a DispatchExecutor instance")
