"""Pallas TPU kernel: fine-grained assignment scores (paper's FA metric).

cos(z_i, mu_m) for every sample bottleneck z against every class centroid,
fused normalize + matmul in VMEM; invalid (padded) centroids masked to -inf
so downstream argmax is safe. Grid over sample tiles; the centroid matrix
(M x hid, few KB) is broadcast to every grid cell.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, c_ref, mask_ref, out_ref, *, eps: float):
    z = z_ref[...]                      # (bm, h)
    c = c_ref[...]                      # (M, h)
    mask = mask_ref[...]                # (1, M)
    zn = z * jax.lax.rsqrt(jnp.sum(z * z, -1, keepdims=True) + eps)
    cn = c * jax.lax.rsqrt(jnp.sum(c * c, -1, keepdims=True) + eps)
    sim = zn @ cn.T                     # (bm, M)
    out_ref[...] = jnp.where(mask > 0, sim, -jnp.inf)


def cosine_scores_pallas(z, centroids, mask, *, block_m: int = 128,
                         eps: float = 1e-12, interpret: bool = True):
    """z: (B, h); centroids: (M, h); mask: (M,). Returns (B, M) cosine
    similarity with masked classes = -inf."""
    B, h = z.shape
    M = centroids.shape[0]
    bm = min(block_m, B)
    assert B % bm == 0, (B, bm)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(B // bm,),
        in_specs=[
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((M, h), lambda i: (0, 0)),
            pl.BlockSpec((1, M), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, M), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M), z.dtype),
        interpret=interpret,
    )(z, centroids, mask[None, :].astype(z.dtype))
