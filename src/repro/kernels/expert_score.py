"""Pallas TPU kernel: fused AE-bank routing score (the paper's hot path).

For every (sample tile, expert k) grid cell, computes the full
encode -> ReLU -> decode -> per-sample MSE chain in VMEM:

    h    = relu(x @ W1_k + b1_k)         (BN folded into W1/b1 by ops.py)
    xhat = h @ W2_k + b2_k
    out[i, k] = mean((xhat - x)^2)

TPU adaptation (vs. launching K tiny GPU kernels): one pallas_call, grid
(B/bm, K); the 784-dim feature axis is zero-padded to 896 = 7*128 for VREG
lane alignment (zero padding is exact for MSE — pad reconstructs pad), and
the per-expert weights (896x128 + 128x896 ~ 900 KB f32) stay resident in
VMEM for the whole sample tile, so reconstructions never touch HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def pad_to_lane(d: int) -> int:
    return ((d + LANE - 1) // LANE) * LANE


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref, *, d_real: int):
    x = x_ref[...]  # (bm, Dp)
    h = jnp.maximum(x @ w1_ref[0] + b1_ref[0], 0.0)  # (bm, H)
    xhat = h @ w2_ref[0] + b2_ref[0]  # (bm, Dp)
    d = xhat - x
    out_ref[:, 0] = jnp.sum(d * d, axis=-1) / d_real


def expert_score_pallas(x, w1, b1, w2, b2, *, d_real: int, block_m: int = 128,
                        interpret: bool = True):
    """x: (B, Dp) f32; w1: (K, Dp, H); b1: (K, H); w2: (K, H, Dp);
    b2: (K, Dp). Returns (B, K) per-sample MSE. Dp must be lane-padded."""
    B, Dp = x.shape
    K, _, H = w1.shape
    bm = min(block_m, B)
    assert B % bm == 0, (B, bm)
    grid = (B // bm, K)
    return pl.pallas_call(
        functools.partial(_kernel, d_real=d_real),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, Dp), lambda i, k: (i, 0)),
            pl.BlockSpec((1, Dp, H), lambda i, k: (k, 0, 0)),
            pl.BlockSpec((1, H), lambda i, k: (k, 0)),
            pl.BlockSpec((1, H, Dp), lambda i, k: (k, 0, 0)),
            pl.BlockSpec((1, Dp), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, k: (i, k)),
        out_shape=jax.ShapeDtypeStruct((B, K), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)
