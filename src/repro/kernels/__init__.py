"""Pallas TPU kernels for the framework's compute hot-spots.

  expert_score.py     — fused AE-bank routing score (encode→decode→MSE)
  cosine_topk.py      — fine-grained assignment cosine scores
  decode_attention.py — GQA flash-decode vs (ring) KV cache
  wkv_step.py         — fused RWKV6 decode step (state + output, one pass)

Each kernel ships with a pure-jnp oracle in ref.py and a jitted public
wrapper in ops.py; kernels run with interpret=True on CPU (validated
against the oracles in tests/test_kernels.py) and compile via Mosaic on
real TPUs.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
