"""Pallas TPU kernel: fused WKV6 decode step (RWKV serving hot spot).

One grid cell per (batch row, head): reads the (P x P) wkv state tile,
produces the output token projection and the decayed state update in a
single VMEM pass —

    o[j]   = sum_i r[i] * (S[i,j] + u[i] k[i] v[j])
    S'[i,j] = exp(logw[i]) * S[i,j] + k[i] v[j]

The state (B, H, P, P) is the decode working set (it IS the "KV cache" of
an attention-free model); fusing output + update halves its HBM traffic
per token vs the two-pass jnp formulation. Oracle: repro.models.rwkv6.wkv_step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s_ref, o_ref, s_out_ref):
    r = r_ref[0, 0].astype(jnp.float32)      # (1, P)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)         # (1, P)
    S = s_ref[0].astype(jnp.float32)         # (P, P)
    kv = k.T @ v                              # (P, P) outer product
    # o[j] = sum_i r[i] * (S[i,j] + u[i]*k[i]*v[j])  ==  r @ S + (r·(u*k)) v
    o_state = r @ S                           # (1, P)
    o_bonus = jnp.sum(r * u * k) * v          # (1, P)
    o_ref[0, 0] = (o_state + o_bonus).astype(o_ref.dtype)
    s_out_ref[0] = (jnp.exp(w).T * S + kv).astype(s_out_ref.dtype)


def wkv_step_pallas(r, k, v, logw, u, state, *, interpret: bool = True):
    """r/k/v/logw: (B, H, P); u: (H, P); state: (B, H, P, P) f32.
    Returns (o (B, H, P) f32, new_state (B, H, P, P) f32)."""
    B, H, P = r.shape
    rs = r.reshape(B, H, 1, P)
    ks = k.reshape(B, H, 1, P)
    vs = v.reshape(B, H, 1, P)
    ws = logw.reshape(B, H, 1, P)
    o, s_new = pl.pallas_call(
        _kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, 1, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h: (h, 0, 0)),
            pl.BlockSpec((1, P, P), lambda b, h: (b * H + h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, P, P), lambda b, h: (b * H + h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, 1, P), jnp.float32),
            jax.ShapeDtypeStruct((B * H, P, P), jnp.float32),
        ],
        interpret=interpret,
    )(rs, ks, vs, ws, u.reshape(H, 1, P),
      state.reshape(B * H, P, P).astype(jnp.float32))
    return o.reshape(B, H, P), s_new.reshape(B, H, P, P)
