"""Pallas TPU kernels: GQA flash-decode (single query token vs. KV cache),
dense-ring and paged variants.

``decode_attention_pallas`` — grid (B, KV_heads, S_blocks); for each
(batch row, kv head) the G = H/KV query heads attend to one KV-cache
block per grid step with an online-softmax carried in VMEM scratch
(m, l, acc). Position ids (-1 = empty ring slot) provide the mask, so
full and sliding-window ring caches use the same kernel. Block size is
the VMEM tiling knob: (block_s, dh) K/V tiles.

``paged_decode_attention_pallas`` — the paged-KV variant: K/V live in a
pool of fixed-size pages ``(P + 1, page, KV, dh)`` (last page is the
write-discard "trash" page) and each row carries a page table mapping
its logical cache pages to physical pool pages, so prefix-sharing rows
point at the *same* physical pages with zero copying. The table rides
in as a scalar-prefetch argument (``pltpu.PrefetchScalarGridSpec``):
the BlockSpec index maps read ``table[b, s]`` to DMA exactly the pages
a row owns — the kernel never materialises a dense per-row KV view.
The online-softmax body is shared with the ring kernel; position ids
are logical-slot-indexed and mask trash-backed (never-written) pages.

The pure-jnp oracle for both is ``repro.models.attention.attention``
(chunk=0), composed with a page-table gather for the paged variant
(``repro.kernels.ref.paged_decode_attention_ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, window: int, n_blocks: int):
    blk = pl.program_id(2)

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]          # (G, dh)
    k = k_ref[0]             # (bs, dh)
    v = v_ref[0]             # (bs, dh)
    kv_pos = pos_ref[0]      # (bs,)
    q_pos = qpos_ref[0]      # scalar int32
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.dot(q.astype(jnp.float32), k.T.astype(jnp.float32)) * scale
    ok = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window:
        ok &= kv_pos > q_pos - window
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]      # (G, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v.astype(jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(blk == n_blocks - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, q_pos, kv_pos, *, window: int = 0,
                            block_s: int = 512, interpret: bool = True):
    """q: (B, H, dh); k, v: (B, S, KV, dh); q_pos: () int32;
    kv_pos: (S,) int32 (-1 = empty). Returns (B, H, dh)."""
    B, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    n_blocks = S // bs
    qg = q.reshape(B, KV, G, dh)
    kt = jnp.moveaxis(k, 2, 1)  # (B, KV, S, dh)
    vt = jnp.moveaxis(v, 2, 1)
    out = pl.pallas_call(
        functools.partial(_kernel, window=window, n_blocks=n_blocks),
        grid=(B, KV, n_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j, s: (0,)),
            pl.BlockSpec((1, 1, G, dh), lambda b, j, s: (b, j, 0, 0)),
            pl.BlockSpec((1, bs, dh), lambda b, j, s: (b * KV + j, s, 0)),
            pl.BlockSpec((1, bs, dh), lambda b, j, s: (b * KV + j, s, 0)),
            pl.BlockSpec((1, bs), lambda b, j, s: (0, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, j, s: (b, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos.reshape(1).astype(jnp.int32),
      qg, kt.reshape(B * KV, S, dh), vt.reshape(B * KV, S, dh),
      kv_pos[None, :].astype(jnp.int32))
    return out.reshape(B, H, dh)


def _paged_kernel(tbl_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, window: int, n_blocks: int):
    # the page-table ref is consumed by the BlockSpec index maps (it
    # decides WHICH page was DMA'd here); the softmax body is the ring
    # kernel's, operating on whatever page landed in VMEM
    del tbl_ref
    _kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_ref, l_ref, acc_ref, window=window, n_blocks=n_blocks)


def paged_decode_attention_pallas(q, k_pages, v_pages, table, q_pos,
                                  kv_pos, *, window: int = 0,
                                  interpret: bool = True):
    """Flash-decode through a per-row page table.

    q: (B, H, dh); k_pages, v_pages: (P1, page, KV, dh) physical pool
    (``P1 - 1`` is the trash page — writable garbage, always masked);
    table: (B, n_pages) int32 physical page per logical page; q_pos: ()
    int32; kv_pos: (C,) int32 logical-slot positions (-1 = empty),
    C = n_pages * page. Returns (B, H, dh).

    One grid step DMAs exactly one physical page per (row, kv head):
    the scalar-prefetched table feeds the K/V BlockSpec index maps, so
    prefix-sharing rows re-read the same pool pages and no dense
    per-row KV copy ever exists.
    """
    B, H, dh = q.shape
    P1, page, KV = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    nlp = table.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, dh)
    kp = jnp.moveaxis(k_pages, 2, 1).reshape(P1 * KV, page, dh)
    vp = jnp.moveaxis(v_pages, 2, 1).reshape(P1 * KV, page, dh)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, nlp),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, j, s, tbl, qp:
                         (b, j, 0, 0)),
            pl.BlockSpec((1, page, dh), lambda b, j, s, tbl, qp:
                         (tbl[b * nlp + s] * KV + j, 0, 0)),
            pl.BlockSpec((1, page, dh), lambda b, j, s, tbl, qp:
                         (tbl[b * nlp + s] * KV + j, 0, 0)),
            pl.BlockSpec((1, page), lambda b, j, s, tbl, qp: (0, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, j, s, tbl, qp:
                               (b, j, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, window=window, n_blocks=nlp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, dh), q.dtype),
        interpret=interpret,
    )(table.reshape(-1).astype(jnp.int32),
      q_pos.reshape(1).astype(jnp.int32),
      qg, kp, vp, kv_pos[None, :].astype(jnp.int32))
    return out.reshape(B, H, dh)
