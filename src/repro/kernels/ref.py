"""Pure-jnp oracles for every Pallas kernel (allclose-tested in CI)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_score_ref(x, w1, b1, w2, b2, *, d_real: int):
    """x: (B, Dp); w1: (K, Dp, H); ... -> (B, K) per-sample MSE over the
    first d_real features (padding reconstructs to zero exactly)."""
    h = jnp.maximum(jnp.einsum("bd,kdh->kbh", x, w1) + b1[:, None, :], 0.0)
    xhat = jnp.einsum("kbh,khd->kbd", h, w2) + b2[:, None, :]
    mse = jnp.sum(jnp.square(xhat - x[None]), axis=-1) / d_real
    return mse.T


def cosine_scores_ref(z, centroids, mask, eps: float = 1e-12):
    zn = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True),
                         jnp.sqrt(eps))
    cn = centroids / jnp.maximum(
        jnp.linalg.norm(centroids, axis=-1, keepdims=True), jnp.sqrt(eps))
    sim = zn @ cn.T
    return jnp.where(mask[None, :] > 0, sim, -jnp.inf)


def decode_attention_ref(q, k, v, q_pos, kv_pos, *, window: int = 0):
    """q: (B, H, dh); k/v: (B, S, KV, dh) -> (B, H, dh)."""
    from ..models.attention import attention
    o = attention(q[:, None], k, v, q_pos=q_pos[None].astype(jnp.int32),
                  kv_pos=kv_pos, window=window, chunk=0)
    return o[:, 0]


def paged_decode_attention_ref(q, k_pages, v_pages, table, q_pos, kv_pos,
                               *, window: int = 0):
    """Paged oracle: gather each row's logical KV through its page table
    into a dense (B, C, KV, dh) view, then run the ring reference.

    q: (B, H, dh); k_pages/v_pages: (P1, page, KV, dh); table:
    (B, n_pages) int32; kv_pos: (C,) with C = n_pages * page.
    """
    from ..models.attention import paged_gather
    k, v = paged_gather(k_pages, v_pages, table)
    return decode_attention_ref(q, k, v, q_pos, kv_pos, window=window)
