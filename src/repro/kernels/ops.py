"""Jitted public wrappers around the Pallas kernels.

``expert_score(bank_params, x)`` is a drop-in for
``repro.core.autoencoder.bank_scores``: it folds each AE's eval-mode
BatchNorm into the encoder weights, lane-pads 784 -> 896, and calls the
fused kernel. ``interpret=True`` everywhere in this container (CPU);
on a real TPU pass ``interpret=False`` for the Mosaic path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .cosine_topk import cosine_scores_pallas
from .decode_attention import (decode_attention_pallas,
                               paged_decode_attention_pallas)
from .expert_score import expert_score_pallas, pad_to_lane
from .wkv_step import wkv_step_pallas

IN_DIM = 784


def fold_bank(bank_params, bank_states, eps: float = 1e-5):
    """Fold eval-mode BN into (W1, b1); lane-pad the feature dim.

    Returns dict(w1 (K, Dp, H), b1 (K, H), w2 (K, H, Dp), b2 (K, Dp)).
    """
    scale = bank_params["bn_scale"] * jax.lax.rsqrt(
        bank_states["var"] + eps)  # (K, H)
    w1 = bank_params["w_enc"] * scale[:, None, :]
    b1 = (bank_params["b_enc"] - bank_states["mean"]) * scale \
        + bank_params["bn_bias"]
    w2, b2 = bank_params["w_dec"], bank_params["b_dec"]
    K, D, H = w1.shape
    Dp = pad_to_lane(D)
    w1 = jnp.pad(w1, ((0, 0), (0, Dp - D), (0, 0)))
    w2 = jnp.pad(w2, ((0, 0), (0, 0), (0, Dp - D)))
    b2 = jnp.pad(b2, ((0, 0), (0, Dp - D)))
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2, "d_real": D}


@functools.partial(jax.jit, static_argnames=("interpret", "block_m"))
def expert_score_folded(folded, x, *, interpret: bool = True,
                        block_m: int = 128):
    """x: (B, 784) -> (B, K) reconstruction MSE via the fused kernel."""
    B, D = x.shape  # D = real (unpadded) feature dim — static at trace time
    Dp = folded["w1"].shape[1]
    xpad = jnp.pad(x, ((0, 0), (0, Dp - D)))
    bm = min(block_m, B)
    while B % bm:
        bm //= 2
    return expert_score_pallas(xpad, folded["w1"], folded["b1"],
                               folded["w2"], folded["b2"],
                               d_real=D, block_m=max(bm, 1),
                               interpret=interpret)


def expert_score(bank_params, x, bank_states=None, *, interpret: bool = True):
    """Convenience entry used by MatcherConfig(use_kernel=True)."""
    if bank_states is None:  # identity BN stats
        K, _, H = bank_params["w_enc"].shape
        bank_states = {"mean": jnp.zeros((K, H)), "var": jnp.ones((K, H)),
                       "count": jnp.zeros((K,))}
    folded = fold_bank(bank_params, bank_states)
    return expert_score_folded(folded, x, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cosine_scores(z, centroids, mask, *, interpret: bool = True):
    B = z.shape[0]
    bm = 128
    while B % bm:
        bm //= 2
    return cosine_scores_pallas(z, centroids, mask, block_m=max(bm, 1),
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret",
                                             "block_s"))
def decode_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                     block_s: int = 512, interpret: bool = True):
    S = k.shape[1]
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    return decode_attention_pallas(q, k, v, q_pos, kv_pos, window=window,
                                   block_s=max(bs, 1), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, table, q_pos, kv_pos, *,
                           window: int = 0, interpret: bool = True):
    """Flash-decode gathering K/V through a per-row page table (the
    paged-KV serving layout). Block size is the page size — the pool's
    physical granularity IS the kernel's VMEM tile."""
    return paged_decode_attention_pallas(q, k_pages, v_pages, table,
                                         q_pos, kv_pos, window=window,
                                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_decode_step(r, k, v, logw, u, state, *, interpret: bool = True):
    """Fused RWKV6 decode step (output + state update in one VMEM pass)."""
    return wkv_step_pallas(r, k, v, logw, u, state, interpret=interpret)
