"""Expert registry: binds matcher bank indices to actual expert backends.

In the paper an "expert" is a pretrained task model on the server. In this
framework an expert entry carries (a) the dataset fingerprint the AE was
trained on, (b) a handle to the serving backend (any of the 10 zoo
architectures, or a lightweight classifier), and (c) optional per-class
sub-experts for fine-grained routing.

The registry is intentionally dumb: the matcher picks indices, the
registry resolves them. New experts can be appended without retraining
anything else — the paper's "modularity" property.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence


@dataclasses.dataclass
class ExpertEntry:
    name: str
    backend: Any = None                     # serving engine / callable
    fine_backends: Optional[List[Any]] = None  # per-class sub-experts
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


class ExpertRegistry:
    def __init__(self):
        self._entries: List[ExpertEntry] = []

    def add(self, name: str, backend=None, fine_backends=None, **meta) -> int:
        self._entries.append(ExpertEntry(name, backend, fine_backends, meta))
        return len(self._entries) - 1

    def __len__(self):
        return len(self._entries)

    def __getitem__(self, idx: int) -> ExpertEntry:
        return self._entries[idx]

    @property
    def names(self) -> List[str]:
        return [e.name for e in self._entries]

    def resolve(self, coarse_idx: int, fine_idx: Optional[int] = None):
        e = self._entries[coarse_idx]
        if fine_idx is not None and e.fine_backends:
            return e.fine_backends[fine_idx]
        return e.backend
