"""Expert registry: binds matcher bank indices to actual expert backends.

In the paper an "expert" is a pretrained task model on the server. In this
framework an expert entry carries (a) the dataset fingerprint the AE was
trained on, (b) a handle to the serving backend (any of the 10 zoo
architectures, or a lightweight classifier), and (c) optional per-class
sub-experts for fine-grained routing.

The registry is intentionally dumb: the matcher picks indices, the
registry resolves them. New experts can be appended without retraining
anything else — the paper's "modularity" property.

``ExpertSpec`` is the one serving-facing description of an expert:
architecture config plus engine geometry. The placement planner groups
experts into banks by spec equality, the expert hub keys its catalog
(and slot compatibility) on it, and registry entries carry it so every
consumer reads the same catalog entry type instead of re-deriving
ad-hoc signatures from live engine objects.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ExpertSpec:
    """Serving-relevant description of one expert.

    Two experts with equal specs compile identical executables: same
    architecture (``arch`` is the config with the per-expert ``name``
    normalised out), same bucket ladders, same KV layout/pool geometry.
    That equality is exactly what makes them co-residable — in one
    ``BankedEngine`` (placement planning) or one hub slot bank (dynamic
    residency) — so spec equality IS the banking/slot-compatibility
    predicate, defined once here.
    """

    arch: Any                           # ArchConfig, name stripped
    max_len: int
    len_buckets: Tuple[int, ...]
    batch_buckets: Tuple[int, ...]
    kv_layout: str = "ring"
    page: Optional[int] = None          # paged-layout pool geometry
    pool_pages: Optional[int] = None
    chunk_len: Optional[int] = None     # chunked-prefill grid (None =
    #                                     monolithic prefill only) — part
    #                                     of the executable ladder, so
    #                                     differently-chunked engines
    #                                     must not bank together
    speculate_k: int = 0                # draft-k/verify-1 speculative
    #                                     decoding — adds the (Bb, k)
    #                                     verify ladder, so spec-k must
    #                                     match across a bank
    draft: Optional[str] = None         # draft model name ("mlp",
    #                                     "table", "always-wrong")

    @classmethod
    def of_engine(cls, engine) -> "ExpertSpec":
        """The spec of a live ``ExpertEngine`` (or any engine exposing
        the same geometry attributes)."""
        kv = getattr(engine, "kv_layout", "ring")
        page = pool_pages = chunk_len = None
        if kv == "paged":
            page = engine.core.page
            pool_pages = engine.core.pool.n_pages
            chunk_len = engine.core.chunk_len
        core = getattr(engine, "core", None)
        return cls(arch=engine.model.cfg.replace(name=""),
                   max_len=engine.max_len,
                   len_buckets=tuple(engine.len_buckets),
                   batch_buckets=tuple(engine.batch_buckets),
                   kv_layout=kv, page=page, pool_pages=pool_pages,
                   chunk_len=chunk_len,
                   speculate_k=getattr(core, "speculate_k", 0),
                   draft=getattr(core, "draft_name", None))

    @property
    def bankable(self) -> bool:
        """Whether experts of this spec may share a stacked dispatch.

        Banking is only sound for models whose per-row outputs don't
        depend on batch padding: capacity-dispatch MoE computes its
        expert capacity from the *total* (padded) token count and
        padding rows consume capacity slots, so padding one member's
        micro-batch to a wave-wide batch bucket could change a real
        row's tokens vs the per-engine path.
        """
        return not (self.arch.n_experts and self.arch.moe_impl ==
                    "dispatch")


@dataclasses.dataclass
class ExpertEntry:
    name: str
    backend: Any = None                     # serving engine / callable
    fine_backends: Optional[List[Any]] = None  # per-class sub-experts
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    spec: Optional[ExpertSpec] = None       # shared catalog entry type


class ExpertRegistry:
    def __init__(self):
        self._entries: List[ExpertEntry] = []

    def add(self, name: str, backend=None, fine_backends=None,
            spec: Optional[ExpertSpec] = None, **meta) -> int:
        self._entries.append(
            ExpertEntry(name, backend, fine_backends, meta, spec))
        return len(self._entries) - 1

    def __len__(self):
        return len(self._entries)

    def __getitem__(self, idx: int) -> ExpertEntry:
        return self._entries[idx]

    @property
    def names(self) -> List[str]:
        return [e.name for e in self._entries]

    def resolve(self, coarse_idx: int, fine_idx: Optional[int] = None):
        e = self._entries[coarse_idx]
        if fine_idx is not None and e.fine_backends:
            return e.fine_backends[fine_idx]
        return e.backend
