"""ExpertMatcher core — the paper's contribution as a composable module.

Pipeline (Fig. 2 of the paper):
  1. ``trainer.train_bank``   — one AE per expert dataset (server side)
  2. ``matcher.build_matcher``— freeze bank + per-class centroids
  3. ``matcher.route``        — coarse (MSE argmin) then fine (cosine) routing
  4. ``registry``             — resolve routed indices to serving backends
"""
from .autoencoder import (bank_encode, bank_scores, decode, encode, forward,
                          init_ae, recon_mse, stack_bank)
from .matcher import (ExpertMatcher, MatcherConfig, build_matcher,
                      class_centroids)
from .mlp_baseline import init_mlp
from .registry import ExpertEntry, ExpertRegistry, ExpertSpec
from .trainer import train_ae, train_bank, train_mlp

__all__ = [
    "init_ae", "encode", "decode", "forward", "recon_mse", "stack_bank",
    "bank_scores", "bank_encode",
    "ExpertMatcher", "MatcherConfig", "build_matcher", "class_centroids",
    "init_mlp", "ExpertEntry", "ExpertRegistry", "ExpertSpec",
    "train_ae", "train_bank", "train_mlp",
]
