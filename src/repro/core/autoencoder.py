"""The paper's autoencoder: 784 -> 128 -> 784 single-layer MLP enc/dec with
BatchNorm, trained with MSE reconstruction loss (Sec. 4, Implementation
Details). A *bank* of K such AEs (one per expert dataset) is stored with
stacked params so scoring a batch against all K experts is one vmap.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.common import KeyGen, dense_init

IN_DIM = 784
HID_DIM = 128


def init_ae(key, in_dim: int = IN_DIM, hid_dim: int = HID_DIM):
    kg = KeyGen(key)
    params = {
        "w_enc": dense_init(kg(), (in_dim, hid_dim), jnp.float32),
        "b_enc": jnp.zeros((hid_dim,), jnp.float32),
        "bn_scale": jnp.ones((hid_dim,), jnp.float32),
        "bn_bias": jnp.zeros((hid_dim,), jnp.float32),
        "w_dec": dense_init(kg(), (hid_dim, in_dim), jnp.float32),
        "b_dec": jnp.zeros((in_dim,), jnp.float32),
    }
    bn_state = {"mean": jnp.zeros((hid_dim,), jnp.float32),
                "var": jnp.ones((hid_dim,), jnp.float32),
                "count": jnp.zeros((), jnp.float32)}
    return params, bn_state


def _bn(h, params, state, train: bool, momentum: float = 0.9):
    if train:
        mu = jnp.mean(h, axis=0)
        var = jnp.var(h, axis=0)
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mu,
            "var": momentum * state["var"] + (1 - momentum) * var,
            "count": state["count"] + 1,
        }
    else:
        mu, var = state["mean"], state["var"]
        new_state = state
    hn = (h - mu) * jax.lax.rsqrt(var + 1e-5)
    return hn * params["bn_scale"] + params["bn_bias"], new_state


def encode(params, state, x, train: bool = False):
    """x: (B, in_dim) -> (bottleneck (B, hid), new_bn_state)."""
    h = x @ params["w_enc"] + params["b_enc"]
    h, new_state = _bn(h, params, state, train)
    return jax.nn.relu(h), new_state


def decode(params, z):
    return z @ params["w_dec"] + params["b_dec"]


def forward(params, state, x, train: bool = False):
    z, new_state = encode(params, state, x, train)
    return decode(params, z), z, new_state


def recon_mse(params, state, x, train: bool = False):
    """Per-sample reconstruction MSE: (B,)."""
    xhat, _, new_state = forward(params, state, x, train)
    return jnp.mean(jnp.square(xhat - x), axis=-1), new_state


def loss_fn(params, state, x):
    """Scalar training loss (mean MSE over the batch)."""
    per, new_state = recon_mse(params, state, x, train=True)
    return jnp.mean(per), new_state


# ---------------------------------------------------------------------------
# AE bank: stacked params over K experts
# ---------------------------------------------------------------------------


def stack_bank(aes):
    """List of (params, bn_state) -> (stacked_params, stacked_state)."""
    params = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                    *[a[0] for a in aes])
    states = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                    *[a[1] for a in aes])
    return params, states


def bank_scores(bank_params, bank_states, x):
    """Reconstruction MSE of every sample under every AE.

    x: (B, in_dim) -> (B, K) MSE matrix (lower = better match).
    """
    def one(params, state):
        mse, _ = recon_mse(params, state, x, train=False)
        return mse

    return jax.vmap(one)(bank_params, bank_states).T  # (B, K)


def bank_encode(bank_params, bank_states, x):
    """Bottleneck features under every AE: (K, B, hid)."""
    def one(params, state):
        z, _ = encode(params, state, x, train=False)
        return z

    return jax.vmap(one)(bank_params, bank_states)
