"""ExpertMatcher: coarse (CA) and fine-grained (FA) expert assignment.

Implements the paper's full landscape (Fig. 1 axes):
  * Resolution — coarse (dataset-level, min reconstruction MSE) and fine
    (class-level, max cosine similarity of the bottleneck vs per-class
    centroids μ^n).
  * Fusion — top-1 or top-K expert selection (``top_k``).
  * Metric — "mse" (ad-hoc, paper default for CA), "cosine" (paper default
    for FA); both exposed for either resolution.

The matcher is a frozen artifact built from a trained AE bank + per-class
centroids; routing is a pure jittable function, and the Pallas kernel
``repro.kernels.expert_score`` is a drop-in for ``bank_scores`` on TPU.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import autoencoder as ae


@dataclasses.dataclass
class MatcherConfig:
    metric: str = "mse"          # coarse metric: mse | cosine
    fine_metric: str = "cosine"  # fine metric: cosine | mse
    top_k: int = 1               # fusion: number of experts returned
    use_kernel: bool = False     # route scoring through the Pallas kernel


class ExpertMatcher:
    """Routes client samples to expert models.

    Attributes:
      bank_params/bank_states: stacked AE params over K expert datasets.
      centroids: (K, N_max, hid) per-class mean bottleneck features,
        padded with zeros; centroid_mask: (K, N_max) validity mask.
      names: dataset/expert names, index-aligned with the bank.
    """

    def __init__(self, bank_params, bank_states, names: Sequence[str],
                 centroids=None, centroid_mask=None,
                 config: Optional[MatcherConfig] = None):
        self.bank_params = bank_params
        self.bank_states = bank_states
        self.names = list(names)
        self.centroids = centroids
        self.centroid_mask = centroid_mask
        self.config = config or MatcherConfig()

    @property
    def n_experts(self) -> int:
        return len(self.names)

    # -- coarse ----------------------------------------------------------
    def coarse_scores(self, x) -> jnp.ndarray:
        """(B, K) matching score; LOWER is better (MSE convention)."""
        if self.config.use_kernel:
            from ..kernels import ops as kops
            return kops.expert_score(self.bank_params, x, self.bank_states)
        if self.config.metric == "cosine":
            z = ae.bank_encode(self.bank_params, self.bank_states, x)
            xhat = jax.vmap(ae.decode)(self.bank_params, z)  # (K, B, D)
            sim = _cos(xhat, x[None]).T  # (B, K)
            return -sim
        return ae.bank_scores(self.bank_params, self.bank_states, x)

    def assign_coarse(self, x) -> jnp.ndarray:
        """Top-1 expert index per sample: (B,)."""
        return jnp.argmin(self.coarse_scores(x), axis=-1)

    def assign_coarse_topk(self, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Fusion: (indices (B, top_k), scores (B, top_k))."""
        s = self.coarse_scores(x)
        neg, idx = jax.lax.top_k(-s, self.config.top_k)
        return idx, -neg

    # -- fine ------------------------------------------------------------
    def fine_scores(self, x, expert_idx) -> jnp.ndarray:
        """Similarity of each sample to each class centroid of its expert.

        x: (B, D); expert_idx: (B,). Returns (B, N_max), invalid classes
        = -inf (cosine) so argmax is safe.
        """
        z = ae.bank_encode(self.bank_params, self.bank_states, x)  # (K,B,h)
        zi = jnp.take_along_axis(
            z, expert_idx[None, :, None], axis=0)[0]  # (B, h)
        cent = self.centroids[expert_idx]  # (B, N_max, h)
        mask = self.centroid_mask[expert_idx]  # (B, N_max)
        if self.config.fine_metric == "mse":
            d = jnp.mean(jnp.square(cent - zi[:, None, :]), axis=-1)
            sim = -d
        else:
            sim = _cos(cent, zi[:, None, :])
        return jnp.where(mask > 0, sim, -jnp.inf)

    def assign_fine(self, x, expert_idx=None) -> jnp.ndarray:
        """Class/model index within the coarse-assigned expert: (B,)."""
        if expert_idx is None:
            expert_idx = self.assign_coarse(x)
        return jnp.argmax(self.fine_scores(x, expert_idx), axis=-1)

    def route(self, x) -> Dict[str, jnp.ndarray]:
        """Hierarchical CA -> FA routing (Fig. 2)."""
        coarse_idx, coarse_score = self.assign_coarse_topk(x)
        fine_idx = self.assign_fine(x, coarse_idx[:, 0])
        return {"coarse": coarse_idx, "coarse_score": coarse_score,
                "fine": fine_idx}


def _cos(a, b, eps: float = 1e-8):
    """Cosine similarity over the last axis with broadcasting."""
    num = jnp.sum(a * b, axis=-1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1)
    return num / jnp.maximum(den, eps)


def class_centroids(params, state, xs: np.ndarray, ys: np.ndarray,
                    n_max: int):
    """Per-class mean bottleneck features for one AE (paper's μ^n).

    Returns (centroids (n_max, hid), mask (n_max,)).
    """
    z, _ = ae.encode(params, state, jnp.asarray(xs), train=False)
    z = np.asarray(z)
    hid = z.shape[-1]
    cent = np.zeros((n_max, hid), np.float32)
    mask = np.zeros((n_max,), np.float32)
    for c in range(int(ys.max()) + 1):
        sel = ys == c
        if sel.any():
            cent[c] = z[sel].mean(axis=0)
            mask[c] = 1.0
    return jnp.asarray(cent), jnp.asarray(mask)


def build_matcher(aes, names, centroid_data=None,
                  config: Optional[MatcherConfig] = None) -> ExpertMatcher:
    """aes: list of (params, bn_state); centroid_data: optional list of
    (xs, ys) per expert for FA centroids."""
    bank_params, bank_states = ae.stack_bank(aes)
    centroids = centroid_mask = None
    if centroid_data is not None:
        n_max = max(int(ys.max()) + 1 for _, ys in centroid_data)
        cents, masks = [], []
        for (params, state), (xs, ys) in zip(aes, centroid_data):
            c, m = class_centroids(params, state, xs, ys, n_max)
            cents.append(c)
            masks.append(m)
        centroids = jnp.stack(cents)
        centroid_mask = jnp.stack(masks)
    return ExpertMatcher(bank_params, bank_states, names, centroids,
                         centroid_mask, config)
