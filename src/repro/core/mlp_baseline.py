"""The paper's baseline: MLP softmax dataset classifier
(784 -> 256 -> 128 -> C) with BatchNorm (Table 2, "MLP-Softmax")."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.common import KeyGen, dense_init, softmax_xent


def init_mlp(key, in_dim: int = 784, n_classes: int = 4):
    kg = KeyGen(key)
    dims = [in_dim, 256, 128]
    params = {"layers": [], "w_out": dense_init(kg(), (128, n_classes),
                                                jnp.float32),
              "b_out": jnp.zeros((n_classes,), jnp.float32)}
    states = []
    for i in range(len(dims) - 1):
        params["layers"].append({
            "w": dense_init(kg(), (dims[i], dims[i + 1]), jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
            "bn_scale": jnp.ones((dims[i + 1],), jnp.float32),
            "bn_bias": jnp.zeros((dims[i + 1],), jnp.float32),
        })
        states.append({"mean": jnp.zeros((dims[i + 1],), jnp.float32),
                       "var": jnp.ones((dims[i + 1],), jnp.float32)})
    return params, states


def forward(params, states, x, train: bool = False, momentum: float = 0.9):
    new_states = []
    h = x
    for lp, st in zip(params["layers"], states):
        h = h @ lp["w"] + lp["b"]
        if train:
            mu, var = jnp.mean(h, axis=0), jnp.var(h, axis=0)
            new_states.append({
                "mean": momentum * st["mean"] + (1 - momentum) * mu,
                "var": momentum * st["var"] + (1 - momentum) * var})
        else:
            mu, var = st["mean"], st["var"]
            new_states.append(st)
        h = (h - mu) * jax.lax.rsqrt(var + 1e-5)
        h = jax.nn.relu(h * lp["bn_scale"] + lp["bn_bias"])
    logits = h @ params["w_out"] + params["b_out"]
    return logits, new_states


def loss_fn(params, states, x, y):
    logits, new_states = forward(params, states, x, train=True)
    return softmax_xent(logits, y), new_states


def predict(params, states, x):
    logits, _ = forward(params, states, x, train=False)
    return jnp.argmax(logits, axis=-1)
