"""Training loops for the matcher artifacts (paper Sec. 4 recipe):
Adam, lr 1e-2 decayed x0.1 every 15 epochs, 45 epochs, BatchNorm.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import autoencoder as ae
from . import mlp_baseline as mlp
from ..optim import adamw_init, adamw_update, step_decay


def _batches(n, batch_size, rng):
    idx = rng.permutation(n)
    for i in range(0, n - batch_size + 1, batch_size):
        yield idx[i:i + batch_size]


def train_ae(x: np.ndarray, *, key=None, epochs: int = 45,
             batch_size: int = 256, base_lr: float = 1e-2,
             lr_decay_epochs: int = 15, seed: int = 0,
             in_dim: int = 784, hid_dim: int = 128):
    """Train one autoencoder on one dataset. Returns (params, bn_state)."""
    key = key if key is not None else jax.random.PRNGKey(seed)
    params, bn_state = ae.init_ae(key, in_dim, hid_dim)
    opt = adamw_init(params)
    steps_per_epoch = max(1, len(x) // batch_size)
    lr_fn = step_decay(base_lr, every_steps=lr_decay_epochs * steps_per_epoch)

    @jax.jit
    def step(params, bn_state, opt, batch):
        (loss, new_bn), grads = jax.value_and_grad(
            ae.loss_fn, has_aux=True)(params, bn_state, batch)
        params, opt = adamw_update(grads, opt, params, lr_fn(opt["step"]))
        return params, new_bn, opt, loss

    rng = np.random.default_rng(seed)
    loss = jnp.float32(0)
    for _ in range(epochs):
        for bidx in _batches(len(x), min(batch_size, len(x)), rng):
            params, bn_state, opt, loss = step(
                params, bn_state, opt, jnp.asarray(x[bidx]))
    return params, bn_state


def train_bank(datasets: Sequence[Tuple[str, np.ndarray]], **kw):
    """Train one AE per (name, x) dataset. Returns (aes, names)."""
    aes, names = [], []
    for i, (name, x) in enumerate(datasets):
        aes.append(train_ae(x, seed=1000 + i, **kw))
        names.append(name)
    return aes, names


def train_mlp(xs: np.ndarray, ys: np.ndarray, *, n_classes: int,
              epochs: int = 45, batch_size: int = 256,
              base_lr: float = 1e-2, lr_decay_epochs: int = 15,
              seed: int = 0, in_dim: int = 784):
    """Train the MLP-softmax dataset classifier baseline."""
    params, states = mlp.init_mlp(jax.random.PRNGKey(seed), in_dim, n_classes)
    opt = adamw_init(params)
    steps_per_epoch = max(1, len(xs) // batch_size)
    lr_fn = step_decay(base_lr, every_steps=lr_decay_epochs * steps_per_epoch)

    @jax.jit
    def step(params, states, opt, bx, by):
        (loss, new_states), grads = jax.value_and_grad(
            mlp.loss_fn, has_aux=True)(params, states, bx, by)
        params, opt = adamw_update(grads, opt, params, lr_fn(opt["step"]))
        return params, new_states, opt, loss

    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        for bidx in _batches(len(xs), min(batch_size, len(xs)), rng):
            params, states, opt, _ = step(
                params, states, opt, jnp.asarray(xs[bidx]),
                jnp.asarray(ys[bidx]))
    return params, states
