"""Mamba2 (SSD) block — chunked state-space duality formulation.

TPU adaptation: the selective scan is computed chunkwise — intra-chunk
contributions are dense (Q x Q) matmuls on the MXU, inter-chunk state is a
short ``lax.scan`` over n_chunks carries of (H, N, P). This is the standard
SSD decomposition (Dao & Gu 2024) mapped to jnp einsums instead of a Triton
kernel. Single-token decode uses the exact recurrence with a carried
(B, H, N, P) state and a depthwise-conv ring buffer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, KeyGen, dense_init, groupnorm_heads

G = 1  # B/C projection groups (ngroups=1, standard for mamba2 LMs)


def init_mamba_layer(key, cfg: ArchConfig, dtype):
    kg = KeyGen(key)
    D, di, H, N, W = (cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state,
                      cfg.ssm_conv_width)
    return {
        "ln1": jnp.ones((D,), jnp.float32),
        "w_in_z": dense_init(kg(), (D, di), dtype),
        "w_in_x": dense_init(kg(), (D, di), dtype),
        "w_B": dense_init(kg(), (D, G * N), dtype),
        "w_C": dense_init(kg(), (D, G * N), dtype),
        "w_dt": dense_init(kg(), (D, H), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "conv_x": dense_init(kg(), (W, di), dtype),
        "conv_B": dense_init(kg(), (W, G * N), dtype),
        "conv_C": dense_init(kg(), (W, G * N), dtype),
        "ssm_norm": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(kg(), (di, D), dtype),
    }


def causal_conv(x, w):
    """Depthwise causal conv: x (B, L, C), w (W, C); y_t = sum_j w[j] x_{t-W+1+j}."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(W):
        y = y + pad[:, j:j + x.shape[1], :].astype(jnp.float32) * w[j].astype(jnp.float32)
    return y.astype(x.dtype)


def conv_step(window, w):
    """window: (B, W, C) — last W inputs (current last); w: (W, C)."""
    return jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(window.dtype)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x:  (B, L, H, P)   inputs (already dt-free; dt applied inside)
    dt: (B, L, H)      softplus'd step sizes
    A:  (H,)           negative decay rates
    Bm, Cm: (B, L, G, N)
    Returns (y (B, L, H, P), final_state (B, H, N, P)).
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    if L % chunk:  # pad with dt=0 steps (exact identity for the recurrence)
        pad = chunk - L % chunk
        padt = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        y, s = ssd_chunked(padt(x), padt(dt), A, padt(Bm), padt(Cm), chunk,
                           initial_state)
        return y[:, :L], s
    nc, Q = L // chunk, chunk
    hg = H // G  # heads per group

    def r(t, tail):  # reshape (B, L, ...) -> (B, nc, Q, ...)
        return t.reshape((Bsz, nc, Q) + tail)

    xg = r(x, (G, hg, P))
    dtg = r(dt, (G, hg))
    Bc = r(Bm, (G, N))
    Cc = r(Cm, (G, N))
    dA = dtg * A.reshape(G, hg)  # (B, nc, Q, G, hg), negative
    cs = jnp.cumsum(dA, axis=2)  # inclusive within-chunk cumsum

    # ---- intra-chunk (diagonal blocks) ----
    # scores[b,c,q,r,g] = C_q . B_r
    scores = jnp.einsum("bcqgn,bcrgn->bcqrg", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    # decay[b,c,q,r,g,h] = exp(cs_q - cs_r) for r <= q else 0
    gap = cs[:, :, :, None] - cs[:, :, None, :]  # (B,nc,Q,Q,G,hg)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: masked entries have gap > 0 -> exp overflows and the
    # where() backward turns inf * 0 into NaN gradients
    gap = jnp.where(tri[None, None, :, :, None, None], gap, -1e30)
    decay = jnp.exp(gap)
    w_qr = scores[..., None] * decay * dtg[:, :, None, :, :, :]  # dt at r
    y_diag = jnp.einsum("bcqrgh,bcrghp->bcqghp", w_qr,
                        xg.astype(jnp.float32))

    # ---- chunk states ----
    tail = cs[:, :, -1:, :, :] - cs  # decay from q to chunk end (>=0 exponent? negative)
    st = jnp.einsum("bcqgh,bcqgn,bcqghp->bcghnp",
                    jnp.exp(tail) * dtg, Bc.astype(jnp.float32),
                    xg.astype(jnp.float32))  # (B, nc, G, hg, N, P)
    total = jnp.exp(cs[:, :, -1, :, :])  # (B, nc, G, hg) chunk total decay

    # ---- inter-chunk scan ----
    if initial_state is None:
        s0 = jnp.zeros((Bsz, G, hg, N, P), jnp.float32)
    else:
        s0 = initial_state.reshape(Bsz, G, hg, N, P).astype(jnp.float32)

    def body(s_prev, inp):
        st_c, tot_c = inp  # (B,G,hg,N,P), (B,G,hg)
        s_new = s_prev * tot_c[..., None, None] + st_c
        return s_new, s_prev  # emit state *before* this chunk

    (s_fin, s_before) = jax.lax.scan(
        body, s0, (jnp.moveaxis(st, 1, 0), jnp.moveaxis(total, 1, 0)))
    s_before = jnp.moveaxis(s_before, 0, 1)  # (B, nc, G, hg, N, P)

    # ---- inter-chunk contribution ----
    y_off = jnp.einsum("bcqgn,bcghnp,bcqgh->bcqghp",
                       Cc.astype(jnp.float32), s_before, jnp.exp(cs))
    y = (y_diag + y_off).reshape(Bsz, L, H, P)
    return y.astype(x.dtype), s_fin.reshape(Bsz, H, N, P).astype(x.dtype)


def ssd_step(state, x1, dt1, A, B1, C1):
    """Exact single-step recurrence.

    state: (B, H, N, P); x1: (B, H, P); dt1: (B, H); B1, C1: (B, G, N).
    """
    Bsz, H, N, P = state.shape
    hg = H // G
    dA = jnp.exp(dt1.astype(jnp.float32) * A)  # (B, H)
    Bh = jnp.repeat(B1, hg, axis=1).astype(jnp.float32)  # (B, H, N)
    Ch = jnp.repeat(C1, hg, axis=1).astype(jnp.float32)
    upd = (dt1.astype(jnp.float32)[..., None, None]
           * Bh[..., :, None] * x1.astype(jnp.float32)[..., None, :])
    new = state.astype(jnp.float32) * dA[..., None, None] + upd
    y = jnp.einsum("bhnp,bhn->bhp", new, Ch)
    return new.astype(state.dtype), y.astype(x1.dtype)


def mamba_seq(lp, x, cfg: ArchConfig, initial_state=None):
    """Full-sequence Mamba2 mixer on pre-normed input x (B, L, D)."""
    Bsz, L, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = x @ lp["w_in_z"]
    xr = causal_conv(x @ lp["w_in_x"], lp["conv_x"])
    xr = jax.nn.silu(xr)
    Bm = jax.nn.silu(causal_conv(x @ lp["w_B"], lp["conv_B"]))
    Cm = jax.nn.silu(causal_conv(x @ lp["w_C"], lp["conv_C"]))
    dtv = jax.nn.softplus(
        (x @ lp["w_dt"]).astype(jnp.float32) + lp["dt_bias"])  # (B, L, H)
    A = -jnp.exp(lp["A_log"])  # (H,)
    xh = xr.reshape(Bsz, L, H, P)
    y, s_fin = ssd_chunked(xh, dtv, A, Bm.reshape(Bsz, L, G, N),
                           Cm.reshape(Bsz, L, G, N), cfg.ssm_chunk,
                           initial_state)
    y = y + lp["D_skip"].reshape(H, 1) * xh.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32)).reshape(Bsz, L, H, P)
    y = groupnorm_heads(y, lp["ssm_norm"].reshape(H, P))
    out = y.reshape(Bsz, L, cfg.d_inner) @ lp["w_out"]
    return out.astype(x.dtype), s_fin


def mamba_step(lp, x, state, conv_buf, cfg: ArchConfig):
    """Single-token Mamba2 mixer.

    x: (B, 1, D); state: (B, H, N, P); conv_buf: dict of last W-1 raw conv
    inputs for x/B/C. Returns (out (B,1,D), state, conv_buf).
    """
    Bsz = x.shape[0]
    H, P, N, W = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv_width
    x0 = x[:, 0]
    z = x0 @ lp["w_in_z"]
    xi = x0 @ lp["w_in_x"]
    Bi = x0 @ lp["w_B"]
    Ci = x0 @ lp["w_C"]

    def roll(buf, new):  # buf (B, W-1, C) -> window (B, W, C), new buf
        win = jnp.concatenate([buf, new[:, None]], axis=1)
        return win, win[:, 1:]

    win_x, nb_x = roll(conv_buf["x"], xi)
    win_B, nb_B = roll(conv_buf["B"], Bi)
    win_C, nb_C = roll(conv_buf["C"], Ci)
    xr = jax.nn.silu(conv_step(win_x, lp["conv_x"]))
    Bm = jax.nn.silu(conv_step(win_B, lp["conv_B"]))
    Cm = jax.nn.silu(conv_step(win_C, lp["conv_C"]))
    dtv = jax.nn.softplus(
        (x0 @ lp["w_dt"]).astype(jnp.float32) + lp["dt_bias"])  # (B, H)
    A = -jnp.exp(lp["A_log"])
    new_state, y = ssd_step(state, xr.reshape(Bsz, H, P), dtv, A,
                            Bm.reshape(Bsz, G, N), Cm.reshape(Bsz, G, N))
    y = y.astype(jnp.float32) + lp["D_skip"].reshape(H, 1) * xr.reshape(Bsz, H, P).astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32)).reshape(Bsz, H, P)
    y = groupnorm_heads(y, lp["ssm_norm"].reshape(H, P))
    out = (y.reshape(Bsz, cfg.d_inner) @ lp["w_out"]).astype(x.dtype)
    return out[:, None], new_state, {"x": nb_x, "B": nb_B, "C": nb_C}
