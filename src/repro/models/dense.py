"""Decoder-only transformer: dense (llama/qwen-style GQA), MoE (mixtral/
olmoe), and VLM backbone (stub patch embeddings prepended).

Layers are applied with ``jax.lax.scan`` over stacked params so HLO size is
O(1) in depth. ``cfg.remat`` wraps the layer body in ``jax.checkpoint``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .api import BaseModel, register_family
from .attention import (attention, cache_append, cache_prefill,
                        init_kv_cache, paged_append, paged_append_rows,
                        paged_gather, paged_scatter_pages, suffix_attend)
from .common import (ArchConfig, KeyGen, apply_rope, dense_init, dt,
                     embed_init, ones_init, rmsnorm, softmax_xent, zeros_init)
from .moe import init_moe, moe_ffn
from ..sharding import shard_act

BATCH = ("pod", "data")


def _init_layer(key, cfg: ArchConfig, dtype):
    kg = KeyGen(key)
    D, H, KV, dh, F = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh, cfg.d_ff
    p = {
        "ln1": jnp.ones((D,), jnp.float32),
        "ln2": jnp.ones((D,), jnp.float32),
        "wq": dense_init(kg(), (D, H * dh), dtype),
        "wk": dense_init(kg(), (D, KV * dh), dtype),
        "wv": dense_init(kg(), (D, KV * dh), dtype),
        "wo": dense_init(kg(), (H * dh, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dtype)
        p["bk"] = jnp.zeros((KV * dh,), dtype)
        p["bv"] = jnp.zeros((KV * dh,), dtype)
    if cfg.n_experts:
        p["moe"] = init_moe(kg(), cfg, dtype)
    else:
        p["mlp"] = {
            "w_gate": dense_init(kg(), (D, F), dtype),
            "w_up": dense_init(kg(), (D, F), dtype),
            "w_down": dense_init(kg(), (F, D), dtype),
        }
    return p


def _qkv(h, lp, cfg: ArchConfig, positions):
    B, S, D = h.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q = q + lp["bq"]
        k = k + lp["bk"]
        v = v + lp["bv"]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, KV, dh)
    v = v.reshape(B, S, KV, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_act(q, (BATCH, None, "model", None))
    k = shard_act(k, (BATCH, None, "model", None))
    return q, k, v


def _ffn(h, lp, cfg: ArchConfig, dropless: bool = False):
    if cfg.n_experts:
        return moe_ffn(lp["moe"], h, cfg, dropless)
    mp = lp["mlp"]
    g = jax.nn.silu(h @ mp["w_gate"])
    u = h @ mp["w_up"]
    y = (g * u) @ mp["w_down"]
    return y, jnp.float32(0.0)


def _layer_full(x, lp, cfg: ArchConfig, positions):
    """Full-sequence layer (train / prefill). Returns (x, (k, v), aux)."""
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = _qkv(h, lp, cfg, positions)
    o = attention(q, k, v, q_pos=positions, kv_pos=positions,
                  window=cfg.sliding_window, chunk=cfg.attn_chunk)
    B, S = x.shape[:2]
    x = x + (o.reshape(B, S, -1) @ lp["wo"]).astype(x.dtype)
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    y, aux = _ffn(h2, lp, cfg)
    x = x + y.astype(x.dtype)
    # sequence parallelism: between TP blocks the residual stream is
    # sharded along seq over `model` (Korthikanti et al.) — GSPMD turns the
    # Megatron all-reduces into reduce-scatter + all-gather pairs and the
    # per-device activation footprint drops by the model-axis size
    x = shard_act(x, (BATCH, "model" if cfg.seq_parallel else None, None))
    return x, (k, v), aux


def _layer_suffix(x, lp, cfg: ArchConfig, positions, pk, pv, offset):
    """Suffix-prefill layer: queries at absolute `positions` attend over
    the gathered prefix KV (positions 0..offset-1) plus the suffix's own
    KV. Returns (x, (k, v)) where k, v cover only the suffix slice."""
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = _qkv(h, lp, cfg, positions)
    o = suffix_attend(q, k, v, pk, pv, offset=offset,
                      window=cfg.sliding_window, chunk=cfg.attn_chunk)
    B, S = x.shape[:2]
    x = x + (o.reshape(B, S, -1) @ lp["wo"]).astype(x.dtype)
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    y, _ = _ffn(h2, lp, cfg)
    x = x + y.astype(x.dtype)
    x = shard_act(x, (BATCH, "model" if cfg.seq_parallel else None, None))
    return x, (k, v)


def _layer_decode(x, lp, layer_cache, cfg: ArchConfig, pos_scalar):
    """Single-token layer. layer_cache: {k, v} slices + shared pos/t.
    ``pos_scalar`` is the query position — () shared across rows (plain
    decode) or (B,) per-row; either way the math is elementwise-
    identical per row."""
    q_pos = pos_scalar[..., None]         # (1,) shared or (B, 1) per-row
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k1, v1 = _qkv(h, lp, cfg, q_pos)
    new_k, new_v, kv_pos = layer_cache["update"](k1, v1)
    o = attention(q, new_k, new_v, q_pos=q_pos, kv_pos=kv_pos,
                  window=cfg.sliding_window, chunk=0)
    B = x.shape[0]
    x = x + (o.reshape(B, 1, -1) @ lp["wo"]).astype(x.dtype)
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    y, _ = _ffn(h2, lp, cfg, dropless=True)
    return x + y.astype(x.dtype), (new_k, new_v)


def _layer_verify(x, lp, layer_cache, cfg: ArchConfig, q_pos):
    """Speculative-verify layer: a width-K+1 causal pass over the live
    cache. x: (B, K1, D); ``q_pos``: (B, K1) per-row absolute positions
    of the window tokens. The whole window's KV lands in the cache
    *before* attention and the per-row position mask (kv_pos <= q_pos_i)
    restricts each query to exactly the key set the chained decode
    would have seen — this is what makes verification one dispatch of
    ~one decode-step's wall cost instead of K+1 sequential steps."""
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k1, v1 = _qkv(h, lp, cfg, q_pos)
    new_k, new_v, kv_pos = layer_cache["update"](k1, v1)
    o = attention(q, new_k, new_v, q_pos=q_pos, kv_pos=kv_pos,
                  window=cfg.sliding_window, chunk=0)
    B, S = x.shape[:2]
    x = x + (o.reshape(B, S, -1) @ lp["wo"]).astype(x.dtype)
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    y, _ = _ffn(h2, lp, cfg, dropless=True)
    return x + y.astype(x.dtype), (new_k, new_v)


@register_family("dense")
@register_family("moe")
@register_family("vlm")
class DecoderLM(BaseModel):
    """Dense / MoE / VLM-backbone decoder-only LM."""

    def init(self, rng):
        cfg = self.cfg
        dtype = dt(cfg.param_dtype)
        kg = KeyGen(rng)
        keys = jax.random.split(kg(), cfg.n_layers)
        layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(keys)
        params = {
            "embed": embed_init(kg(), (cfg.padded_vocab, cfg.d_model), dtype),
            "layers": layers,
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(
                kg(), (cfg.d_model, cfg.padded_vocab), dtype)
        return params

    # ------------------------------------------------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(dt(cfg.compute_dtype))
        if cfg.n_stub_embeds and "stub_embeds" in batch:
            stub = batch["stub_embeds"].astype(x.dtype)
            x = jnp.concatenate([stub, x], axis=1)
        return shard_act(x, (BATCH, "model" if cfg.seq_parallel else None,
                             None))

    def _unembed(self, params, x):
        w = (params["embed"].T if self.cfg.tie_embeddings
             else params["unembed"])
        return x @ w.astype(x.dtype)

    def _run_layers(self, params, x, positions):
        cfg = self.cfg

        def body(carry, lp):
            x, aux = carry
            x, kv, a = _layer_full(x, lp, cfg, positions)
            return (x, aux + a), kv

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), kvs = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                     params["layers"])
        return x, aux, kvs

    # ------------------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        x = self._embed(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)
        x, aux, _ = self._run_layers(params, x, positions)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        if cfg.n_stub_embeds:  # loss only on text positions
            x = x[:, cfg.n_stub_embeds:]
        logits = self._unembed(params, x)
        ce = softmax_xent(logits, batch["labels"])
        total = ce + 0.01 * aux / max(cfg.n_layers, 1)
        return total, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------
    def init_cache(self, batch_size, capacity):
        cfg = self.cfg
        c = init_kv_cache(batch_size, capacity, cfg.n_kv_heads, cfg.dh,
                          dt(cfg.compute_dtype))
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L,) + c["k"].shape, c["k"].dtype),
            "v": jnp.zeros((L,) + c["v"].shape, c["v"].dtype),
            "pos": c["pos"],
            "t": c["t"],
        }

    def prefill(self, params, batch, capacity=None):
        cfg = self.cfg
        x = self._embed(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)
        x, _, kvs = self._run_layers(params, x, positions)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = self._unembed(params, x[:, -1])
        # build cache from stacked per-layer (k, v)
        ks, vs = kvs
        C = capacity or self.cache_capacity(S)
        base = init_kv_cache(x.shape[0], C, cfg.n_kv_heads, cfg.dh,
                             dt(cfg.compute_dtype))
        filled = jax.vmap(lambda k, v: cache_prefill(base, k, v))(ks, vs)
        cache = {"k": filled["k"], "v": filled["v"],
                 "pos": filled["pos"][0], "t": filled["t"][0]}
        return logits, cache

    def decode(self, params, cache, batch):
        cfg = self.cfg
        x = self._embed(params, {"tokens": batch["token"]})
        t = cache["t"]
        C = cache["k"].shape[2]
        slot = t % C

        def body(x, inp):
            lp, ck, cv = inp

            def update(k1, v1):
                nk = jax.lax.dynamic_update_slice(
                    ck, k1.astype(ck.dtype), (0, slot, 0, 0))
                nv = jax.lax.dynamic_update_slice(
                    cv, v1.astype(cv.dtype), (0, slot, 0, 0))
                kv_pos = jax.lax.dynamic_update_slice(
                    cache["pos"], t[None], (slot,))
                return nk, nv, kv_pos

            x, (nk, nv) = _layer_decode(
                x, lp, {"update": update}, cfg, t)
            return x, (nk, nv)

        x, (nks, nvs) = jax.lax.scan(body, x,
                                     (params["layers"], cache["k"], cache["v"]))
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = self._unembed(params, x[:, 0])
        new_cache = {
            "k": nks, "v": nvs,
            "pos": jax.lax.dynamic_update_slice(cache["pos"], t[None], (slot,)),
            "t": t + 1,
        }
        return logits, new_cache

    # ------------------------------------------------------------------
    # Speculative verify: the whole K+1 token window scored in ONE
    # parallel causal pass — this is the mechanism that makes
    # speculation pay: K+1 positions cost roughly one decode step of
    # wall time (width-K1 matmuls against the same weights) instead of
    # K+1 sequential steps. Exactness: all K+1 keys/values land in the
    # cache ring ROPE'd at their absolute positions before attention,
    # and the per-row position mask (kv_pos <= q_pos_i, kv_pos >= 0)
    # gives query i exactly the key set a chained one-by-one decode
    # would have seen; masked slots contribute *exactly* zero (score
    # NEG_INF -> softmax weight 0.0 in f32, and 0 * finite garbage = 0
    # — the written KV values are finite projections of valid/clamped
    # token embeddings, never inf/NaN). Bitwise token identity against
    # the chained decode ladder is asserted by the differential suite
    # (tests/test_speculative.py) on the CPU platform CI pins.
    # ------------------------------------------------------------------
    @property
    def supports_verify(self):
        return True

    def verify(self, params, cache, pos, t, batch):
        """Verify a K+1 token window per row against the target model.

        cache: {"k", "v"} (L, B, C, KV, dh) ring buffers; pos: (B, C)
        per-row absolute slot positions (-1 empty); t: (B,) per-row next
        write position; batch: {"tokens": (B, K+1)} — the last sampled
        token followed by K draft proposals. Returns (greedy (B, K+1)
        int32, {"k", "v"}') where greedy[:, i] is the argmax
        continuation after feeding window token i. All K+1 slots
        t .. t+K are written optimistically (the caller must guarantee
        they carry pos == -1 on entry — the engine's no-wrap gate — and
        rolls back pos over the rejected suffix)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, K1 = tokens.shape
        C = cache["k"].shape[2]
        rows = jnp.arange(B)[:, None]                        # (B, 1)
        offs = t[:, None] + jnp.arange(K1)[None, :]          # (B, K1)
        slots = offs % C
        new_pos = pos.at[rows, slots].set(offs)
        x = self._embed(params, {"tokens": tokens})          # (B, K1, D)

        def body(x, inp):
            lp, ck, cv = inp

            def update(k1, v1):
                nk = ck.at[rows, slots].set(k1.astype(ck.dtype))
                nv = cv.at[rows, slots].set(v1.astype(cv.dtype))
                return nk, nv, new_pos

            x, (nk, nv) = _layer_verify(
                x, lp, {"update": update}, cfg, offs)
            return x, (nk, nv)

        x, (nks, nvs) = jax.lax.scan(body, x, (params["layers"],
                                               cache["k"], cache["v"]))
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = self._unembed(params, x)                    # (B, K1, V)
        gs = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return gs, {"k": nks, "v": nvs}

    def paged_verify(self, params, pool, table, pos, t, batch, *, page):
        """Paged-layout verify: gather each row's dense view through its
        page table, run the ring ``verify`` on it, scatter the K+1
        optimistically written slots back through ``paged_append_rows``
        at per-row offsets. Same identity-by-construction argument as
        ``paged_decode``. pos: (B, C), t: (B,); returns (greedy, pool')."""
        tokens = batch["tokens"]
        K1 = tokens.shape[1]
        nlp = table.shape[1]
        C = nlp * page
        gk, gv = jax.vmap(paged_gather, in_axes=(1, 1, None),
                          out_axes=0)(pool["k"], pool["v"], table)
        greedy, nc = self.verify(params, {"k": gk, "v": gv}, pos, t,
                                 batch)
        slots = (t[:, None] + jnp.arange(K1)[None, :]) % C     # (B, K1)
        tbl_cols = jnp.take_along_axis(table, slots // page, axis=1)
        offs = slots % page
        idx = slots[:, :, None, None]

        def per_layer(kp, vp, kl, vl):
            kw = jnp.take_along_axis(kl, idx, axis=1)          # (B, K1, ...)
            vw = jnp.take_along_axis(vl, idx, axis=1)
            return paged_append_rows(kp, vp, tbl_cols, offs, kw, vw)

        nk, nv = jax.vmap(per_layer, in_axes=(1, 1, 0, 0),
                          out_axes=(1, 1))(pool["k"], pool["v"],
                                           nc["k"], nc["v"])
        return greedy, {"k": nk, "v": nv}

    # ------------------------------------------------------------------
    # Paged KV cache protocol. The forward math is *shared with the ring
    # path by construction*: paged_prefill runs the ordinary prefill and
    # only then scatters the dense cache into pool pages; paged_decode
    # gathers each row's pages into the dense view the ordinary decode
    # expects and scatters back the one slot it wrote. Logits therefore
    # go through the identical op sequence in both layouts — the
    # token-identity the serving equivalence tests assert is a property
    # of the construction, not a numerical accident.
    # ------------------------------------------------------------------
    @property
    def supports_paged_kv(self):
        # stub-embed (VLM) prefills prepend non-token positions, so the
        # prompt page <-> token page correspondence breaks
        return not self.cfg.n_stub_embeds

    def init_paged_pool(self, n_pages, page):
        # layer-stack on axis 1: (P1, L, page, KV, dh) keeps the page
        # index leading so one gather per table entry covers all layers
        cfg = self.cfg
        shape = (n_pages + 1, cfg.n_layers, page, cfg.n_kv_heads, cfg.dh)
        cdt = dt(cfg.compute_dtype)
        return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt)}

    def paged_prefill(self, params, batch, pool, scatter_tbl, *, page,
                      capacity):
        """Ordinary prefill + page scatter. scatter_tbl: (B, S // page)
        physical destination pages (trash for rows whose compute is
        discarded). Returns (logits, pool', pos, t)."""
        logits, cache = self.prefill(params, batch, capacity=capacity)
        S = batch["tokens"].shape[1]
        k, v = cache["k"][:, :, :S], cache["v"][:, :, :S]

        def per_layer(kp, vp, kl, vl):
            return paged_scatter_pages(kp, vp, scatter_tbl, kl, vl)

        nk, nv = jax.vmap(per_layer, in_axes=(1, 1, 0, 0),
                          out_axes=(1, 1))(pool["k"], pool["v"], k, v)
        return logits, {"k": nk, "v": nv}, cache["pos"], cache["t"]

    def paged_prefill_suffix(self, params, batch, pool, prefix_tbl,
                             scatter_tbl, *, offset, page):
        """Compute-shared suffix prefill: attend over cached prefix KV
        (gathered through ``prefix_tbl``, (B, offset // page)) and compute
        only the suffix tokens at absolute positions offset..offset+Ssuf-1.
        Suffix KV is scattered into pool pages via ``scatter_tbl``
        (B, Ssuf // page). Returns (logits, pool') where logits are the
        last suffix position's — causal masking makes them identical to a
        monolithic prefill of the full offset+Ssuf prompt."""
        cfg = self.cfg
        x = self._embed(params, batch)
        Ssuf = x.shape[1]
        positions = jnp.arange(offset, offset + Ssuf)
        # gather the prefix view once per layer: (L, B, offset, KV, dh)
        gk, gv = jax.vmap(paged_gather, in_axes=(1, 1, None),
                          out_axes=0)(pool["k"], pool["v"], prefix_tbl)

        def body(x, inp):
            lp, pk, pv = inp
            x, kv = _layer_suffix(x, lp, cfg, positions, pk, pv, offset)
            return x, kv

        if cfg.remat:
            body = jax.checkpoint(body)
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], gk, gv))
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = self._unembed(params, x[:, -1])

        def per_layer(kp, vp, kl, vl):
            return paged_scatter_pages(kp, vp, scatter_tbl, kl, vl)

        nk, nv = jax.vmap(per_layer, in_axes=(1, 1, 0, 0),
                          out_axes=(1, 1))(pool["k"], pool["v"], ks, vs)
        return logits, {"k": nk, "v": nv}

    def paged_decode(self, params, pool, table, pos, t, batch, *, page):
        """Gather the dense per-row view through the page table, run the
        ordinary decode on it, scatter the newly written slot back.
        Returns (logits, pool', pos', t')."""
        nlp = table.shape[1]
        C = nlp * page
        gk, gv = jax.vmap(paged_gather, in_axes=(1, 1, None),
                          out_axes=0)(pool["k"], pool["v"], table)
        logits, nc = self.decode(
            params, {"k": gk, "v": gv, "pos": pos, "t": t}, batch)
        slot = t % C
        tbl_col = jnp.take(table, slot // page, axis=1)
        off = slot % page
        k1 = jax.lax.dynamic_slice_in_dim(nc["k"], slot, 1, axis=2)
        v1 = jax.lax.dynamic_slice_in_dim(nc["v"], slot, 1, axis=2)

        def per_layer(kp, vp, kl, vl):
            return paged_append(kp, vp, tbl_col, off, kl, vl)

        nk, nv = jax.vmap(per_layer, in_axes=(1, 1, 0, 0),
                          out_axes=(1, 1))(pool["k"], pool["v"], k1, v1)
        return logits, {"k": nk, "v": nv}, nc["pos"], nc["t"]

    # ------------------------------------------------------------------
    def input_shapes(self, sc):
        cfg = self.cfg
        if not cfg.n_stub_embeds:
            return super().input_shapes(sc)
        B, S = sc.global_batch, sc.seq_len
        f = jax.ShapeDtypeStruct
        i32, cdt = jnp.int32, dt(cfg.compute_dtype)
        stub = f((B, cfg.n_stub_embeds, cfg.d_model), cdt)
        n_txt = S - cfg.n_stub_embeds
        if sc.mode == "train":
            return {"tokens": f((B, n_txt), i32), "labels": f((B, n_txt), i32),
                    "stub_embeds": stub}
        if sc.mode == "prefill":
            return {"tokens": f((B, n_txt), i32), "stub_embeds": stub}
        return {"token": f((B, 1), i32)}
