"""Zamba2-style hybrid: a Mamba2 backbone with a *shared* full-attention
transformer block (attention + MLP, one set of weights) applied after every
``cfg.attn_every``-th Mamba2 layer (arXiv:2411.15242).

Deviation noted in DESIGN.md: Zamba2's per-invocation LoRA adapters and
initial-embedding concat are omitted; the shared block is applied to the
running residual stream with plain weight reuse.

Cache layout (decode): per-layer SSM state + conv ring buffers, plus a
stacked KV cache with one slot-group per shared-attention application
(``A = n_layers // attn_every``). Each application keeps its own K/V
because activations differ even though weights are shared.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .api import BaseModel, register_family
from .common import (ArchConfig, KeyGen, dense_init, dt, embed_init, rmsnorm,
                     softmax_xent)
from .dense import _init_layer as init_attn_layer
from .dense import _layer_full as attn_layer_full
from .dense import _qkv
from .attention import attention, cache_prefill, init_kv_cache
from .mamba2 import init_mamba_layer, mamba_seq, mamba_step
from ..sharding import shard_act

BATCH = ("pod", "data")


@register_family("hybrid")
class Zamba2(BaseModel):
    def _attn_layer_ids(self) -> np.ndarray:
        cfg = self.cfg
        if not cfg.attn_every:
            return np.zeros((0,), np.int32)
        ids = np.arange(cfg.attn_every - 1, cfg.n_layers, cfg.attn_every)
        return ids.astype(np.int32)

    @property
    def n_attn_apps(self) -> int:
        return len(self._attn_layer_ids())

    def init(self, rng):
        cfg = self.cfg
        dtype = dt(cfg.param_dtype)
        kg = KeyGen(rng)
        keys = jax.random.split(kg(), cfg.n_layers)
        layers = jax.vmap(lambda k: init_mamba_layer(k, cfg, dtype))(keys)
        params = {
            "embed": embed_init(kg(), (cfg.padded_vocab, cfg.d_model), dtype),
            "layers": layers,
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
            "unembed": dense_init(kg(), (cfg.d_model, cfg.padded_vocab), dtype),
        }
        if self.n_attn_apps:
            params["shared"] = init_attn_layer(kg(), cfg, dtype)
        return params

    def _flags(self):
        f = np.zeros((self.cfg.n_layers,), bool)
        f[self._attn_layer_ids()] = True
        return jnp.asarray(f)

    # ------------------------------------------------------------------
    def _run_full(self, params, x, positions, collect: bool = False):
        """Train/prefill pass via scan-over-layers.

        With ``collect`` the scan also emits per-layer (k, v, ssm_state,
        conv tails) for cache construction (zeros at non-attn layers for
        k/v; the attn rows are selected by static layer ids afterwards).
        """
        cfg = self.cfg
        shared = params.get("shared")
        W = cfg.ssm_conv_width

        def body(x, inp):
            lp, flag = inp
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            o, s_fin = mamba_seq(lp, h, cfg)
            if collect:
                cx = (h @ lp["w_in_x"])[:, -(W - 1):]
                cB = (h @ lp["w_B"])[:, -(W - 1):]
                cC = (h @ lp["w_C"])[:, -(W - 1):]
            x = x + o

            if shared is not None:
                def with_attn(x):
                    y, kv, _aux = attn_layer_full(x, shared, cfg, positions)
                    return (y,) + kv

                def without(x):
                    B, S = x.shape[:2]
                    z = jnp.zeros((B, S, cfg.n_kv_heads, cfg.dh),
                                  dt(cfg.compute_dtype))
                    return x, z, z

                x, k, v = jax.lax.cond(flag, with_attn, without, x)
            else:
                k = v = jnp.zeros((), dt(cfg.compute_dtype))
            x = shard_act(x, (BATCH, None, None))
            ys = (k, v, s_fin, cx, cB, cC) if collect else None
            return x, ys

        if cfg.remat:
            body = jax.checkpoint(body)
        x, ys = jax.lax.scan(body, x, (params["layers"], self._flags()))
        return x, ys

    def loss(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(dt(cfg.compute_dtype))
        x = shard_act(x, (BATCH, None, None))
        positions = jnp.arange(x.shape[1])
        x, _ = self._run_full(params, x, positions)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = x @ params["unembed"].astype(x.dtype)
        ce = softmax_xent(logits, batch["labels"])
        return ce, {"ce": ce}

    # ------------------------------------------------------------------
    def init_cache(self, batch_size, capacity):
        cfg = self.cfg
        L, H, N, P = (cfg.n_layers, cfg.ssm_heads, cfg.ssm_state,
                      cfg.ssm_head_dim)
        W = cfg.ssm_conv_width
        cdt = dt(cfg.compute_dtype)
        cache = {
            "ssm": jnp.zeros((L, batch_size, H, N, P), cdt),
            "conv_x": jnp.zeros((L, batch_size, W - 1, cfg.d_inner), cdt),
            "conv_B": jnp.zeros((L, batch_size, W - 1, N), cdt),
            "conv_C": jnp.zeros((L, batch_size, W - 1, N), cdt),
            "t": jnp.zeros((), jnp.int32),
        }
        A = self.n_attn_apps
        if A:
            cache["attn_k"] = jnp.zeros(
                (A, batch_size, capacity, cfg.n_kv_heads, cfg.dh), cdt)
            cache["attn_v"] = jnp.zeros_like(cache["attn_k"])
            cache["attn_pos"] = jnp.full((capacity,), -1, jnp.int32)
        return cache

    def prefill(self, params, batch, capacity=None):
        cfg = self.cfg
        B, S = batch["tokens"].shape
        x = params["embed"][batch["tokens"]].astype(dt(cfg.compute_dtype))
        positions = jnp.arange(S)
        x, ys = self._run_full(params, x, positions, collect=True)
        ks, vs, ssm, cx, cB, cC = ys
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = x[:, -1] @ params["unembed"].astype(x.dtype)

        cache = self.init_cache(B, capacity or self.cache_capacity(S))
        cdt = dt(cfg.compute_dtype)
        cache.update({"ssm": ssm.astype(cdt), "conv_x": cx.astype(cdt),
                      "conv_B": cB.astype(cdt), "conv_C": cC.astype(cdt),
                      "t": jnp.asarray(S, jnp.int32)})
        ids = self._attn_layer_ids()
        if len(ids):
            C = cache["attn_k"].shape[2]
            base = init_kv_cache(B, C, cfg.n_kv_heads, cfg.dh, cdt)
            filled = jax.vmap(lambda k, v: cache_prefill(base, k, v))(
                ks[ids], vs[ids])
            cache["attn_k"] = filled["k"]
            cache["attn_v"] = filled["v"]
            cache["attn_pos"] = filled["pos"][0]
        return logits, cache

    def decode(self, params, cache, batch):
        cfg = self.cfg
        x = params["embed"][batch["token"]].astype(dt(cfg.compute_dtype))
        t = cache["t"]
        shared = params.get("shared")
        A = self.n_attn_apps
        flags = self._flags()
        ids = self._attn_layer_ids()
        app_of_layer = np.zeros((cfg.n_layers,), np.int32)
        app_of_layer[ids] = np.arange(len(ids))
        app_idx = jnp.asarray(app_of_layer)
        C = cache["attn_k"].shape[2] if A else 1
        slot = t % C if A else jnp.zeros((), jnp.int32)
        new_pos = (jax.lax.dynamic_update_slice(cache["attn_pos"], t[None],
                                                (slot,)) if A else None)

        def body(carry, inp):
            x, ak, av = carry
            lp, flag, aidx, ssm, cx, cB, cC = inp
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            o, new_ssm, new_conv = mamba_step(
                lp, h, ssm, {"x": cx, "B": cB, "C": cC}, cfg)
            x = x + o

            def with_attn(args):
                x, ak, av = args
                h2 = rmsnorm(x, shared["ln1"], cfg.norm_eps)
                q, k1, v1 = _qkv(h2, shared, cfg, t[None])
                ck = jax.lax.dynamic_index_in_dim(ak, aidx, 0, keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(av, aidx, 0, keepdims=False)
                nk = jax.lax.dynamic_update_slice(
                    ck, k1.astype(ck.dtype), (0, slot, 0, 0))
                nv = jax.lax.dynamic_update_slice(
                    cv, v1.astype(cv.dtype), (0, slot, 0, 0))
                o2 = attention(q, nk, nv, q_pos=t[None], kv_pos=new_pos,
                               window=cfg.sliding_window)
                Bsz = x.shape[0]
                x = x + (o2.reshape(Bsz, 1, -1) @ shared["wo"]).astype(x.dtype)
                h3 = rmsnorm(x, shared["ln2"], cfg.norm_eps)
                mp = shared["mlp"]
                y = (jax.nn.silu(h3 @ mp["w_gate"]) * (h3 @ mp["w_up"])) \
                    @ mp["w_down"]
                x = x + y.astype(x.dtype)
                ak = jax.lax.dynamic_update_index_in_dim(ak, nk, aidx, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, nv, aidx, 0)
                return x, ak, av

            if A:
                x, ak, av = jax.lax.cond(flag, with_attn,
                                         lambda a: a, (x, ak, av))
            return (x, ak, av), (new_ssm, new_conv["x"], new_conv["B"],
                                 new_conv["C"])

        ak0 = cache.get("attn_k", jnp.zeros((1,), dt(cfg.compute_dtype)))
        av0 = cache.get("attn_v", ak0)
        (x, ak, av), (ssm, cx, cB, cC) = jax.lax.scan(
            body, (x, ak0, av0),
            (params["layers"], flags, app_idx, cache["ssm"],
             cache["conv_x"], cache["conv_B"], cache["conv_C"]))
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = x[:, 0] @ params["unembed"].astype(x.dtype)
        new_cache = dict(cache)
        new_cache.update({"ssm": ssm, "conv_x": cx, "conv_B": cB,
                          "conv_C": cC, "t": t + 1})
        if A:
            new_cache.update({"attn_k": ak, "attn_v": av,
                              "attn_pos": new_pos})
        return logits, new_cache
