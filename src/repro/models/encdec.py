"""Encoder-decoder transformer backbone (Seamless-M4T-v2 style, audio).

The modality frontend (mel-spectrogram + conv feature extractor) is a stub
per the assignment: ``input_shapes`` supplies precomputed frame embeddings
(B, enc_seq_len, d_model). The encoder is a bidirectional transformer; the
decoder is causal with cross-attention. Cross-attention K/V are computed
once at prefill and cached (enc length is fixed), so decode cost is
self-attn KV + cross-attn reads.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .api import BaseModel, register_family
from .attention import attention, cache_prefill, init_kv_cache
from .common import (ArchConfig, KeyGen, apply_rope, dense_init, dt,
                     embed_init, rmsnorm, softmax_xent)
from .dense import _ffn, _qkv
from ..sharding import shard_act

BATCH = ("pod", "data")


def _init_attn(kg, cfg, dtype, cross: bool = False):
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    return {
        "wq": dense_init(kg(), (D, H * dh), dtype),
        "wk": dense_init(kg(), (D, KV * dh), dtype),
        "wv": dense_init(kg(), (D, KV * dh), dtype),
        "wo": dense_init(kg(), (H * dh, D), dtype),
    }


def _init_enc_layer(key, cfg, dtype):
    kg = KeyGen(key)
    D, F = cfg.d_model, cfg.d_ff
    return {
        "ln1": jnp.ones((D,), jnp.float32),
        "ln2": jnp.ones((D,), jnp.float32),
        "attn": _init_attn(kg, cfg, dtype),
        "mlp": {
            "w_gate": dense_init(kg(), (D, F), dtype),
            "w_up": dense_init(kg(), (D, F), dtype),
            "w_down": dense_init(kg(), (F, D), dtype),
        },
    }


def _init_dec_layer(key, cfg, dtype):
    kg = KeyGen(key)
    p = _init_enc_layer(key, cfg, dtype)
    p["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
    p["xattn"] = _init_attn(kg, cfg, dtype, cross=True)
    return p


def _mha(ap, xq, xkv, cfg, *, q_pos, kv_pos, causal, rope_q=True,
         rope_k=True, chunk=0):
    B, Sq, D = xq.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = (xq @ ap["wq"]).reshape(B, Sq, H, dh)
    k = (xkv @ ap["wk"]).reshape(B, xkv.shape[1], KV, dh)
    v = (xkv @ ap["wv"]).reshape(B, xkv.shape[1], KV, dh)
    if rope_q:
        q = apply_rope(q, q_pos, cfg.rope_theta)
    if rope_k:
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    q = shard_act(q, (BATCH, None, "model", None))
    o = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
                  chunk=chunk)
    return (o.reshape(B, Sq, H * dh) @ ap["wo"]).astype(xq.dtype), k, v


def _enc_layer(x, lp, cfg, positions):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    o, _, _ = _mha(lp["attn"], h, h, cfg, q_pos=positions, kv_pos=positions,
                   causal=False, chunk=cfg.attn_chunk)
    x = x + o
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    y, _ = _ffn(h2, lp, cfg)
    return x + y.astype(x.dtype)


def _dec_layer_full(x, enc_out, lp, cfg, positions, enc_positions):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    o, k, v = _mha(lp["attn"], h, h, cfg, q_pos=positions, kv_pos=positions,
                   causal=True, chunk=cfg.attn_chunk)
    x = x + o
    hx = rmsnorm(x, lp["ln_x"], cfg.norm_eps)
    ox, xk, xv = _mha(lp["xattn"], hx, enc_out, cfg, q_pos=positions,
                      kv_pos=enc_positions, causal=False, rope_q=False,
                      rope_k=False, chunk=cfg.attn_chunk)
    x = x + ox
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    y, _ = _ffn(h2, lp, cfg)
    return x + y.astype(x.dtype), (k, v, xk, xv)


@register_family("encdec")
class EncDecLM(BaseModel):
    def init(self, rng):
        cfg = self.cfg
        dtype = dt(cfg.param_dtype)
        kg = KeyGen(rng)
        ek = jax.random.split(kg(), cfg.n_enc_layers)
        dk = jax.random.split(kg(), cfg.n_dec_layers)
        return {
            "embed": embed_init(kg(), (cfg.padded_vocab, cfg.d_model), dtype),
            "enc_layers": jax.vmap(
                lambda k: _init_enc_layer(k, cfg, dtype))(ek),
            "dec_layers": jax.vmap(
                lambda k: _init_dec_layer(k, cfg, dtype))(dk),
            "ln_enc": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
            "unembed": dense_init(kg(), (cfg.d_model, cfg.padded_vocab), dtype),
        }

    # ------------------------------------------------------------------
    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(dt(cfg.compute_dtype))
        x = shard_act(x, (BATCH, None, None))
        positions = jnp.arange(x.shape[1])

        def body(x, lp):
            return _enc_layer(x, lp, cfg, positions), None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return rmsnorm(x, params["ln_enc"], cfg.norm_eps)

    def _decode_full(self, params, enc_out, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(dt(cfg.compute_dtype))
        x = shard_act(x, (BATCH, None, None))
        positions = jnp.arange(x.shape[1])
        enc_positions = jnp.arange(enc_out.shape[1])

        def body(x, lp):
            x, kvs = _dec_layer_full(x, enc_out, lp, cfg, positions,
                                     enc_positions)
            return x, kvs

        if cfg.remat:
            body = jax.checkpoint(body)
        x, kvs = jax.lax.scan(body, x, params["dec_layers"])
        return rmsnorm(x, params["ln_f"], cfg.norm_eps), kvs

    def loss(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        x, _ = self._decode_full(params, enc_out, batch["tokens"])
        logits = x @ params["unembed"].astype(x.dtype)
        ce = softmax_xent(logits, batch["labels"])
        return ce, {"ce": ce}

    # ------------------------------------------------------------------
    def init_cache(self, batch_size, capacity):
        cfg = self.cfg
        L = cfg.n_dec_layers
        cdt = dt(cfg.compute_dtype)
        Se = cfg.enc_seq_len
        KV, dh = cfg.n_kv_heads, cfg.dh
        return {
            "k": jnp.zeros((L, batch_size, capacity, KV, dh), cdt),
            "v": jnp.zeros((L, batch_size, capacity, KV, dh), cdt),
            "xk": jnp.zeros((L, batch_size, Se, KV, dh), cdt),
            "xv": jnp.zeros((L, batch_size, Se, KV, dh), cdt),
            "pos": jnp.full((capacity,), -1, jnp.int32),
            "t": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, batch, capacity=None):
        cfg = self.cfg
        B, S = batch["tokens"].shape
        enc_out = self.encode(params, batch["frames"])
        x, kvs = self._decode_full(params, enc_out, batch["tokens"])
        logits = x[:, -1] @ params["unembed"].astype(x.dtype)
        ks, vs, xks, xvs = kvs
        C = capacity or self.cache_capacity(S)
        base = init_kv_cache(B, C, cfg.n_kv_heads, cfg.dh,
                             dt(cfg.compute_dtype))
        filled = jax.vmap(lambda k, v: cache_prefill(base, k, v))(ks, vs)
        cdt = dt(cfg.compute_dtype)
        cache = {"k": filled["k"], "v": filled["v"],
                 "xk": xks.astype(cdt), "xv": xvs.astype(cdt),
                 "pos": filled["pos"][0], "t": filled["t"][0]}
        return logits, cache

    def decode(self, params, cache, batch):
        cfg = self.cfg
        x = params["embed"][batch["token"]].astype(dt(cfg.compute_dtype))
        t = cache["t"]
        C = cache["k"].shape[2]
        slot = t % C
        new_pos = jax.lax.dynamic_update_slice(cache["pos"], t[None], (slot,))
        enc_positions = jnp.arange(cfg.enc_seq_len)

        def body(x, inp):
            lp, ck, cv, xk, xv = inp
            B = x.shape[0]
            H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            ap = lp["attn"]
            q = apply_rope((h @ ap["wq"]).reshape(B, 1, H, dh), t[None],
                           cfg.rope_theta)
            k1 = apply_rope((h @ ap["wk"]).reshape(B, 1, KV, dh), t[None],
                            cfg.rope_theta)
            v1 = (h @ ap["wv"]).reshape(B, 1, KV, dh)
            nk = jax.lax.dynamic_update_slice(ck, k1.astype(ck.dtype),
                                              (0, slot, 0, 0))
            nv = jax.lax.dynamic_update_slice(cv, v1.astype(cv.dtype),
                                              (0, slot, 0, 0))
            o = attention(q, nk, nv, q_pos=t[None], kv_pos=new_pos)
            x = x + (o.reshape(B, 1, H * dh) @ ap["wo"]).astype(x.dtype)
            hx = rmsnorm(x, lp["ln_x"], cfg.norm_eps)
            xp = lp["xattn"]
            qx = (hx @ xp["wq"]).reshape(B, 1, H, dh)
            ox = attention(qx, xk, xv, q_pos=t[None], kv_pos=enc_positions,
                           causal=False)
            x = x + (ox.reshape(B, 1, H * dh) @ xp["wo"]).astype(x.dtype)
            h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
            y, _ = _ffn(h2, lp, cfg)
            return x + y.astype(x.dtype), (nk, nv)

        x, (nks, nvs) = jax.lax.scan(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = x[:, 0] @ params["unembed"].astype(x.dtype)
        new_cache = dict(cache)
        new_cache.update({"k": nks, "v": nvs, "pos": new_pos, "t": t + 1})
        return logits, new_cache

    # ------------------------------------------------------------------
    def input_shapes(self, sc):
        cfg = self.cfg
        B, S = sc.global_batch, sc.seq_len
        f = jax.ShapeDtypeStruct
        i32, cdt = jnp.int32, dt(cfg.compute_dtype)
        frames = f((B, cfg.enc_seq_len, cfg.d_model), cdt)
        if sc.mode == "train":
            return {"frames": frames, "tokens": f((B, S), i32),
                    "labels": f((B, S), i32)}
        if sc.mode == "prefill":
            return {"frames": frames, "tokens": f((B, S), i32)}
        return {"token": f((B, 1), i32)}
