"""Mixture-of-Experts FFN.

Two implementations, selected by ``cfg.moe_impl``:

* ``dispatch`` (production, GShard/Switch-style): top-k routing, capacity-
  bounded scatter into an (E, capacity, D) buffer, batched per-expert
  GEMMs on the MXU, weighted combine. Static shapes throughout — the TPU
  adaptation of ragged grouped-GEMM dispatch. With experts sharded over the
  ``model`` mesh axis this is expert parallelism (GSPMD inserts the
  all-to-all at the scatter/gather); with d_ff sharded it is tensor
  parallelism within every expert.
* ``dense``: computes every expert for every token and masks — exact same
  math, O(E/k) more FLOPs. Used as the correctness oracle and for smoke
  configs; also the "naive baseline" in the §Perf MoE hillclimb.

Both return (output, aux_loss) where aux_loss is the Switch load-balance
loss E * sum_e f_e * p_e.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ArchConfig, KeyGen, dense_init, zeros_init
from ..sharding import axis_size, shard_act


def init_moe(key, cfg: ArchConfig, dtype):
    kg = KeyGen(key)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(kg(), (D, E), jnp.float32),  # router in f32
        "w_gate": dense_init(kg(), (E, D, F), dtype, in_axis=-2),
        "w_up": dense_init(kg(), (E, D, F), dtype, in_axis=-2),
        "w_down": dense_init(kg(), (E, F, D), dtype, in_axis=-2),
    }


def _route(params, x2d, cfg: ArchConfig):
    """x2d: (T, D) -> (weights (T,k), ids (T,k), probs (T,E))."""
    logits = x2d.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, ids, probs


def _aux_loss(probs, ids, E):
    """Switch load-balance loss: E * sum_e (fraction routed) * (mean prob)."""
    T = probs.shape[0]
    counts = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(jnp.float32(ids.size), 1.0)
    p = jnp.mean(probs, axis=0)
    return E * jnp.sum(f * p)


def _expert_ffn(w_gate, w_up, w_down, xb):
    """Batched per-expert SwiGLU: xb (E, C, D) -> (E, C, D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xb, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_ffn(params, x, cfg: ArchConfig, dropless: bool = False):
    """x: (B, S, D) -> (y, aux_loss).

    ``dropless=True`` (decode path) sets capacity = T so no token is ever
    dropped — exactness matters for serving and T is small there.
    """
    B, S, D = x.shape
    T = B * S
    x2d = x.reshape(T, D)
    w, ids, probs = _route(params, x2d, cfg)
    aux = _aux_loss(probs, ids, cfg.n_experts)
    if cfg.moe_impl == "dense":
        y = _moe_dense(params, x2d, w, ids, cfg)
    else:
        y = _moe_dispatch(params, x2d, w, ids, cfg, dropless)
    return y.reshape(B, S, D).astype(x.dtype), aux


def _moe_dense(params, x2d, w, ids, cfg: ArchConfig):
    """Every expert on every token, masked combine. Oracle / smoke path."""
    E = cfg.n_experts
    xb = jnp.broadcast_to(x2d[None], (E,) + x2d.shape)  # (E, T, D)
    ye = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], xb)
    # weight for (token, expert) = sum over k slots where ids==e
    wte = jnp.zeros((x2d.shape[0], E), jnp.float32)
    wte = wte.at[jnp.arange(x2d.shape[0])[:, None], ids].add(w)
    return jnp.einsum("etd,te->td", ye.astype(jnp.float32), wte)


def _moe_dispatch(params, x2d, w, ids, cfg: ArchConfig,
                  dropless: bool = False):
    """Hierarchical (grouped) capacity dispatch — GShard-style.

    Tokens are split into G groups aligned with the (pod, data) mesh axes;
    positions/capacity are computed *within* each group, so the scatter into
    the (G, E, cap_g, D) buffer is group-local. With a global cumsum the
    SPMD partitioner has to all-reduce the whole buffer across the data
    axis (measured 1.9 TB/step on mixtral train_4k); grouped, the only
    cross-device traffic left is the expert GEMM's own parallelism
    (all-to-all over `model` when experts are expert-parallel, or the
    standard activation all-reduce when they are tensor-parallel).
    """
    T, D = x2d.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    G = max(1, axis_size("pod") * axis_size("data"))
    if T % G:
        G = 1
    Tg = T // G
    cap = Tg if dropless else max(1, int(cfg.moe_capacity_factor * Tg * k / E))

    xg = shard_act(x2d.reshape(G, Tg, D), (("pod", "data"), None, None))
    idsg = ids.reshape(G, Tg, k)
    wg = w.reshape(G, Tg, k)

    def one_group(xs, ids1, w1):
        flat_e = ids1.reshape(-1)  # (Tg*k,)
        flat_w = w1.reshape(-1)
        tok_of = jnp.repeat(jnp.arange(Tg), k)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - oh  # exclusive, group-local
        mypos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = mypos < cap
        dest = jnp.where(keep, mypos, cap - 1)
        buf = jnp.zeros((E, cap, D), xs.dtype)
        src = xs[tok_of] * keep[:, None].astype(xs.dtype)
        buf = buf.at[flat_e, dest].add(src)
        return buf, (flat_e, dest, flat_w, keep, tok_of)

    bufs, meta = jax.vmap(one_group)(xg, idsg, wg)  # (G, E, cap, D)
    bufs = shard_act(bufs, (("pod", "data"), "model", None, None))
    yb = jax.vmap(lambda b: _expert_ffn(
        params["w_gate"], params["w_up"], params["w_down"], b))(bufs)
    yb = shard_act(yb, (("pod", "data"), "model", None, None))

    def combine(yb1, m):
        flat_e, dest, flat_w, keep, tok_of = m
        y_tok = yb1[flat_e, dest]  # (Tg*k, D)
        y_tok = y_tok.astype(jnp.float32) * (flat_w * keep)[:, None]
        return jnp.zeros((Tg, D), jnp.float32).at[tok_of].add(y_tok)

    y = jax.vmap(combine)(yb, meta)  # (G, Tg, D)
    return y.reshape(T, D)
