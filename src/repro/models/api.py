"""Uniform model interface used by train/serve/dryrun.

Every family implements:
  init(rng) -> params
  loss(params, batch) -> (scalar, metrics)
  prefill(params, batch) -> (last_logits (B, V), cache)
  decode(params, cache, batch) -> (logits (B, V), cache)
  init_cache(batch_size, capacity) -> zeroed cache pytree
  cache_shapes(batch_size, capacity) -> ShapeDtypeStruct pytree
  input_shapes(shape_cfg) -> dict[str, ShapeDtypeStruct]
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, ShapeConfig, dt


class BaseModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- construction ------------------------------------------------------
    def init(self, rng):
        raise NotImplementedError

    def param_shapes(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # -- compute -----------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        raise NotImplementedError

    def prefill(self, params, batch, capacity=None):
        raise NotImplementedError

    def decode(self, params, cache, batch):
        raise NotImplementedError

    def init_cache(self, batch_size: int, capacity: int):
        raise NotImplementedError

    def cache_shapes(self, batch_size: int, capacity: int):
        zeros = jax.eval_shape(lambda: self.init_cache(batch_size, capacity))
        return zeros

    # -- paged KV cache protocol (opt-in per family) ----------------------
    @property
    def supports_paged_kv(self) -> bool:
        """Whether this family implements the paged cache protocol
        (``init_paged_pool`` / ``paged_prefill`` / ``paged_decode``).
        Families with non-KV recurrent state (rwkv6, mamba2) or
        prepended stub embeddings keep the dense ring layout."""
        return False

    def init_paged_pool(self, n_pages: int, page: int):
        raise NotImplementedError(
            f"{type(self).__name__} does not support the paged KV layout")

    def paged_prefill(self, params, batch, pool, scatter_tbl, *,
                      page: int, capacity: int):
        raise NotImplementedError(
            f"{type(self).__name__} does not support the paged KV layout")

    def paged_decode(self, params, pool, table, pos, t, batch, *,
                     page: int):
        raise NotImplementedError(
            f"{type(self).__name__} does not support the paged KV layout")

    # -- speculative verify protocol (opt-in per family) ------------------
    @property
    def supports_verify(self) -> bool:
        """Whether this family implements the speculative-verify protocol
        (``verify`` / ``paged_verify``): score a K+1 token window in one
        dispatch with *per-row* cache positions, bitwise identical to
        K+1 chained ``decode`` steps."""
        return False

    def verify(self, params, cache, pos, t, batch):
        raise NotImplementedError(
            f"{type(self).__name__} does not support speculative verify")

    def paged_verify(self, params, pool, table, pos, t, batch, *,
                     page: int):
        raise NotImplementedError(
            f"{type(self).__name__} does not support speculative verify")

    # -- shapes ------------------------------------------------------------
    def cache_capacity(self, seq_len: int) -> int:
        w = self.cfg.sliding_window
        return min(seq_len, w) if w else seq_len

    def input_shapes(self, sc: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """Default token-LM inputs; multimodal families override."""
        B, S = sc.global_batch, sc.seq_len
        i32 = jnp.int32
        f = jax.ShapeDtypeStruct
        if sc.mode == "train":
            return {"tokens": f((B, S), i32), "labels": f((B, S), i32)}
        if sc.mode == "prefill":
            return {"tokens": f((B, S), i32)}
        return {"token": f((B, 1), i32)}

    def supports(self, sc: ShapeConfig) -> Tuple[bool, str]:
        """Whether this (arch, shape) combo is runnable (long_500k gating)."""
        if sc.name == "long_500k" and self.cfg.family in ("dense", "moe", "vlm", "encdec"):
            if not self.cfg.sliding_window:
                return False, "full-attention arch at 500k decode (quadratic KV) — skipped per assignment; use --swa-window variant"
        return True, ""


_REGISTRY = {}


def register_family(name: str):
    def deco(cls):
        _REGISTRY[name] = cls
        return cls
    return deco


def build_model(cfg: ArchConfig) -> BaseModel:
    from . import dense, encdec, rwkv6, zamba  # noqa: F401  (registration)
    if cfg.family not in _REGISTRY:
        raise ValueError(f"unknown family {cfg.family!r}")
    return _REGISTRY[cfg.family](cfg)
