"""RWKV6 ("Finch") — attention-free RNN LM with data-dependent decay.

Time-mixing uses the WKV6 recurrence per head (P = head size):
    o_t[j] = sum_i r_t[i] * (S_t[i,j] + u[i] k_t[i] v_t[j])
    S_{t+1}[i,j] = exp(logw_t[i]) * S_t[i,j] + k_t[i] v_t[j]
with per-channel decay logw_t = -exp(w0 + lora(x_t)) (data-dependent), and
ddlerp token-shift mixing for the r/k/v/w/g branches (arXiv:2404.05892).

Two sequence-mode evaluators:
  * ``wkv_scan``    — exact per-timestep ``lax.scan`` (baseline / oracle)
  * ``wkv_chunked`` — chunkwise matmul formulation (MXU-friendly; decays
    accumulated in log space within a chunk, state carried across chunks).
The chunked path is the TPU adaptation of the CUDA wkv kernel and is the
subject of the rwkv6 §Perf hillclimb.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .api import BaseModel, register_family
from .common import (ArchConfig, KeyGen, dense_init, dt, embed_init,
                     groupnorm_heads, rmsnorm, softmax_xent)
from ..sharding import shard_act

BATCH = ("pod", "data")
N_MIX = 5  # r, k, v, w, g ddlerp branches


def _init_layer(key, cfg: ArchConfig, dtype):
    kg = KeyGen(key)
    D, F, R = cfg.d_model, cfg.d_ff, cfg.rwkv_lora_dim
    H, P = cfg.n_heads, cfg.dh
    return {
        "ln1": jnp.ones((D,), jnp.float32),
        "ln2": jnp.ones((D,), jnp.float32),
        # ddlerp token-shift mixing
        "maa_x": jnp.zeros((D,), jnp.float32),
        "maa_base": jnp.zeros((N_MIX, D), jnp.float32),
        "maa_w1": jnp.zeros((D, N_MIX * R), jnp.float32),
        "maa_w2": dense_init(kg(), (N_MIX, R, D), jnp.float32, in_axis=-2),
        # data-dependent decay
        "decay_w0": jnp.full((H, P), -6.0, jnp.float32).reshape(H, P),
        "decay_lora1": dense_init(kg(), (D, 2 * R), jnp.float32),
        "decay_lora2": dense_init(kg(), (2 * R, D), jnp.float32),
        "first_u": jnp.zeros((H, P), jnp.float32),
        # projections
        "w_r": dense_init(kg(), (D, D), dtype),
        "w_kk": dense_init(kg(), (D, D), dtype),
        "w_vv": dense_init(kg(), (D, D), dtype),
        "w_g": dense_init(kg(), (D, D), dtype),
        "w_o2": dense_init(kg(), (D, D), dtype),
        "g_norm": jnp.ones((D,), jnp.float32),
        # channel mix
        "ch_maa_k": jnp.zeros((D,), jnp.float32),
        "ch_maa_r": jnp.zeros((D,), jnp.float32),
        "w_ch_k": dense_init(kg(), (D, F), dtype),
        "w_ch_v": dense_init(kg(), (F, D), dtype),
        "w_ch_r": dense_init(kg(), (D, D), dtype),
    }


def _shift(x, x_prev):
    """x: (B, L, D); x_prev: (B, D) state (last token of previous segment)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _ddlerp(lp, x, xs):
    """Data-dependent lerp producing the 5 mixed branch inputs."""
    dx = xs - x
    xxx = (x + dx * lp["maa_x"]).astype(x.dtype)
    r = lp["maa_w1"].shape[1] // N_MIX
    lo = jnp.tanh(xxx.astype(jnp.float32) @ lp["maa_w1"])
    lo = lo.reshape(x.shape[:-1] + (N_MIX, r))
    mixes = lp["maa_base"] + jnp.einsum("...kr,krd->...kd", lo, lp["maa_w2"])
    out = x[..., None, :] + dx[..., None, :] * mixes.astype(x.dtype)
    return [out[..., i, :] for i in range(N_MIX)]  # w, k, v, r, g


def wkv_scan(r, k, v, logw, u, initial_state=None):
    """Exact recurrence. r/k/v/logw: (B, L, H, P); u: (H, P).
    Returns (o (B, L, H, P) f32, final_state (B, H, P, P) f32)."""
    B, L, H, P = r.shape
    s0 = (jnp.zeros((B, H, P, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    rT = jnp.moveaxis(r.astype(jnp.float32), 1, 0)
    kT = jnp.moveaxis(k.astype(jnp.float32), 1, 0)
    vT = jnp.moveaxis(v.astype(jnp.float32), 1, 0)
    wT = jnp.moveaxis(logw.astype(jnp.float32), 1, 0)

    def body(S, inp):
        rt, kt, vt, wt = inp  # (B, H, P)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, H, P, P)
        o = jnp.einsum("bhi,bhij->bhj", rt,
                       S + (u * kt)[..., :, None] * vt[..., None, :])
        S = jnp.exp(wt)[..., :, None] * S + kv
        return S, o

    S, oT = jax.lax.scan(body, s0, (rT, kT, vT, wT))
    return jnp.moveaxis(oT, 0, 1), S


def wkv_step(S, rt, kt, vt, logwt, u):
    """One decode step. S: (B,H,P,P) f32; rt/kt/vt/logwt: (B,H,P)."""
    S = S.astype(jnp.float32)
    rt, kt, vt, wt = (a.astype(jnp.float32) for a in (rt, kt, vt, logwt))
    kv = kt[..., :, None] * vt[..., None, :]
    o = jnp.einsum("bhi,bhij->bhj", rt, S + (u * kt)[..., :, None] * vt[..., None, :])
    S = jnp.exp(wt)[..., :, None] * S + kv
    return S, o


def wkv_chunked(r, k, v, logw, u, initial_state=None, chunk: int = 32):
    """Chunkwise WKV6: intra-chunk via (Q x Q) matmuls with per-channel
    log-space decay factored into r'/k', inter-chunk via state carry.
    Valid because within a short chunk |cumsum(logw)| is moderate; we clamp
    per-step logw at -8 (exp(-8) ~ 3e-4 decay floor) to bound the exponent
    spread, matching fla's chunked rwkv6 implementation."""
    B, L, H, P = r.shape
    if L % chunk:
        return wkv_scan(r, k, v, logw, u, initial_state)
    nc, Q = L // chunk, chunk
    f32 = jnp.float32
    rc = r.astype(f32).reshape(B, nc, Q, H, P)
    kc = k.astype(f32).reshape(B, nc, Q, H, P)
    vc = v.astype(f32).reshape(B, nc, Q, H, P)
    wc = jnp.clip(logw.astype(f32), -8.0, -1e-6).reshape(B, nc, Q, H, P)
    cs = jnp.cumsum(wc, axis=2)  # inclusive
    total = cs[:, :, -1]  # (B, nc, H, P)
    # decay of state contribution: for output at q, state decayed by
    # exp(cs[q-1]) = exp(cs[q] - w[q]); define cs_ex = cs - wc (exclusive)
    cs_ex = cs - wc
    # intra-chunk: o[q] += sum_{q2<q} (r[q]*exp(cs_ex[q])) . (k[q2]*exp(-cs[q2])) v[q2]
    # the true pair exponent cs_ex[q] - cs[q2] is always <= 0; the
    # factorization splits it into one negative and one *positive* half —
    # shift both by the chunk-midpoint cumsum so each half's magnitude is
    # bounded by (Q/2)*|w|_max (finite in f32 for Q<=32 with the -8 clamp)
    mid = cs[:, :, Q // 2:Q // 2 + 1]
    r_dec = rc * jnp.exp(cs_ex - mid)
    k_dec = kc * jnp.exp(mid - cs)
    att = jnp.einsum("bcqhp,bcrhp->bcqrh", r_dec, k_dec)
    tri = jnp.tril(jnp.ones((Q, Q), bool), k=-1)  # strictly lower
    att = jnp.where(tri[None, None, :, :, None], att, 0.0)
    o_intra = jnp.einsum("bcqrh,bcrhp->bcqhp", att, vc)
    # bonus (current token) term
    o_bonus = jnp.einsum("bcqhp,bcqhp->bcqh", rc, u * kc)[..., None] * vc
    # inter-chunk: state before chunk, decayed to q by exp(cs_ex[q])
    kv_c = jnp.einsum("bcqhp,bcqhj->bchpj", kc * jnp.exp(total[:, :, None] - cs), vc)
    s0 = (jnp.zeros((B, H, P, P), f32) if initial_state is None
          else initial_state.astype(f32))

    def body(S, inp):
        kv, tot = inp  # (B,H,P,P), (B,H,P)
        S_new = jnp.exp(tot)[..., None] * S + kv
        return S_new, S

    S_fin, S_before = jax.lax.scan(
        body, s0, (jnp.moveaxis(kv_c, 1, 0), jnp.moveaxis(total, 1, 0)))
    S_before = jnp.moveaxis(S_before, 0, 1)  # (B, nc, H, P, P)
    o_state = jnp.einsum("bcqhp,bchpj->bcqhj", rc * jnp.exp(cs_ex), S_before)
    o = (o_intra + o_bonus + o_state).reshape(B, L, H, P)
    return o, S_fin


def time_mix(lp, x, cfg: ArchConfig, x_prev, wkv_state, mode: str):
    """x: (B, L, D) pre-normed. Returns (out, new_x_prev, new_wkv_state)."""
    B, L, D = x.shape
    H, P = cfg.n_heads, cfg.dh
    xs = _shift(x, x_prev)
    xw, xk, xv, xr, xg = _ddlerp(lp, x, xs)
    r = (xr @ lp["w_r"]).reshape(B, L, H, P)
    k = (xk @ lp["w_kk"]).reshape(B, L, H, P)
    v = (xv @ lp["w_vv"]).reshape(B, L, H, P)
    g = jax.nn.silu((xg @ lp["w_g"]).astype(jnp.float32))
    lo = jnp.tanh(xw.astype(jnp.float32) @ lp["decay_lora1"]) @ lp["decay_lora2"]
    w_raw = lp["decay_w0"].reshape(D) + lo  # (B, L, D)
    logw = -jnp.exp(w_raw).reshape(B, L, H, P)
    r = shard_act(r, (BATCH, None, "model", None))
    k = shard_act(k, (BATCH, None, "model", None))
    if mode == "chunked":
        o, S = wkv_chunked(r, k, v, logw, lp["first_u"], wkv_state,
                           cfg.ssm_chunk)
    else:
        o, S = wkv_scan(r, k, v, logw, lp["first_u"], wkv_state)
    o = groupnorm_heads(o, jnp.ones((H, P), jnp.float32))
    o = o.reshape(B, L, D) * lp["g_norm"] * g
    out = o.astype(x.dtype) @ lp["w_o2"]
    return out.astype(x.dtype), x[:, -1], S


def channel_mix(lp, x, x_prev):
    xs = _shift(x, x_prev)
    dx = xs - x
    xk = (x + dx * lp["ch_maa_k"]).astype(x.dtype)
    xr = (x + dx * lp["ch_maa_r"]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ lp["w_ch_k"]))
    out = jax.nn.sigmoid((xr @ lp["w_ch_r"]).astype(jnp.float32)).astype(x.dtype) \
        * (k @ lp["w_ch_v"])
    return out, x[:, -1]


def _layer(lp, x, cfg, state, mode):
    """state: dict(S, x_tm, x_cm). Returns (x, new_state)."""
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    o, x_tm, S = time_mix(lp, h, cfg, state["x_tm"], state["S"], mode)
    x = x + o
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    o2, x_cm = channel_mix(lp, h2, state["x_cm"])
    x = x + o2
    x = shard_act(x, (BATCH, None, None))
    return x, {"S": S, "x_tm": x_tm, "x_cm": x_cm}


@register_family("rwkv")
class RWKV6(BaseModel):
    seq_mode = "chunked"  # chunked | scan  (hillclimb knob)

    def init(self, rng):
        cfg = self.cfg
        dtype = dt(cfg.param_dtype)
        kg = KeyGen(rng)
        keys = jax.random.split(kg(), cfg.n_layers)
        layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(keys)
        return {
            "embed": embed_init(kg(), (cfg.padded_vocab, cfg.d_model), dtype),
            "layers": layers,
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
            "unembed": dense_init(kg(), (cfg.d_model, cfg.padded_vocab), dtype),
        }

    def _zero_state(self, B):
        cfg = self.cfg
        H, P, D = cfg.n_heads, cfg.dh, cfg.d_model
        cdt = dt(cfg.compute_dtype)
        return {
            "S": jnp.zeros((B, H, P, P), jnp.float32),
            "x_tm": jnp.zeros((B, D), cdt),
            "x_cm": jnp.zeros((B, D), cdt),
        }

    def _run(self, params, x, state_stack, mode):
        cfg = self.cfg

        def body(x, inp):
            lp, st = inp
            x, new_st = _layer(lp, x, cfg, st, mode)
            return x, new_st

        if cfg.remat:
            body = jax.checkpoint(body)
        x, new_states = jax.lax.scan(body, x, (params["layers"], state_stack))
        return x, new_states

    def _stack_zero(self, B):
        z = self._zero_state(B)
        L = self.cfg.n_layers
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros((L,) + a.shape, a.dtype), z)

    def loss(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(dt(cfg.compute_dtype))
        x = shard_act(x, (BATCH, None, None))
        x, _ = self._run(params, x, self._stack_zero(x.shape[0]),
                         self.seq_mode)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = x @ params["unembed"].astype(x.dtype)
        ce = softmax_xent(logits, batch["labels"])
        return ce, {"ce": ce}

    # -- serving --------------------------------------------------------
    def init_cache(self, batch_size, capacity):
        st = self._stack_zero(batch_size)
        st["t"] = jnp.zeros((), jnp.int32)
        return st

    def cache_capacity(self, seq_len):
        return 1  # constant-size recurrent state

    def prefill(self, params, batch, capacity=None):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(dt(cfg.compute_dtype))
        x, states = self._run(params, x, self._stack_zero(x.shape[0]),
                              self.seq_mode)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = x[:, -1] @ params["unembed"].astype(x.dtype)
        states["t"] = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
        return logits, states

    def decode(self, params, cache, batch):
        cfg = self.cfg
        t = cache.get("t", jnp.zeros((), jnp.int32))
        x = params["embed"][batch["token"]].astype(dt(cfg.compute_dtype))
        states = {k: v for k, v in cache.items() if k != "t"}
        x, new_states = self._run(params, x, states, "scan")
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = x[:, 0] @ params["unembed"].astype(x.dtype)
        new_states["t"] = t + 1
        return logits, new_states
