from .api import BaseModel, build_model
from .common import ArchConfig, ShapeConfig, SHAPES

__all__ = ["BaseModel", "build_model", "ArchConfig", "ShapeConfig", "SHAPES"]
