"""GQA attention: blockwise online-softmax (flash) for train/prefill,
plain masked attention for single-token decode, sliding-window support,
and KV-cache plumbing.

TPU adaptation note: instead of porting a CUDA flash-attention kernel we use
a `jax.lax.scan` over KV chunks with an online-softmax carry — XLA:TPU keeps
the (Sq x chunk) score tile in VMEM and never materializes the full S x S
matrix. The chunk size (`cfg.attn_chunk`) is a roofline tuning knob.
A Pallas flash-decode kernel (repro/kernels/decode_attention.py) covers the
decode hot path on real TPUs; the code here is also its oracle.

Masking is position-id based throughout: every key slot carries an absolute
position (-1 = empty), which makes full caches and sliding-window ring
caches look identical to the attention math.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q: (B, Sq, H, dh), k: (B, Sk, KV, dh) -> (B, Sq, H, Sk) in f32."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dh)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    return s.reshape(B, Sq, H, Sk)


def _gqa_av(p, v):
    """p: (B, Sq, H, Sk) f32, v: (B, Sk, KV, dh) -> (B, Sq, H, dh) f32."""
    B, Sq, H, Sk = p.shape
    KV, dh = v.shape[2], v.shape[3]
    G = H // KV
    pg = p.reshape(B, Sq, KV, G, Sk)
    o = jnp.einsum("bqkgs,bskd->bqkgd", pg, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh)


def _edge_mask(q_pos, kv_pos, window: int, causal: bool = True):
    """Allowed-edge mask. Shared positions — q_pos (Sq,), kv_pos (Sk,)
    — give an (Sq, Sk) mask; per-row positions — q_pos (B, Sq), kv_pos
    (B, Sk), the speculative-verify path where rows advance by
    different accepted-prefix lengths — give (B, Sq, Sk). kv_pos == -1
    marks an empty cache slot (always masked); the comparisons are
    elementwise either way, so the two ranks agree wherever a per-row
    mask carries the same positions in every row."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    m = kp >= 0
    if causal:
        m &= kp <= qp
    if window:
        m &= kp > qp - window
    return m


def attention(q, k, v, *, q_pos, kv_pos, window: int = 0, chunk: int = 0,
              causal: bool = True):
    """Unified GQA attention.

    q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh); q_pos: (Sq,) int32 absolute
    query positions; kv_pos: (Sk,) int32 absolute key positions (-1 empty).
    Per-row positions — q_pos (B, Sq) / kv_pos (B, Sk) — are accepted on
    the plain path only (speculative verify is single-token decode, which
    never takes the flash branch). Returns (B, Sq, H, dh) in q.dtype.
    ``chunk`` selects the blockwise online-softmax path when it tiles Sk.
    """
    Sq, Sk = q.shape[1], k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    if chunk and Sq > 1 and Sk > chunk and Sk % chunk == 0:
        if q_pos.ndim != 1 or kv_pos.ndim != 1:
            raise ValueError("flash path requires shared (1-D) positions")
        return _flash(q, k, v, q_pos=q_pos, kv_pos=kv_pos, window=window,
                      chunk=chunk, scale=scale, causal=causal)
    m = _edge_mask(q_pos, kv_pos, window, causal)  # (Sq, Sk) | (B, Sq, Sk)
    m = m[None, :, None, :] if m.ndim == 2 else m[:, :, None, :]
    s = _gqa_scores(q, k) * scale  # (B, Sq, H, Sk)
    s = jnp.where(m, s, NEG_INF)
    # guard fully-masked rows (empty cache) against NaN
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_av(p, v)
    return o.astype(q.dtype)


def _flash(q, k, v, *, q_pos, kv_pos, window, chunk, scale, causal=True):
    """Online-softmax scan over KV chunks; never materializes (Sq, Sk)."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    n_chunks = Sk // chunk
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, KV, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, KV, dh), 1, 0)
    pc = kv_pos.reshape(n_chunks, chunk)

    def body(carry, inp):
        m_run, l_run, acc = carry
        kb, vb, pos_b = inp
        s = _gqa_scores(q, kb) * scale  # (B, Sq, H, chunk) f32
        msk = _edge_mask(q_pos, pos_b, window, causal)  # (Sq, chunk)
        s = jnp.where(msk[None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + _gqa_av(p, vb)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((B, Sq, H), NEG_INF, jnp.float32),
        jnp.zeros((B, Sq, H), jnp.float32),
        jnp.zeros((B, Sq, H, dh), jnp.float32),
    )
    (m_run, l_run, acc), _ = jax.lax.scan(body, init, (kc, vc, pc))
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache: dict {k, v, pos, t}
#   k, v: (B, C, KV, dh) where C = max_len (full) or window (ring)
#   pos:  (C,) absolute position held in each slot, -1 if empty
#   t:    () next absolute position to write
# ---------------------------------------------------------------------------


def init_kv_cache(batch, capacity, n_kv, dh, dtype):
    return {
        "k": jnp.zeros((batch, capacity, n_kv, dh), dtype),
        "v": jnp.zeros((batch, capacity, n_kv, dh), dtype),
        "pos": jnp.full((capacity,), -1, jnp.int32),
        "t": jnp.zeros((), jnp.int32),
    }


def kv_cache_shapes(batch, capacity, n_kv, dh, dtype):
    """ShapeDtypeStruct pytree mirroring init_kv_cache (for dry-run)."""
    f = jax.ShapeDtypeStruct
    return {
        "k": f((batch, capacity, n_kv, dh), dtype),
        "v": f((batch, capacity, n_kv, dh), dtype),
        "pos": f((capacity,), jnp.int32),
        "t": f((), jnp.int32),
    }


def cache_prefill(cache, k, v):
    """Write a full prefill of S tokens (positions 0..S-1) into the cache.
    If the cache is a ring (capacity < S), keep the last `capacity` tokens."""
    S = k.shape[1]
    C = cache["k"].shape[1]
    if S <= C:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, 0, 0))
        pos = jnp.where(jnp.arange(C) < S, jnp.arange(C), -1).astype(jnp.int32)
    else:
        # ring: keep last C tokens; slot = absolute_pos % C
        last_k = k[:, S - C:, :, :]
        last_v = v[:, S - C:, :, :]
        abs_pos = jnp.arange(S - C, S)
        slots = abs_pos % C
        ck = cache["k"].at[:, slots].set(last_k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(last_v.astype(cache["v"].dtype))
        pos = jnp.zeros((C,), jnp.int32).at[slots].set(abs_pos)
    return {"k": ck, "v": cv, "pos": pos, "t": jnp.asarray(S, jnp.int32)}


def cache_append(cache, k1, v1):
    """Append one token (k1, v1: (B, 1, KV, dh)); ring-wraps automatically."""
    C = cache["k"].shape[1]
    t = cache["t"]
    slot = t % C
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k1.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v1.astype(cache["v"].dtype), (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(cache["pos"], t[None], (slot,))
    return {"k": ck, "v": cv, "pos": pos, "t": t + 1}


# ---------------------------------------------------------------------------
# Paged KV cache protocol (single-layer primitives)
#
# Instead of one dense (B, C, KV, dh) buffer per micro-batch, K/V live
# in a shared pool of fixed-size pages (n_pages + 1, page, KV, dh) —
# the trailing page is the *trash page*, a write-discard target for
# rows whose computed KV is deliberately dropped (batch padding, rows
# deduplicated against a shared prefix). Each row carries a page table
# (B, C // page) of physical page ids; prefix-sharing rows simply map
# leading logical pages to the same physical pages. `pos`/`t` tracking
# is unchanged from the ring cache: positions are logical-slot-indexed
# and rows advance in lockstep, so the attention masking math cannot
# tell the layouts apart. Allocation/refcounting is host-side
# (`repro.serve.kvcache.PagePool`); these helpers are the device half.
# ---------------------------------------------------------------------------


def init_paged_pool(n_pages, page, n_kv, dh, dtype):
    """Zeroed (n_pages + 1, page, KV, dh) pool; last page is trash."""
    shape = (n_pages + 1, page, n_kv, dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_gather(k_pages, v_pages, table):
    """Materialise each row's logical KV view through its page table.

    k_pages, v_pages: (P1, page, KV, dh); table: (B, n) int32 physical
    page per logical page. Returns dense (B, n * page, KV, dh) views
    whose values equal the ring cache's for every written slot (unwritten
    slots carry pool garbage — always masked via pos == -1).
    """
    B, n = table.shape
    page, KV, dh = k_pages.shape[1:]
    k = k_pages[table].reshape(B, n * page, KV, dh)
    v = v_pages[table].reshape(B, n * page, KV, dh)
    return k, v


def paged_scatter_pages(k_pages, v_pages, scatter_tbl, k, v):
    """Write whole prefill pages: k, v (B, S, KV, dh) with S a multiple
    of the page size; scatter_tbl (B, S // page) physical destinations.
    Rows whose compute is discarded point every entry at the trash page
    (duplicate trash indices are fine — the page is never read)."""
    B, S, KV, dh = k.shape
    npp = scatter_tbl.shape[1]
    page = S // npp
    ku = k.reshape(B, npp, page, KV, dh).astype(k_pages.dtype)
    vu = v.reshape(B, npp, page, KV, dh).astype(v_pages.dtype)
    return (k_pages.at[scatter_tbl].set(ku),
            v_pages.at[scatter_tbl].set(vu))


def suffix_attend(q, k_suf, v_suf, pk, pv, *, offset, window=0, chunk=0):
    """Suffix-prefill attention: queries at absolute positions
    ``offset .. offset + Ssuf - 1`` attend over the cached prefix KV
    (absolute positions ``0 .. offset - 1``, typically gathered through a
    page table with :func:`paged_gather`) concatenated with the suffix's
    own freshly-computed KV.

    q, k_suf, v_suf: (B, Ssuf, ·, dh); pk, pv: (B, offset, KV, dh).
    ``offset`` must be a static int (it shapes the position vectors).

    Exactness: causal masking means prefix positions never attend to the
    suffix, so the prefix KV read from the pool is the same tensor a
    monolithic prefill would have computed in place — a greedy decode
    seeded from suffix logits is token-identical to the monolithic path.
    Rows whose prefix table points at the trash page read finite garbage;
    their outputs must be discarded by the caller (batch padding).
    """
    Ssuf = q.shape[1]
    positions = jnp.arange(offset, offset + Ssuf)
    fk = jnp.concatenate([pk.astype(k_suf.dtype), k_suf], axis=1)
    fv = jnp.concatenate([pv.astype(v_suf.dtype), v_suf], axis=1)
    kv_pos = jnp.concatenate([jnp.arange(offset), positions])
    return attention(q, fk, fv, q_pos=positions, kv_pos=kv_pos,
                     window=window, chunk=chunk)


def paged_append(k_pages, v_pages, tbl_col, offset, k1, v1):
    """Write one decoded token per row: tbl_col (B,) physical pages,
    offset () in-page slot (shared — rows decode in lockstep), k1, v1
    (B, 1, KV, dh)."""
    return (k_pages.at[tbl_col, offset].set(k1[:, 0].astype(k_pages.dtype)),
            v_pages.at[tbl_col, offset].set(v1[:, 0].astype(v_pages.dtype)))


def paged_append_rows(k_pages, v_pages, tbl_cols, offsets, kw, vw):
    """Write W tokens per row at *per-row* slots — the speculative
    verify scatter, where each row's write window starts at its own
    ``t``. tbl_cols, offsets: (B, W) physical page / in-page slot per
    written token; kw, vw: (B, W, KV, dh). Advanced indexing pairs the
    two index arrays elementwise, so (b, w) lands in
    ``pages[tbl_cols[b, w], offsets[b, w]]``. Rows may only collide on
    the trash page (write windows are wave-owned per row), where the
    winning write is irrelevant — the page is never read unmasked."""
    return (k_pages.at[tbl_cols, offsets].set(kw.astype(k_pages.dtype)),
            v_pages.at[tbl_cols, offsets].set(vw.astype(v_pages.dtype)))
