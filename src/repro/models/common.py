"""Shared model-zoo building blocks: configs, norms, RoPE, initializers.

Everything is pure JAX (no flax): params are nested dicts of jnp arrays,
modules are (init_fn, apply_fn) pairs. Layer stacks store params stacked on
a leading ``L`` axis and are applied with ``jax.lax.scan`` so the HLO stays
O(1) in depth (critical for 80-layer dry-run compiles).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "f32": jnp.float32,
    "bf16": jnp.bfloat16,
}


def dt(name: str):
    return DTYPES[name]


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """One config describes any architecture family in the zoo."""

    name: str
    family: str  # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_impl: str = "dispatch"  # dispatch | dense
    # --- attention variants ---
    sliding_window: int = 0  # 0 = full attention
    attn_chunk: int = 1024  # KV block for chunked flash attention
    # --- SSM / RWKV ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    rwkv_lora_dim: int = 32
    # --- hybrid (zamba-style shared attention) ---
    attn_every: int = 0  # apply shared attn block after every N core layers
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    enc_seq_len: int = 0  # stub encoder frames (audio)
    # --- multimodal stub ---
    n_stub_embeds: int = 0  # patch embeddings prepended (vlm)
    # --- dtypes / memory policy ---
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    train_microbatches: int = 1
    seq_parallel: bool = False  # shard the seq dim of activations over model
    # provenance
    source: str = ""

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (model axis x lane) so the
        embedding/unembedding tables shard cleanly. Labels/tokens always
        stay < vocab_size; pad logits train toward -inf harmlessly."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, **kw) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=2,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            head_dim=32 if self.head_dim else 0,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            attn_chunk=64,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_dec_layers=min(self.n_dec_layers, 2),
            enc_seq_len=min(self.enc_seq_len, 16) if self.enc_seq_len else 0,
            n_stub_embeds=min(self.n_stub_embeds, 8) if self.n_stub_embeds else 0,
            rwkv_lora_dim=8,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            train_microbatches=1,
            name=self.name + "-smoke",
        )
        # keep GQA ratio valid
        if small["n_heads"] % max(small["n_kv_heads"], 1):
            small["n_kv_heads"] = small["n_heads"]
        small.update(kw)
        return self.replace(**small)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape (training / prefill / decode)."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis=-2):
    """LeCun-normal style init on the fan-in axis."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Splits a PRNG key on demand; keeps init code tidy."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def groupnorm_heads(x, scale, eps=1e-5):
    """GroupNorm over the last dim where x is (..., H, P): normalize each head."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(dh: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh), positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_xent(logits, labels, mask=None):
    """Mean cross-entropy; logits (..., V) any dtype, reduction in f32.

    The gold-logit pick uses an equality-mask contraction instead of
    take_along_axis: with the vocab dim sharded over the ``model`` axis the
    masked reduce stays sharded (partial sums + one psum) where a gather
    would force GSPMD to all-gather the full f32 logits.
    """
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    onehot = (labels[..., None] == vocab_iota)
    gold = jnp.sum(jnp.where(onehot, logits.astype(jnp.float32), 0.0),
                   axis=-1)
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def tree_size(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))
