"""repro.obs — zero-dependency tracing + metrics for the serving mesh.

Two halves, both pure stdlib (no numpy, no jax — importable from any
layer without dragging a backend in):

``trace``
    Request-lifecycle spans. A :class:`Tracer` mints per-request trace
    ids at ``Scheduler.submit`` and carries them through routing, hub
    admission (park → stage → commit), chunked prefill, speculative
    verify/fallback and harvest. Host work uses ``span(...)`` contexts;
    device work uses ``begin_device``/``end_device`` pairs that close
    only at the engine's existing harvest sync points, so tracing adds
    **zero** new host blocks by construction (``EngineStats.host_blocks``
    is asserted identical with tracing on and off). Export is Chrome
    ``trace_event`` JSON (load in ``chrome://tracing`` / Perfetto) or a
    greppable JSONL stream.

``metrics``
    ``Counter`` / ``Gauge`` / ``Histogram`` (fixed log buckets, pure
    Python in the hot path) plus a :class:`MetricsRegistry` that folds
    ``EngineStats``, ``HubStats``, scheduler counters and
    ``PagePool.telemetry()`` into one ``snapshot()`` tree — the single
    source of truth ``serving_bench`` and the placement rebalancer read.

The static side of the contract lives in ``repro.analysis.obs_lint``
(rules O001–O003): no tracing call inside jit-traced code, device-
dispatch spans must end at a blessed sync site, histogram buckets
declared as literals.
"""
from .metrics import (DEFAULT_MS_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .trace import NULL_TRACER, Tracer

__all__ = [
    "Counter",
    "DEFAULT_MS_BUCKETS",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "Tracer",
]
