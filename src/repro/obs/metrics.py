"""Unified metrics: Counter / Gauge / Histogram + the snapshot registry.

The hot-path rule: **no numpy**. ``Histogram.observe`` is a ``bisect``
into a fixed tuple of bucket bounds — scheduler latency accounting runs
once per request, on the serving thread, and must never pay an array
allocation. Bucket bounds are declared as literals (rule O003) so a
reviewer can read the resolution straight off the call site and no
runtime computation can silently produce degenerate buckets.

The :class:`MetricsRegistry` is the one snapshot tree. Producers
register under a slash path (``engines/shard0``, ``hub``, ``kv/...``)
either a metric instance or a zero-argument provider (a callable
returning a dict/scalar, or an object with ``as_dict``) — providers are
pulled lazily at ``snapshot()`` so registration costs nothing on the
hot path and the tree always reflects live state.

Naming convention (see docs/architecture.md "Observability"):
top-level groups are ``scheduler``, ``engines/<shard>``, ``kv/<shard>``,
``hub``, ``router``, ``executor``; leaves are snake_case counters in
base units (``*_ms`` for milliseconds, ``*_s`` for seconds).
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Any, Callable, Dict, List, Tuple, Union

#: Default latency buckets, milliseconds — log-spaced from 50µs to 5s.
#: A literal on purpose (rule O003): bucket resolution is part of the
#: observability contract, not a runtime computation.
DEFAULT_MS_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
                      25.0, 50.0, 100.0, 250.0, 500.0, 1000.0,
                      2500.0, 5000.0)


class Counter:
    """Monotonic counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0) -> None:
        self.value = value

    def set(self, value: float) -> None:
        self.value = value


class Histogram:
    """Fixed-bucket histogram (cumulative-style bounds, +inf implicit).

    ``buckets`` must be an ascending sequence of numeric literals
    (O003). ``observe`` is one ``bisect`` + two adds — pure Python, no
    numpy, safe on the serving thread.
    """

    __slots__ = ("buckets", "counts", "count", "sum", "max")

    def __init__(self, buckets: Tuple[float, ...] = DEFAULT_MS_BUCKETS):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in
                             zip(bounds, bounds[1:])):
            raise ValueError(
                f"histogram buckets must be non-empty ascending, "
                f"got {buckets!r}")
        self.buckets = bounds
        self.counts = [0] * (len(bounds) + 1)   # last = overflow
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def observe(self, value: float) -> None:
        self.counts[bisect_left(self.buckets, value)] += 1
        self.count += 1
        self.sum += value
        if value > self.max:
            self.max = value

    def percentile(self, q: float) -> float:
        """Upper-bound estimate: the smallest bucket bound whose
        cumulative count covers the ``q`` quantile (the overflow bucket
        reports the true max). 0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        need = q * self.count
        seen = 0
        for bound, n in zip(self.buckets, self.counts):
            seen += n
            if seen >= need:
                return bound
        return self.max

    def snapshot(self) -> Dict[str, float]:
        mean = self.sum / self.count if self.count else 0.0
        return {"count": self.count, "sum": self.sum, "mean": mean,
                "p50": self.percentile(0.50),
                "p95": self.percentile(0.95),
                "p99": self.percentile(0.99),
                "max": self.max}


Provider = Union[Counter, Gauge, Histogram, Callable[[], Any]]


def _resolve(provider: Any) -> Any:
    if isinstance(provider, (Counter, Gauge)):
        return provider.value
    if isinstance(provider, Histogram):
        return provider.snapshot()
    if callable(provider):
        return _resolve(provider())
    if hasattr(provider, "as_dict"):
        return _resolve(provider.as_dict())
    if isinstance(provider, dict):
        return {k: _resolve(v) for k, v in provider.items()}
    return provider


class MetricsRegistry:
    """The snapshot tree: slash-path → provider, resolved lazily.

    Re-registering a path replaces the provider (servers rebind after
    reconfiguration); registering under a path that already has leaves
    merges at snapshot time, later registrations winning on key clashes.
    """

    def __init__(self) -> None:
        self._providers: List[Tuple[Tuple[str, ...], Provider]] = []

    def register(self, path: str, provider: Provider) -> None:
        if not path:
            raise ValueError("metrics path must be non-empty")
        key = tuple(path.split("/"))
        self._providers = [(k, p) for k, p in self._providers
                           if k != key]
        self._providers.append((key, provider))

    def snapshot(self) -> Dict[str, Any]:
        """Resolve every provider into one nested dict."""
        tree: Dict[str, Any] = {}
        for key, provider in self._providers:
            node = tree
            for part in key[:-1]:
                node = node.setdefault(part, {})
                if not isinstance(node, dict):
                    raise TypeError(
                        f"metrics path {'/'.join(key)} descends through "
                        f"a leaf")
            resolved = _resolve(provider)
            leaf = key[-1]
            if isinstance(resolved, dict) and isinstance(
                    node.get(leaf), dict):
                node[leaf].update(resolved)
            else:
                node[leaf] = resolved
        return tree
