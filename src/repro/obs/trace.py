"""Request-lifecycle tracing for the serving mesh.

Design constraints, in order:

1. **Zero new host blocks.** Device work (prefill / decode / verify
   dispatches) is timed with ``begin_device``/``end_device`` handle
   pairs. ``end_device`` is only ever called from the engine's existing
   sync points (``_materialize``/``_materialize_spec``, the two
   functions that already call ``jax.device_get`` and bump
   ``EngineStats.host_blocks``) — the tracer itself never syncs, so a
   device span measures *the same* enqueue→harvest interval the serving
   stack already pays for. Rule O002 in ``repro.analysis`` enforces
   this statically.

2. **One clock read per edge.** A ``span`` reads ``perf_counter`` once
   at enter and once at exit, and exposes the elapsed ``.ms`` so call
   sites that also feed their own stats (e.g. ``HubStats.stage_ms``)
   reuse the measurement instead of reading the clock again. Spans
   *always* measure, even on a disabled tracer — recording is what
   enabling toggles — so stats stay populated when tracing is off.

3. **No dependencies.** Pure stdlib; importable from the analysis layer
   and from tests without jax.

Span taxonomy (the names the exporter and the bench's stage-breakdown
join rely on — see docs/architecture.md "Observability"):

=====================  ====  =======================================
name                   ph    emitted by
=====================  ====  =======================================
``request.submit``     i     ``Scheduler.submit`` (mints trace id)
``route``              X     scheduler, around ``Router.route``
``request.admit``      i     scheduler, per admitted dispatch group
``hub.park``           i     scheduler, rows parked on ``NotResident``
``hub.stage``          X     hub worker/inline, checkpoint → host
``hub.commit``         X     hub, host → device slot install (enqueue)
``kv.requeue``         i     scheduler, ``PagePoolExhausted`` rollback
``wave.prefill``       X     engine, admit enqueue → harvest sync
``wave.chunk``         i     engine, one chunked-prefill dispatch
``wave.decode``        X     engine, decode tick(s) → harvest sync
``wave.verify``        X     engine, speculative verify → harvest sync
``spec.fallback``      i     engine, wave gated to plain decode
``request.finish``     i     scheduler harvest (per response)
=====================  ====  =======================================
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional


class _Span:
    """Live span handle (context manager).

    Always measures — one ``perf_counter`` read at enter, one at exit —
    and publishes the elapsed milliseconds as ``.ms`` so the call site
    can fold the same measurement into its own stats. The record is
    appended to the tracer only when recording is enabled. An exception
    propagating out of the body still closes the span (with an
    ``error`` attribute) so span balance holds under rollback paths.
    """

    __slots__ = ("_tracer", "name", "cat", "args", "t0", "ms")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = 0.0
        self.ms = 0.0

    def set(self, **attrs: Any) -> "_Span":
        self.args.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, etype, evalue, tb) -> bool:
        t1 = time.perf_counter()
        self.ms = (t1 - self.t0) * 1e3
        if etype is not None:
            self.args.setdefault("error", etype.__name__)
        if self._tracer.enabled:
            self._tracer._append(self.name, self.cat, "X", self.t0,
                                 t1 - self.t0, self.args)
        return False


class _DeviceSpan:
    """Open device-work handle: begun at enqueue, ended at a sync site."""

    __slots__ = ("name", "args", "t0", "tid")

    def __init__(self, name: str, args: Dict[str, Any], t0: float,
                 tid: str):
        self.name = name
        self.args = args
        self.t0 = t0
        self.tid = tid


class Tracer:
    """Thread-safe span/event recorder with Chrome + JSONL export.

    One tracer serves the whole mesh: the scheduler thread, the hub's
    stager thread and (in tests) arbitrary callers append under one
    lock. Timestamps are microseconds relative to the tracer's epoch,
    which is what the Chrome ``trace_event`` format wants.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._records: List[Dict[str, Any]] = []
        self._seq = 0
        self._uid_trace: Dict[Any, int] = {}
        self._open: Dict[int, _DeviceSpan] = {}

    # -- clock / ids ---------------------------------------------------
    def now(self) -> float:
        """The tracer's clock (``perf_counter`` seconds) — call sites
        that stamp their own timestamps use this so every number in a
        trace shares one time base."""
        return time.perf_counter()

    def next_id(self) -> int:
        """Mint a fresh id (request traces, wave ids) — monotonic,
        unique across threads."""
        with self._lock:
            self._seq += 1
            return self._seq

    def bind_uid(self, uid: Any, trace: int) -> None:
        """Associate a request uid with its trace id so layers that only
        see uids (the engine core) can label spans without threading
        trace ids through every call signature."""
        if not self.enabled:
            return
        with self._lock:
            self._uid_trace[uid] = trace

    def trace_of(self, uid: Any) -> int:
        with self._lock:
            return self._uid_trace.get(uid, 0)

    def release_uid(self, uid: Any) -> None:
        with self._lock:
            self._uid_trace.pop(uid, None)

    # -- spans ---------------------------------------------------------
    def span(self, name: str, /, **attrs: Any) -> _Span:
        """Host-work span. Must NOT wrap bare device dispatch — rule
        O002 flags that; use ``begin_device``/``end_device`` (completion
        semantics) or ``enqueue_span`` (explicit enqueue semantics)."""
        return _Span(self, name, "host", attrs)

    def enqueue_span(self, name: str, /, **attrs: Any) -> _Span:
        """A span that *deliberately* measures device-work enqueue, not
        completion — e.g. the hub's jitted slot install, whose cost
        model is 'time until the scheduler may proceed'. The ``enqueue``
        category marks the semantics in the exported trace, and O002
        exempts it (the rule exists to catch *accidental* enqueue
        timing)."""
        return _Span(self, name, "enqueue", attrs)

    def event(self, name: str, /, **attrs: Any) -> None:
        """Instant event (Chrome ``ph: i``)."""
        if not self.enabled:
            return
        t = time.perf_counter()
        self._append(name, "host", "i", t, 0.0, attrs)

    # -- device-work handles -------------------------------------------
    def begin_device(self, name: str, /, **attrs: Any
                     ) -> Optional[_DeviceSpan]:
        """Open a device-work span at enqueue time. Returns ``None``
        when disabled (``end_device(None)`` is a no-op), so call sites
        stay unconditional."""
        if not self.enabled:
            return None
        h = _DeviceSpan(name, attrs, time.perf_counter(),
                        threading.current_thread().name)
        with self._lock:
            self._open[id(h)] = h
        return h

    def end_device(self, handle: Optional[_DeviceSpan],
                   **attrs: Any) -> None:
        """Close a device-work span. Callers must already be at a sync
        site (they contain a ``device_get``/``block_until_ready``) —
        rule O002 checks this statically; the tracer never syncs."""
        if handle is None:
            return
        t1 = time.perf_counter()
        handle.args.update(attrs)
        with self._lock:
            self._open.pop(id(handle), None)
        self._append(handle.name, "device", "X", handle.t0,
                     t1 - handle.t0, handle.args, tid=handle.tid)

    def open_device_count(self) -> int:
        """Device spans begun but not yet ended — 0 after a full drain
        (the span-balance invariant the tests assert, including across
        ``PagePoolExhausted`` rollback and speculative fallback)."""
        with self._lock:
            return len(self._open)

    # -- storage / export ----------------------------------------------
    def _append(self, name: str, cat: str, ph: str, t0: float,
                dur_s: float, args: Dict[str, Any],
                tid: Optional[str] = None) -> None:
        rec = {"name": name, "cat": cat, "ph": ph,
               "ts": (t0 - self._epoch) * 1e6,
               "dur": dur_s * 1e6,
               "tid": tid or threading.current_thread().name,
               "args": args}
        with self._lock:
            self._records.append(rec)

    def records(self) -> List[Dict[str, Any]]:
        """A snapshot copy of all records (JSONL-shaped dicts)."""
        with self._lock:
            return [dict(r) for r in self._records]

    def clear(self) -> None:
        with self._lock:
            self._records.clear()

    def export_chrome(self, path: str) -> int:
        """Write Chrome ``trace_event`` JSON (open in chrome://tracing
        or Perfetto). Returns the number of events written."""
        recs = self.records()
        tids: Dict[str, int] = {}
        events: List[Dict[str, Any]] = []
        for r in recs:
            tid = tids.setdefault(r["tid"], len(tids) + 1)
            ev: Dict[str, Any] = {"name": r["name"], "cat": r["cat"],
                                  "ph": r["ph"], "pid": 1, "tid": tid,
                                  "ts": r["ts"], "args": r["args"]}
            if r["ph"] == "X":
                ev["dur"] = r["dur"]
            else:
                ev["s"] = "t"
            events.append(ev)
        meta = [{"name": "thread_name", "ph": "M", "pid": 1, "tid": t,
                 "args": {"name": n}} for n, t in sorted(
                     tids.items(), key=lambda kv: kv[1])]
        with open(path, "w") as fh:
            json.dump({"traceEvents": meta + events,
                       "displayTimeUnit": "ms"}, fh, default=str)
        return len(events)

    def export_jsonl(self, path: str) -> int:
        """One record per line — greppable (``grep '"trace": 42'``)."""
        recs = self.records()
        with open(path, "w") as fh:
            for r in recs:
                fh.write(json.dumps(r, default=str))
                fh.write("\n")
        return len(recs)


#: Shared disabled tracer — the default binding everywhere, so serving
#: code calls ``self.tracer.event(...)`` unconditionally and never
#: branches on "is tracing on". ``span``s on it still measure (stats
#: consumers keep their numbers); nothing is recorded.
NULL_TRACER = Tracer(enabled=False)
