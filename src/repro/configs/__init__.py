"""Architecture config registry: one module per assigned architecture.

``get_config(name)`` returns the exact full-size config; every config also
has ``.reduced()`` for CPU smoke tests. ``ALL_ARCHS`` lists the assigned
pool plus the paper's own expert-matcher config lives in repro.core.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.common import ArchConfig

ALL_ARCHS: List[str] = [
    "rwkv6_7b",
    "zamba2_7b",
    "seamless_m4t_large_v2",
    "smollm_135m",
    "internvl2_26b",
    "qwen2_72b",
    "mixtral_8x22b",
    "olmoe_1b_7b",
    "qwen2_5_14b",
    "llama3_2_1b",
]

_ALIASES = {a.replace("_", "-"): a for a in ALL_ARCHS}
_ALIASES.update({
    "rwkv6-7b": "rwkv6_7b",
    "zamba2-7b": "zamba2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "smollm-135m": "smollm_135m",
    "internvl2-26b": "internvl2_26b",
    "qwen2-72b": "qwen2_72b",
    "mixtral-8x22b": "mixtral_8x22b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2.5-14b": "qwen2_5_14b",
    "llama3.2-1b": "llama3_2_1b",
})


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ALL_ARCHS}
