"""SmolLM-135M — llama-arch small dense LM.

[hf:HuggingFaceTB/SmolLM-135M] 30L, d_model=576, 9H (GQA kv=3), d_ff=1536,
vocab=49152, tied embeddings.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    remat=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    train_microbatches=4,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
